"""Benchmark trajectory tracking: one schema-versioned JSON point per run.

    PYTHONPATH=src python -m benchmarks.track [--out-dir .] [--no-gate]
    PYTHONPATH=src python -m benchmarks.run --track        (same thing)

Runs the smoke-sized sweeps (shared-load scheduling, out-of-core serving,
fused-kernel vs pure-jnp ref timing, roofline if dry-run artifacts exist),
emits ``BENCH_<utc-date>.json`` and appends a compact summary point to the
repo-root ``bench_trajectory.json``.  CI uploads the file as an artifact
and fails when a tracked metric regresses >20% against the last committed
``BENCH_*.json`` (deterministic counters gate hard; timing metrics also
need to clear an absolute noise floor, since CI runners are shared).

Schema (version 1):
  { "schema_version": 1, "utc_date": "...", "platform": {...},
    "shared":  [ {mode, batch, loads_per_query, cold_loads, warm_loads,
                  p50_ms, p95_ms, p99_ms, qps}, ... ],
    "oocore":  [ {mode, disk_reads, read_ahead_hits, cold_loads,
                  warm_loads, p50_ms, p95_ms, p99_ms}, ... ],
    "kernel":  {shape, ref_ms, fused_ms, speedup},
    "roofline": {available, note} }

(p99_ms joined within schema v1: the gate guards each timing key with a
presence check, so points committed before the key exists still compare
on the keys they have.  ``--trials N`` repeats the sweeps: timing keys
become across-trial means with ``<key>_std`` sample stddevs and the
point records ``n_trials`` — measured variance the EWMA regression
detector in benchmarks/regress.py sizes its noise bands from.  The
trajectory keeps ONE point per utc_date: a re-run replaces that day's
entry instead of double-weighting it.)
"""
from __future__ import annotations

import argparse
import datetime
import glob
import json
import os
import statistics
import sys
import time
from typing import Dict, List, Optional

sys.path.insert(0, "src")

SCHEMA_VERSION = 1

# >20% worse than the last committed point fails CI
REL_TOL = 0.20
# timing metrics additionally need to move by this much in absolute terms
# (shared CI runners jitter small numbers well past 20%)
ABS_MS_FLOOR = 75.0
ABS_QPS_FLOOR = 0.5


def _utc_date() -> str:
    return datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%d")


# -- collection --------------------------------------------------------------

def _collect_shared(seed: int) -> List[Dict]:
    from .common import run_shared_sweep
    res = run_shared_sweep(batch_sizes=(2, 8), seed=seed)
    if not (res.answers_identical and res.oracle_match):
        sys.exit("track: shared sweep answers diverged from the oracle")
    return [dict(mode=p.mode, batch=p.batch,
                 loads_per_query=round(p.loads_per_query, 4),
                 cold_loads=p.cold_loads, warm_loads=p.warm_loads,
                 p50_ms=round(p.p50_ms, 3), p95_ms=round(p.p95_ms, 3),
                 p99_ms=round(p.p99_ms, 3),
                 qps=round(p.qps, 4))
            for p in res.phases]


def _collect_oocore(seed: int) -> List[Dict]:
    from .common import run_oocore_sweep
    res = run_oocore_sweep(seed=seed)
    if not (res.answers_identical and res.oracle_match):
        sys.exit("track: oocore sweep answers diverged from the oracle")
    return [dict(mode=p.mode, disk_reads=p.disk_reads,
                 read_ahead_hits=p.read_ahead_hits,
                 cold_loads=p.cold_loads, warm_loads=p.warm_loads,
                 p50_ms=round(p.p50_ms, 3), p95_ms=round(p.p95_ms, 3),
                 p99_ms=round(p.p99_ms, 3))
            for p in res.phases]


def _collect_kernel(seed: int, reps: int = 5) -> Dict:
    """Fused Pallas kernel (interpret off-TPU) vs its pure-jnp ref twin on
    one fixed synthetic tile.  On TPU the speedup is the point of the
    kernel; on CPU interpret mode is a *correctness* path and slower than
    the ref — the trajectory records the ratio either way, tagged with the
    backend so points are only comparable within a platform."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.plan import PlanArrays
    from repro.kernels import ops

    EB, W, Q, Np, S, V = 64, 128, 8, 64, 6, 1000
    rng = np.random.default_rng(seed)
    plan = PlanArrays(
        n_slots=Q, n_steps=S,
        start_slot=np.int32(0), start_label=np.int32(0),
        start_value_op=np.int32(0), start_value=np.float32(0),
        src_slot=rng.integers(0, Q, S).astype(np.int32),
        dst_slot=rng.integers(0, Q, S).astype(np.int32),
        edge_label=rng.integers(-1, 3, S).astype(np.int32),
        direction=rng.integers(0, 3, S).astype(np.int32),
        dst_label=rng.integers(-1, 3, S).astype(np.int32),
        dst_value_op=rng.integers(0, 7, S).astype(np.int32),
        dst_value=rng.normal(size=S).astype(np.float32),
        closes_cycle=rng.integers(0, 2, S).astype(np.int32))
    dst = rng.integers(-1, Np, size=(Np, W)).astype(np.int32)
    tables = (dst,
              rng.integers(-2, 3, size=(Np, W)).astype(np.int32),
              rng.integers(0, 3, size=(Np, W)).astype(np.int32),
              rng.integers(-2, 3, size=(Np, W)).astype(np.int32),
              rng.normal(size=(Np, W)).astype(np.float32),
              np.where(dst >= 0, rng.integers(0, V, size=(Np, W)),
                       -1).astype(np.int32))
    g2l = rng.integers(-1, Np, size=V).astype(np.int32)
    owner = rng.integers(0, 4, size=V).astype(np.int32)
    n_core = np.int32(Np // 2)
    rows = rng.integers(-1, V, size=(EB, Q)).astype(np.int32)
    step = rng.integers(0, S, size=EB).astype(np.int32)
    lidx = rng.integers(0, Np, size=EB).astype(np.int32)
    m = rng.random(EB) < 0.8
    n_steps = np.int32(S - 1)
    dlidx, downer = ops.denorm_locality(jnp.asarray(tables[5]),
                                        jnp.asarray(g2l), jnp.asarray(owner))
    # device-commit everything (incl. the PlanArrays pytree): numpy leaves
    # captured in a jit closure cannot be indexed by traced step values
    plan = jax.tree_util.tree_map(jnp.asarray, plan)
    tables = tuple(jnp.asarray(t) for t in tables)
    rows, step, lidx, m = map(jnp.asarray, (rows, step, lidx, m))
    g2l, owner = jnp.asarray(g2l), jnp.asarray(owner)

    fused = jax.jit(lambda: ops.fused_frontier(
        rows, step, lidx, m, *tables, dlidx, downer, g2l, owner, n_core,
        plan, n_steps))
    ref = jax.jit(lambda: ops.fused_frontier_ref(
        rows, step, lidx, m, *tables, g2l, owner, n_core, plan, n_steps))

    def _time(fn) -> float:
        jax.block_until_ready(fn())           # compile outside the clock
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / reps * 1000.0

    ref_ms = _time(ref)
    fused_ms = _time(fused)
    return dict(shape=dict(EB=EB, W=W, Q=Q, Np=Np),
                backend=jax.default_backend(),
                ref_ms=round(ref_ms, 3), fused_ms=round(fused_ms, 3),
                speedup=round(ref_ms / fused_ms, 4) if fused_ms else None)


def _collect_roofline(dryrun_dir: str) -> Dict:
    from . import roofline
    note = roofline.report(dryrun_dir)
    available = not note.startswith("(")
    return dict(available=available,
                note=None if available else note.strip())


# phase keys whose values are timing measurements (noisy across trials);
# everything else in a phase dict is a deterministic counter and must be
# identical on every trial of the same seed
_TIMING_KEYS = ("p50_ms", "p95_ms", "p99_ms", "qps", "ref_ms", "fused_ms")


def _merge_trials(runs: List[List[Dict]], id_keys: List[str]) -> List[Dict]:
    """Fold N trials of one sweep into its first trial's phase list:
    timing keys become the across-trial mean plus a ``<key>_std`` sample
    stddev; deterministic counters must agree across trials (same seed →
    same schedule) and a mismatch aborts — that's a real nondeterminism
    bug, not noise."""
    base = [dict(p) for p in runs[0]]
    if len(runs) == 1:
        return base
    for i, p in enumerate(base):
        for k in list(p):
            if k in _TIMING_KEYS:
                vals = [float(r[i][k]) for r in runs]
                p[k] = round(statistics.mean(vals), 3)
                p[k + "_std"] = round(statistics.stdev(vals), 3)
            elif k not in id_keys and any(r[i].get(k) != p[k]
                                          for r in runs[1:]):
                sys.exit(f"track: counter {k!r} diverged across trials of "
                         f"the same seed ({[r[i].get(k) for r in runs]}) — "
                         f"nondeterministic scheduling")
    return base


def collect(seed: int = 0, dryrun_dir: str = "results/dryrun",
            trials: int = 1) -> Dict:
    trials = max(1, int(trials))
    shared = _merge_trials([_collect_shared(seed) for _ in range(trials)],
                           ["mode", "batch"])
    oocore = _merge_trials([_collect_oocore(seed) for _ in range(trials)],
                           ["mode"])
    kruns = [_collect_kernel(seed) for _ in range(trials)]
    # "speedup" is derived from timing, so it rides the id-key exemption
    # and is recomputed from the merged means below
    kernel = _merge_trials([[k] for k in kruns],
                           ["shape", "backend", "speedup"])[0]
    if trials > 1 and kernel.get("fused_ms"):
        kernel["speedup"] = round(kernel["ref_ms"] / kernel["fused_ms"], 4)
    return {
        "schema_version": SCHEMA_VERSION,
        "utc_date": _utc_date(),
        "n_trials": trials,
        "shared": shared,
        "oocore": oocore,
        "kernel": kernel,
        "roofline": _collect_roofline(dryrun_dir),
    }


# -- regression gate ---------------------------------------------------------

def _phase_map(phases: List[Dict], keys: List[str]) -> Dict:
    return {tuple(p.get(k) for k in keys): p for p in phases}


def compare(current: Dict, baseline: Dict) -> List[str]:
    """Regressions of ``current`` vs ``baseline`` (empty list: gate green).

    Deterministic counters (loads per query, cold loads, disk reads) gate
    hard at >20%; timing metrics (p50/p95, q/s) must regress >20% AND by
    more than an absolute noise floor.
    """
    fails: List[str] = []
    if baseline.get("schema_version") != current.get("schema_version"):
        return []   # schema changed on purpose; nothing comparable

    def worse_counter(cur, base) -> bool:
        return cur > base * (1 + REL_TOL) and cur > base + 1

    def worse_ms(cur, base) -> bool:
        return cur > base * (1 + REL_TOL) and cur > base + ABS_MS_FLOOR

    def worse_qps(cur, base) -> bool:
        return cur < base * (1 - REL_TOL) and cur < base - ABS_QPS_FLOOR

    cur_s = _phase_map(current.get("shared", []), ["mode", "batch"])
    for key, b in _phase_map(baseline.get("shared", []),
                             ["mode", "batch"]).items():
        c = cur_s.get(key)
        if c is None:
            continue
        tag = f"shared[{key[0]},B={key[1]}]"
        if worse_counter(c["loads_per_query"], b["loads_per_query"]):
            fails.append(f"{tag}.loads_per_query {b['loads_per_query']} -> "
                         f"{c['loads_per_query']}")
        if worse_counter(c["cold_loads"], b["cold_loads"]):
            fails.append(f"{tag}.cold_loads {b['cold_loads']} -> "
                         f"{c['cold_loads']}")
        for k in ("p50_ms", "p95_ms", "p99_ms"):
            # presence-guarded: baselines written before p99_ms joined the
            # schema simply don't gate on it
            if k in c and k in b and worse_ms(c[k], b[k]):
                fails.append(f"{tag}.{k} {b[k]} -> {c[k]}")
        if worse_qps(c["qps"], b["qps"]):
            fails.append(f"{tag}.qps {b['qps']} -> {c['qps']}")

    cur_o = _phase_map(current.get("oocore", []), ["mode"])
    for key, b in _phase_map(baseline.get("oocore", []), ["mode"]).items():
        c = cur_o.get(key)
        if c is None:
            continue
        tag = f"oocore[{key[0]}]"
        for k in ("disk_reads", "cold_loads"):
            if worse_counter(c[k], b[k]):
                fails.append(f"{tag}.{k} {b[k]} -> {c[k]}")
        for k in ("p50_ms", "p95_ms", "p99_ms"):
            if k in c and k in b and worse_ms(c[k], b[k]):
                fails.append(f"{tag}.{k} {b[k]} -> {c[k]}")
    return fails


def last_committed(baseline_dir: str, exclude: Optional[str] = None) -> Optional[str]:
    """Path of the newest (lexicographically last dated) BENCH_*.json."""
    cands = sorted(glob.glob(os.path.join(baseline_dir, "BENCH_*.json")))
    if exclude is not None:
        ex = os.path.abspath(exclude)
        cands = [c for c in cands if os.path.abspath(c) != ex]
    return cands[-1] if cands else None


# -- trajectory --------------------------------------------------------------

def summary_point(point: Dict) -> Dict:
    """The compact per-run record appended to bench_trajectory.json.

    ``kernel_speedup`` is recorded only off-CPU: interpret-mode Pallas on
    CPU is a correctness path, so its ratio tracks interpreter overhead,
    not the kernel — comparing it across runs would gate on noise about
    the wrong thing (``kernel_backend`` still records where the point
    ran).  Timing metrics carry their across-trial stddev when the run
    measured more than one trial, so the regression detector
    (benchmarks/regress.py) can size its noise band from measured
    variance instead of guessing."""
    shared8 = next((p for p in point["shared"]
                    if p["mode"] == "shared" and p["batch"] == 8), None)
    ooc = next((p for p in point["oocore"] if p["mode"] == "out-of-core"),
               None)
    backend = point["kernel"].get("backend")
    out = {
        "utc_date": point["utc_date"],
        "schema_version": point["schema_version"],
        "n_trials": point.get("n_trials", 1),
        "shared_b8_loads_per_query": (shared8 or {}).get("loads_per_query"),
        "shared_b8_qps": (shared8 or {}).get("qps"),
        "shared_b8_p95_ms": (shared8 or {}).get("p95_ms"),
        "oocore_disk_reads": (ooc or {}).get("disk_reads"),
        "kernel_speedup": (point["kernel"]["speedup"]
                           if backend != "cpu" else None),
        "kernel_backend": backend,
    }
    for src, dst in (("qps_std", "shared_b8_qps_std"),
                     ("p95_ms_std", "shared_b8_p95_ms_std")):
        if shared8 and src in shared8:
            out[dst] = shared8[src]
    return out


def append_trajectory(path: str, point: Dict) -> None:
    """Append this run's summary — replacing, not duplicating, any entry
    already recorded for the same ``utc_date`` (re-runs within a day
    would otherwise double-weight that day in every EWMA/variance the
    regression detector computes)."""
    traj: List[Dict] = []
    if os.path.exists(path):
        with open(path) as f:
            traj = json.load(f)
    sp = summary_point(point)
    traj = [t for t in traj if t.get("utc_date") != sp["utc_date"]]
    traj.append(sp)
    with open(path, "w") as f:
        json.dump(traj, f, indent=2)
        f.write("\n")


# -- entrypoint --------------------------------------------------------------

def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out-dir", default=".",
                    help="where BENCH_<utc-date>.json is written")
    ap.add_argument("--baseline-dir", default=".",
                    help="where the last committed BENCH_*.json lives")
    ap.add_argument("--trajectory", default="bench_trajectory.json",
                    help="repo-root trajectory file to append to")
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trials", type=int, default=1,
                    help="repeat each sweep N times: timing metrics "
                         "record their across-trial mean + stddev "
                         "(deterministic counters must agree), giving "
                         "the regression detector a measured noise band")
    ap.add_argument("--no-gate", action="store_true",
                    help="collect + emit but never fail on regression")
    args = ap.parse_args(argv)

    print("== benchmark trajectory point (smoke size) ==", flush=True)
    point = collect(seed=args.seed, dryrun_dir=args.dryrun_dir,
                    trials=args.trials)

    os.makedirs(args.out_dir, exist_ok=True)
    out_path = os.path.join(args.out_dir,
                            f"BENCH_{point['utc_date']}.json")
    with open(out_path, "w") as f:
        json.dump(point, f, indent=2)
        f.write("\n")
    print(f"   wrote {out_path}")

    append_trajectory(args.trajectory, point)
    print(f"   appended to {args.trajectory}")

    base_path = last_committed(args.baseline_dir, exclude=out_path)
    if base_path is None:
        print("   no committed BENCH_*.json baseline; gate skipped")
        return
    with open(base_path) as f:
        baseline = json.load(f)
    fails = compare(point, baseline)
    print(f"   gate vs {base_path}: "
          f"{'PASS' if not fails else f'{len(fails)} regression(s)'}")
    for msg in fails:
        print("   -", msg)
    if fails and not args.no_gate:
        sys.exit(f"track: >{int(REL_TOL * 100)}% regression vs {base_path}")


if __name__ == "__main__":
    main()
