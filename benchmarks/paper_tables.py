"""Paper Tables 3/4/5 and Figures 7-10 from one OPAT sweep.

  Table 3 — h(D)^{query}_{pschemes}: per-query mean load ratio across the
            six partitioning schemes, per heuristic.
  Table 4 — h(D)^{pscheme}_{qbatch}: per-scheme mean load ratio over the
            query batch, per heuristic.
  Table 5 — CC heuristic: Table-4 measure evaluated at the MIN-CC and
            MAX-CC schemes (+ total CC counts).
  Figures 7-10 — raw loads per (query, scheme, heuristic) vs L_ideal.

The paper's qualitative claims this reproduces (EXPERIMENTS.md §Tables):
  * MAX-SN >= MIN-SN >> RANDOM-SN on load ratio,
  * on IMDB (unique labels) MAX-SN == MIN-SN exactly,
  * MIN-CC schemes beat MAX-CC schemes,
  * ties when total-CC difference < 5%.
"""
from __future__ import annotations

import csv
import os
from typing import List


from .common import (ALL_HEURISTICS, BUDGET_HEURISTICS, MAX_SN, MIN_SN, RANDOM_SN,
                     BudgetSweepResult, OocoreSweepResult, SharedSweepResult, SweepResult,
                     WawSweepResult, fmt_table, avg_load_ratio_across_schemes,
                     avg_load_ratio_for_batch)


def table3(sweep: SweepResult, out_dir: str) -> str:
    queries = sorted({s.query for s in sweep.stats})
    rows = []
    for h in ALL_HEURISTICS:
        row = [h.upper()]
        for q in queries:
            row.append(f"{avg_load_ratio_across_schemes(sweep.stats, q, h):.3f}")
        rows.append(row)
    _csv(os.path.join(out_dir, "table3.csv"), ["heuristic"] + queries, rows)
    return fmt_table(rows, ["heuristic"] + queries)


def table4(sweep: SweepResult, out_dir: str) -> str:
    schemes = sorted({s.scheme for s in sweep.stats})
    workloads = sorted({s.query.split(":")[0] for s in sweep.stats})
    blocks = []
    for wl in workloads:
        sub = [s for s in sweep.stats if s.query.startswith(wl + ":")]
        rows = []
        for h in ALL_HEURISTICS:
            row = [f"{wl}:{h.upper()}"]
            for sc in schemes:
                row.append(f"{avg_load_ratio_for_batch(sub, sc, h):.3f}")
            rows.append(row)
        blocks.append(fmt_table(rows, ["batch"] + schemes))
        _csv(os.path.join(out_dir, f"table4_{wl}.csv"), ["batch"] + schemes, rows)
    return "\n\n".join(blocks)


def table5(sweep: SweepResult, out_dir: str) -> str:
    workloads = sorted({s.query.split(":")[0] for s in sweep.stats})
    rows = []
    for wl in workloads:
        ccs = {sc: cc for (w, sc), cc in sweep.total_cc.items() if w == wl}
        min_cc = min(ccs, key=ccs.get)
        max_cc = max(ccs, key=ccs.get)
        sub = [s for s in sweep.stats if s.query.startswith(wl + ":")]
        for h in (MAX_SN, MIN_SN):
            rows.append([
                wl, h.upper(),
                f"{min_cc}({ccs[min_cc]})",
                f"{avg_load_ratio_for_batch(sub, min_cc, h):.3f}",
                f"{max_cc}({ccs[max_cc]})",
                f"{avg_load_ratio_for_batch(sub, max_cc, h):.3f}",
            ])
    header = ["workload", "heuristic", "MIN-CC scheme", "ratio@MIN-CC",
              "MAX-CC scheme", "ratio@MAX-CC"]
    _csv(os.path.join(out_dir, "table5.csv"), header, rows)
    return fmt_table(rows, header)


def table_k_budget(budget: BudgetSweepResult, out_dir: str) -> str:
    """Response-time vs K: per (query, heuristic, K) — partition loads,
    loads saved vs the exhaustive run, and answers returned.  Loads are
    the response-time proxy (each load = one partition residency, the
    paper's cost unit); the "saved" column is what the answer budget buys,
    and MAX-YIELD vs MAX-SN/MIN-SN shows the budget-aware heuristic's
    edge at small K."""
    def k_label(k):
        return "inf" if k is None else str(k)

    queries = sorted({s.query for s in budget.stats})
    # derive from the data (BUDGET_HEURISTICS order first, then any extras)
    present = {s.heuristic for s in budget.stats}
    heuristics = ([h for h in BUDGET_HEURISTICS if h in present]
                  + sorted(present - set(BUDGET_HEURISTICS)))
    ks = sorted({s.answers_requested for s in budget.stats},
                key=lambda k: (k is None, k))
    rows = []
    for q in queries:
        for h in heuristics:
            row = [q, h.upper()]
            for kk in ks:
                sub = [s for s in budget.stats
                       if s.query == q and s.heuristic == h
                       and s.answers_requested == kk]
                if sub:
                    s = sub[0]
                    row.append(f"{s.n_loads}(-{s.loads_saved_vs_full})"
                               f"/{s.n_answers}a")
                else:
                    row.append("-")
            rows.append(row)
    header = ["query", "heuristic"] + [f"K={k_label(k)} loads(-saved)/ans"
                                       for k in ks]
    _csv(os.path.join(out_dir, "table_k_budget.csv"), header, rows)
    return fmt_table(rows, header)


def table_waw(waw: WawSweepResult, out_dir: str) -> str:
    """Before/after workload-aware repartitioning on the same skewed query
    mix (WawPart loop; edge-cut vs query-locality frame of Averbuch &
    Neumann).  Loads-per-query and answer span are the query-locality
    side; edge cut is the topology side — the point of the table is that
    the ``"waw"`` layout improves the former without paying on the
    latter, at identical (oracle-verified) answer sets."""
    rows = []
    for phase in (waw.baseline, waw.waw):
        rows.append([
            phase.scheme,
            f"{phase.mean_loads:.2f}",
            f"{phase.mean_span:.2f}",
            phase.edge_cut,
            f"{phase.latency_s*1000:.0f}",
            phase.n_answers,
        ])
    header = ["scheme", "loads/query", "answer span", "edge cut",
              "latency ms", "answers"]
    _csv(os.path.join(out_dir, "table_waw.csv"), header, rows)
    verdict = ("identical answer sets"
               if waw.answers_identical else "ANSWER SETS DIFFER")
    oracle = "oracle MATCH" if waw.oracle_match else "oracle MISMATCH"
    return (fmt_table(rows, header)
            + f"\n({verdict}, {oracle}; repartition round "
              f"{waw.repartition_info['round']}, cut "
              f"{waw.repartition_info['cut_before']} -> "
              f"{waw.repartition_info['cut_after']})")


def table_shared(shared: SharedSweepResult, out_dir: str) -> str:
    """Isolated vs shared serving of the same overlapping query batches
    (QueryScheduler, core/scheduler.py).  Loads-per-query and the
    cold-load column are the amortization story — one device-resident
    partition advancing B pending queries in a single batched evaluation
    — and queries/sec is what that buys at the workload level; per-query
    answers are verified identical across modes (and vs the oracle), so
    the speedup never changes semantics."""
    rows = []
    for p in shared.phases:
        rows.append([
            p.batch, p.mode, p.n_loads, f"{p.loads_per_query:.2f}",
            p.cold_loads, p.warm_loads,
            f"{p.p50_ms:.0f}", f"{p.p95_ms:.0f}", f"{p.p99_ms:.0f}",
            f"{p.qps:.1f}", p.n_answers,
        ])
    header = ["batch", "mode", "loads", "loads/query", "cold", "warm",
              "p50 ms", "p95 ms", "p99 ms", "q/s", "answers"]
    _csv(os.path.join(out_dir, "table_shared.csv"), header, rows)
    verdict = ("identical answer sets"
               if shared.answers_identical else "ANSWER SETS DIFFER")
    oracle = "oracle MATCH" if shared.oracle_match else "oracle MISMATCH"
    return fmt_table(rows, header) + f"\n({verdict}, {oracle})"


def table_oocore(oocore: OocoreSweepResult, out_dir: str) -> str:
    """In-RAM vs out-of-core serving of the same query mix (disk →
    pinned-host LRU → device LRU, src/repro/storage/).  The graph's total
    shard bytes exceed the host budget, so the out-of-core row pays real
    disk reads; the read-ahead column shows how many of those overlapped
    evaluation instead of blocking a load, and the latency columns price
    the tier against the all-in-RAM baseline — at identical,
    oracle-verified answers."""
    rows = []
    for p in oocore.phases:
        rows.append([
            p.mode, p.disk_reads,
            f"{p.read_ahead_hits}/{p.read_ahead_issued}",
            p.cold_loads, p.warm_loads, p.bytes_disk,
            f"{p.p50_ms:.0f}", f"{p.p95_ms:.0f}", f"{p.p99_ms:.0f}",
            p.n_answers,
        ])
    header = ["mode", "disk reads", "ra hit/issued", "cold", "warm",
              "disk bytes", "p50 ms", "p95 ms", "p99 ms", "answers"]
    _csv(os.path.join(out_dir, "table_oocore.csv"), header, rows)
    verdict = ("identical answer sets"
               if oocore.answers_identical else "ANSWER SETS DIFFER")
    oracle = "oracle MATCH" if oocore.oracle_match else "oracle MISMATCH"
    return (fmt_table(rows, header)
            + f"\n({verdict}, {oracle}; {oocore.total_part_bytes} shard "
              f"bytes on disk vs a {oocore.host_cap_bytes}-byte host "
              f"budget = {oocore.host_cache_parts}/{oocore.k} partitions)")


def figs_loads(sweep: SweepResult, out_dir: str) -> str:
    """Figures 7-10 raw data: #loads per (query, scheme, heuristic)."""
    rows = []
    for s in sorted(sweep.stats, key=lambda s: (s.query, s.scheme, s.heuristic)):
        rows.append([s.query, s.scheme, s.heuristic, s.l_ideal, s.n_loads,
                     f"{s.load_ratio:.3f}", s.n_answers,
                     " ".join(map(str, s.loads))])
    header = ["query", "scheme", "heuristic", "L_ideal", "loads", "ratio",
              "answers", "load_sequence"]
    _csv(os.path.join(out_dir, "figs_loads.csv"), header, rows)
    return fmt_table([r[:7] for r in rows[:24]], header[:7]) + \
        f"\n... ({len(rows)} rows total, full data in figs_loads.csv)"


def validate_claims(sweep: SweepResult) -> List[str]:
    """The paper's qualitative claims, checked mechanically."""
    failures = []
    queries = sorted({s.query for s in sweep.stats})
    for q in queries:
        mx = avg_load_ratio_across_schemes(sweep.stats, q, MAX_SN)
        mn = avg_load_ratio_across_schemes(sweep.stats, q, MIN_SN)
        rd = avg_load_ratio_across_schemes(sweep.stats, q, RANDOM_SN)
        if not mx >= mn - 1e-9:
            failures.append(f"MAX-SN < MIN-SN on {q}: {mx:.3f} vs {mn:.3f}")
        if not mx >= rd - 1e-9:
            failures.append(f"MAX-SN < RANDOM on {q}: {mx:.3f} vs {rd:.3f}")
        if q.startswith("IMDB:") and abs(mx - mn) > 1e-9:
            failures.append(f"IMDB MAX-SN != MIN-SN on {q} (unique labels)")
    # MIN-CC >= MAX-CC per workload (when CC difference is significant)
    for wl in sorted({s.query.split(":")[0] for s in sweep.stats}):
        ccs = {sc: cc for (w, sc), cc in sweep.total_cc.items() if w == wl}
        min_cc = min(ccs, key=ccs.get)
        max_cc = max(ccs, key=ccs.get)
        if ccs[max_cc] and (ccs[max_cc] - ccs[min_cc]) / ccs[max_cc] >= 0.05:
            sub = [s for s in sweep.stats if s.query.startswith(wl + ":")]
            lo = avg_load_ratio_for_batch(sub, min_cc, MAX_SN)
            hi = avg_load_ratio_for_batch(sub, max_cc, MAX_SN)
            if lo + 0.05 < hi:
                failures.append(
                    f"MIN-CC worse than MAX-CC on {wl}: {lo:.3f} vs {hi:.3f}")
    return failures


def _csv(path: str, header: List[str], rows: List[List]) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
