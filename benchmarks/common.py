"""Shared benchmark harness: run (dataset x scheme x heuristic x query)
sweeps and collect the paper's RunStats.

Each (workload, scheme) pair opens one ``GraphSession`` (core/session.py)
and serves every query/heuristic through it — the paper's serving shape:
one engine compile, partitions staged into the session's ``PartitionStore``
once (cold) and reused across the batch (warm), with per-run RunStats
carrying the scheme name and the cold/warm split.

Scales: ``--paper-scale`` regenerates the paper's sizes (IMDB 1750K/5100K,
synthetic 400K/1200K); default sizes finish on a laptop CPU in minutes and
preserve every structural property the heuristics depend on (unique IMDB
labels, embedded template instances that span partitions).
"""
from __future__ import annotations

import dataclasses
import sys
import tempfile
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

sys.path.insert(0, "src")

from repro.core import (ALL_HEURISTICS, BUDGET_HEURISTICS, EngineConfig,
                        GraphSession, MAX_SN, MAX_YIELD, MAX_YIELD_SHARED,
                        MIN_SN, RANDOM_SN, RunStats, SCHEMES,
                        answer_span_matrix, avg_load_ratio_across_schemes,
                        avg_load_ratio_for_batch, build_catalog,
                        build_partitions, generate_plan, match_disjunctive,
                        partition_graph, partition_quality,
                        total_connected_components, validate_run_residency)
from repro.data.generators import (imdb_like_graph, imdb_queries,
                                   subgen_like_graph, subgen_queries,
                                   waw_skewed_graph, waw_skewed_queries)

# this module is the import hub for the benchmark drivers: the names below
# are re-exported for paper_tables.py / mp_scaling.py / track.py even when
# unused here
__all__ = [
    "ALL_HEURISTICS", "BUDGET_HEURISTICS", "EngineConfig", "GraphSession",
    "MAX_SN", "MAX_YIELD", "MAX_YIELD_SHARED", "MIN_SN", "RANDOM_SN",
    "RunStats", "SCHEMES", "avg_load_ratio_across_schemes",
    "avg_load_ratio_for_batch", "build_catalog", "build_partitions",
    "generate_plan", "partition_graph",
]

K_PARTITIONS = 4   # the paper's experimental setting


@dataclasses.dataclass
class Workload:
    name: str
    graph: object
    dqueries: list


def build_workloads(scale: float = 1.0, seed: int = 0) -> List[Workload]:
    imdb = imdb_like_graph(n_movies=int(300 * scale),
                           n_people=int(400 * scale),
                           n_companies=max(4, int(40 * scale)), seed=seed)
    synth = subgen_like_graph(n_nodes=int(2000 * scale),
                              n_edges=int(6000 * scale),
                              n_embed=max(10, int(50 * scale)), seed=seed)
    return [Workload("IMDB", imdb, imdb_queries(imdb, seed=seed)),
            Workload("Synthetic", synth, subgen_queries(synth))]


@dataclasses.dataclass
class SweepResult:
    stats: List[RunStats]
    total_cc: Dict[Tuple[str, str], int]     # (workload, scheme) -> total CC
    wall_s: float


def aggregate_disjuncts(per_disjunct: Sequence[RunStats], query: str,
                        scheme: str, heuristic: str, **extra) -> RunStats:
    """Fold the per-disjunct RunStats of one DisjunctiveQuery into the
    single record the tables consume (shared by every sweep so the
    aggregation convention cannot diverge between them)."""
    loads: List[int] = []
    l_ideal = 0
    n_answers = 0
    iters = 0
    for s in per_disjunct:
        loads += s.loads
        l_ideal = max(l_ideal, s.l_ideal)
        n_answers += s.n_answers
        iters += s.iterations

    def _fold(field):  # sum the store counters when every disjunct has them
        vals = [getattr(s, field) for s in per_disjunct]
        return sum(vals) if all(v is not None for v in vals) else None

    return RunStats(query=query, scheme=scheme, heuristic=heuristic,
                    loads=loads, l_ideal=l_ideal, n_answers=n_answers,
                    iterations=iters, cold_loads=_fold("cold_loads"),
                    warm_loads=_fold("warm_loads"),
                    prefetch_hits=_fold("prefetch_hits"), **extra)


def run_sweep(workloads: Sequence[Workload],
              schemes: Sequence[str] = tuple(sorted(SCHEMES)),
              heuristics: Sequence[str] = ALL_HEURISTICS,
              seed: int = 0, cap: int = 32768,
              k: int = K_PARTITIONS) -> SweepResult:
    t0 = time.time()
    stats: List[RunStats] = []
    total_cc: Dict[Tuple[str, str], int] = {}
    for wl in workloads:
        catalog = build_catalog(wl.graph)
        for scheme in schemes:
            sess = GraphSession(wl.graph, k=k, scheme=scheme, engine="opat",
                                config=EngineConfig(cap=cap), seed=seed,
                                catalog=catalog)
            total_cc[(wl.name, scheme)] = total_connected_components(sess.pg)
            for dq in wl.dqueries:
                for heuristic in heuristics:
                    res = sess.submit(dq, heuristic=heuristic)
                    merged = aggregate_disjuncts(
                        res.stats, f"{wl.name}:{dq.name}", scheme,
                        heuristic)
                    # OPAT's load unit is the single partition, so the
                    # residency classes must tile the load sequence
                    validate_run_residency(merged)
                    stats.append(merged)
    return SweepResult(stats=stats, total_cc=total_cc,
                       wall_s=time.time() - t0)


BUDGET_KS = (1, 10, 100, None)   # None = exhaustive ("K = inf")


@dataclasses.dataclass
class BudgetSweepResult:
    """OPAT answer-budget runs: the response-time-vs-K raw data."""

    stats: List[RunStats]     # answers_requested / loads_saved_vs_full set
    wall_s: float


def run_budget_sweep(workloads: Sequence[Workload],
                     scheme: str = "kway_shem",
                     heuristics: Sequence[str] = BUDGET_HEURISTICS,
                     ks: Sequence[Optional[int]] = BUDGET_KS,
                     seed: int = 0, cap: int = 32768,
                     k_partitions: int = K_PARTITIONS) -> BudgetSweepResult:
    """Run every query at each answer budget K through one warm
    ``GraphSession`` and record how many partition loads the budget saved
    vs the exhaustive run (the paper's "specified number of answers" mode,
    Sec. 1/5)."""
    t0 = time.time()
    stats: List[RunStats] = []
    for wl in workloads:
        sess = GraphSession(wl.graph, k=k_partitions, scheme=scheme,
                            engine="opat", config=EngineConfig(cap=cap),
                            seed=seed)
        for dq in wl.dqueries:
            for heuristic in heuristics:
                # exhaustive baseline per (query, heuristic); each disjunct's
                # stats are reused verbatim whenever the budget cannot bind
                # on it: K=None, or K strictly above its total answer count
                # (at K == total the budgeted run may stop earlier than
                # exhaustion, so it must execute for real — and a re-run
                # would repeat the same deterministic load sequence anyway,
                # contributing 0 to `saved`)
                full = sess.submit(dq, heuristic=heuristic)
                for kk in ks:
                    per_disjunct = []
                    for q, fstat in zip(dq.disjuncts, full.stats):
                        if kk is None or fstat.n_answers < kk:
                            per_disjunct.append(fstat)
                        else:
                            per_disjunct.append(sess.submit(
                                q, max_answers=kk,
                                heuristic=heuristic).stats[0])
                    saved = sum(f.n_loads - r.n_loads
                                for f, r in zip(full.stats, per_disjunct))
                    merged = aggregate_disjuncts(
                        per_disjunct, f"{wl.name}:{dq.name}", scheme,
                        heuristic, answers_requested=kk,
                        loads_saved_vs_full=saved)
                    validate_run_residency(merged)
                    stats.append(merged)
    return BudgetSweepResult(stats=stats, wall_s=time.time() - t0)


@dataclasses.dataclass
class WawPhase:
    """One serving phase of the before/after repartitioning comparison."""

    scheme: str
    stats: List[RunStats]
    mean_loads: float          # mean partitions loaded per query
    mean_span: float           # mean #partitions an answer's bindings hit
    edge_cut: int              # unweighted cut of the phase's assignment
    latency_s: float           # summed submit latency over the mix
    n_answers: int


@dataclasses.dataclass
class WawSweepResult:
    """Before/after workload-aware repartitioning on the same query mix."""

    baseline: WawPhase
    waw: WawPhase
    answers_identical: bool    # same answer sets per query across phases
    oracle_match: bool         # both phases match the whole-graph oracle
    repartition_info: Dict
    wall_s: float


def run_waw_sweep(scheme: str = "kway_shem", k: int = 2,
                  hot_repeats: int = 6, seed: int = 0, cap: int = 32768,
                  engine: str = "opat") -> WawSweepResult:
    """Close the WawPart loop on a skewed synthetic workload and measure
    both sides: serve the mix on the baseline layout, feed the session's
    own workload profile to ``GraphSession.repartition()``, serve the SAME
    mix on the ``"waw"`` layout, and report loads-per-query, answer spans,
    edge cut, and response time for each phase (plus oracle verification
    that the answer sets are identical — repartitioning must never change
    semantics, only placement)."""
    t0 = time.time()
    graph = waw_skewed_graph(seed=seed)
    mix = waw_skewed_queries(hot_repeats)
    sess = GraphSession(graph, k=k, scheme=scheme, engine=engine,
                        config=EngineConfig(cap=cap), seed=seed)

    def phase() -> Tuple[WawPhase, Dict[str, np.ndarray]]:
        stats: List[RunStats] = []
        answers: Dict[str, np.ndarray] = {}
        span_sum, span_rows, latency = 0, 0, 0.0
        for dq in mix:
            res = sess.submit(dq)
            stats.append(aggregate_disjuncts(res.stats, dq.name,
                                             sess.scheme, sess.heuristic))
            _, span = answer_span_matrix(sess.pg.owner, res.answers, sess.k)
            span_sum += int(span.sum())
            span_rows += int(span.shape[0])
            latency += res.latency_s
            answers[dq.name] = res.answers
        cut = partition_quality(graph, sess.pg.assignment, sess.k)["cut"]
        return WawPhase(
            scheme=sess.scheme, stats=stats,
            mean_loads=float(np.mean([s.n_loads for s in stats])),
            mean_span=(span_sum / span_rows) if span_rows else 0.0,
            edge_cut=cut, latency_s=latency,
            n_answers=sum(s.n_answers for s in stats)), answers

    # warm-up submit before each timed phase so the latency column compares
    # layouts, not first-touch XLA compile/dispatch cost (the engine is
    # rebuilt by repartition(), so each phase has its own fresh compile);
    # the extra query only scales the profile's hot counts uniformly
    sess.submit(mix[0])
    base_phase, base_answers = phase()
    info = sess.repartition()          # consumes the session's own profile
    sess.submit(mix[0])
    waw_phase, waw_answers = phase()

    identical = all(
        np.array_equal(base_answers[n], waw_answers[n]) for n in base_answers)
    oracle_ok = True
    for dq in mix:
        ref = match_disjunctive(graph, dq,
                                q_pad=base_answers[dq.name].shape[1])
        oracle_ok &= np.array_equal(base_answers[dq.name], ref)
        oracle_ok &= np.array_equal(waw_answers[dq.name], ref)
    return WawSweepResult(baseline=base_phase, waw=waw_phase,
                          answers_identical=identical,
                          oracle_match=bool(oracle_ok),
                          repartition_info=info,
                          wall_s=time.time() - t0)


@dataclasses.dataclass
class SharedPhase:
    """One (batch size, serving mode) cell of the shared-vs-isolated
    throughput comparison."""

    mode: str              # "isolated" | "shared"
    batch: int             # #queries served together
    n_loads: int           # engine-level partition loads (workload level
                           # for shared: one batched load counts once)
    cold_loads: int        # store transfers paid on the critical path
    warm_loads: int
    loads_per_query: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    qps: float             # queries per second over the phase wall clock
    wall_s: float
    n_answers: int


@dataclasses.dataclass
class SharedSweepResult:
    """Isolated vs shared serving of the same overlapping query batches."""

    phases: List[SharedPhase]      # two per batch size: isolated, shared
    answers_identical: bool        # per-query answers equal across modes
    oracle_match: bool             # both modes match the whole-graph oracle
    wall_s: float

    def phase(self, batch: int, mode: str) -> SharedPhase:
        return next(p for p in self.phases
                    if p.batch == batch and p.mode == mode)


def _pct(vals: List[float], q: float) -> float:
    """Latency percentile in [0, 1] (0.0 for an empty sample)."""
    return float(np.percentile(vals, q * 100)) if vals else 0.0


def run_shared_sweep(batch_sizes: Sequence[int] = (2, 4, 8),
                     scheme: str = "kway_shem", k: int = K_PARTITIONS,
                     seed: int = 0, cap: int = 32768,
                     heuristic: str = MAX_YIELD_SHARED) -> SharedSweepResult:
    """The QueryScheduler's throughput claim, measured: serve batches of
    overlapping queries (the skewed WawPart workload: B-1 hot template
    queries + 1 cold control) in two modes —

      isolated — one query at a time with the store cleared before each,
                 the no-residency-sharing baseline (every partition a
                 query touches is a cold transfer, as if each query ran in
                 its own session);
      shared   — the whole batch through ``GraphSession.submit_many``:
                 workload-level load ordering, one batched evaluation per
                 load, budgets/retirement per query.

    Reports loads-per-query, cold/warm split, latency percentiles, and
    queries/sec per (batch, mode), and verifies per-query answers are
    identical across modes and match the whole-graph oracle.  Each mode is
    warmed up (compile + first-touch) before its timed phase so the table
    compares serving, not XLA tracing."""
    t0 = time.time()
    graph = waw_skewed_graph(seed=seed)
    phases: List[SharedPhase] = []
    identical = True
    oracle_ok = True
    for B in batch_sizes:
        assert B >= 2, "need at least 2 queries to share anything"
        mix = waw_skewed_queries(hot_repeats=B - 1)  # B-1 hot + 1 cold
        assert len(mix) == B
        refs = {dq.name: match_disjunctive(graph, dq, q_pad=8) for dq in mix}

        # -- isolated: store cleared before every query ---------------------
        sess = GraphSession(graph, k=k, scheme=scheme, engine="opat",
                            config=EngineConfig(cap=cap), seed=seed)
        # warm-up compile for BOTH plan shapes in the mix (the jit cache
        # keys on the plan geometry: all HOT queries share one trace, the
        # COLD control has its own)
        sess.submit(mix[0])
        sess.submit(mix[-1])
        lat: List[float] = []
        iso_answers: Dict[str, np.ndarray] = {}
        stats0 = sess.load_stats.copy()
        n_loads = 0
        wall0 = time.time()
        for dq in mix:
            sess.store.clear()                   # no residency sharing
            res = sess.submit(dq)
            lat.append(res.latency_s)
            n_loads += res.n_loads
            iso_answers[dq.name] = res.answers
        wall = time.time() - wall0
        delta = sess.load_stats - stats0
        lat.sort()
        phases.append(SharedPhase(
            mode="isolated", batch=B, n_loads=n_loads,
            cold_loads=delta.cold_loads, warm_loads=delta.warm_loads,
            loads_per_query=n_loads / B,
            p50_ms=_pct(lat, 0.5) * 1000, p95_ms=_pct(lat, 0.95) * 1000,
            p99_ms=_pct(lat, 0.99) * 1000,
            qps=B / wall if wall else 0.0, wall_s=wall,
            n_answers=sum(a.shape[0] for a in iso_answers.values())))

        # -- shared: the whole batch through the scheduler ------------------
        sess = GraphSession(graph, k=k, scheme=scheme, engine="opat",
                            config=EngineConfig(cap=cap), seed=seed)
        sess.submit_many(mix, heuristic=heuristic)   # warm-up (all buckets)
        sess.store.clear()
        report = sess.submit_many(mix, heuristic=heuristic)
        lat = sorted(r.latency_s for r in report.results)
        sh_answers = {r.name: r.answers for r in report.results}
        phases.append(SharedPhase(
            mode="shared", batch=B, n_loads=report.n_loads,
            cold_loads=report.load_stats.cold_loads,
            warm_loads=report.load_stats.warm_loads,
            loads_per_query=report.loads_per_query,
            p50_ms=_pct(lat, 0.5) * 1000, p95_ms=_pct(lat, 0.95) * 1000,
            p99_ms=_pct(lat, 0.99) * 1000,
            qps=B / report.wall_s if report.wall_s else 0.0,
            wall_s=report.wall_s,
            n_answers=sum(a.shape[0] for a in sh_answers.values())))

        for dq in mix:
            identical &= np.array_equal(iso_answers[dq.name],
                                        sh_answers[dq.name])
            oracle_ok &= np.array_equal(iso_answers[dq.name], refs[dq.name])
            oracle_ok &= np.array_equal(sh_answers[dq.name], refs[dq.name])
    return SharedSweepResult(phases=phases, answers_identical=identical,
                             oracle_match=bool(oracle_ok),
                             wall_s=time.time() - t0)


@dataclasses.dataclass
class OocorePhase:
    """One serving mode of the out-of-core comparison: the same query mix
    against in-RAM partitions vs disk-resident shards behind the
    three-tier cache."""

    mode: str                  # "in-ram" | "out-of-core"
    disk_reads: int            # shard reads against the disk tier
    read_ahead_issued: int     # background-thread reads started
    read_ahead_hits: int       # host gets served by a read-ahead
    cold_loads: int            # device transfers on the critical path
    warm_loads: int
    bytes_disk: int
    p50_ms: float
    p95_ms: float
    p99_ms: float
    wall_s: float
    n_answers: int


@dataclasses.dataclass
class OocoreSweepResult:
    """In-RAM vs out-of-core serving of an identical query mix, on a graph
    whose total shard bytes exceed the configured host-cache budget."""

    phases: List[OocorePhase]          # [in-ram, out-of-core]
    answers_identical: bool            # per-query answers equal across modes
    oracle_match: bool                 # both modes match the oracle
    total_part_bytes: int              # shard bytes on disk
    host_cache_parts: int
    host_cap_bytes: int                # host budget in bytes (cap x shard)
    k: int
    wall_s: float

    def phase(self, mode: str) -> OocorePhase:
        return next(p for p in self.phases if p.mode == mode)


def run_oocore_sweep(k: int = K_PARTITIONS, scheme: str = "kway_shem",
                     host_cache_parts: int = 2, cache_parts: int = 2,
                     repeats: int = 2, seed: int = 0, cap: int = 32768,
                     n_nodes: int = 600, n_edges: int = 1800,
                     n_embed: int = 20,
                     graph_dir: Optional[str] = None) -> OocoreSweepResult:
    """The out-of-core acceptance run: serve a query mix on an in-RAM
    session, ``save`` the partitioned graph, reopen it with a host cache
    strictly smaller than the total shard bytes (``host_cache_parts`` of
    ``k`` uniformly padded shards), and serve the SAME mix out of core.
    Both the device and host tiers are bounded so the mix keeps paying
    real disk traffic, the background read-ahead overlaps it, and the
    table reports disk reads, read-ahead hit rate, and p50/p95 latency
    against the all-in-RAM baseline — with per-query answers verified
    identical across modes and against the whole-graph oracle."""
    t0 = time.time()
    graph = subgen_like_graph(n_nodes=n_nodes, n_edges=n_edges,
                              n_embed=n_embed, seed=seed)
    mix = subgen_queries(graph) * repeats
    refs = {dq.name: match_disjunctive(graph, dq, q_pad=8) for dq in mix}

    def phase(sess, mode: str) -> Tuple[OocorePhase, Dict[str, np.ndarray]]:
        sess.submit(mix[0])                 # compile + first-touch warm-up
        stats0 = sess.load_stats.copy()
        lat: List[float] = []
        answers: Dict[str, np.ndarray] = {}
        wall0 = time.time()
        for dq in mix:
            res = sess.submit(dq)
            lat.append(res.latency_s)
            answers[dq.name] = res.answers
        wall = time.time() - wall0
        d = sess.load_stats - stats0
        lat.sort()
        return OocorePhase(
            mode=mode, disk_reads=d.disk_reads,
            read_ahead_issued=d.read_ahead_issued,
            read_ahead_hits=d.read_ahead_hits,
            cold_loads=d.cold_loads, warm_loads=d.warm_loads,
            bytes_disk=d.bytes_disk,
            p50_ms=_pct(lat, 0.5) * 1000, p95_ms=_pct(lat, 0.95) * 1000,
            p99_ms=_pct(lat, 0.99) * 1000,
            wall_s=wall,
            n_answers=sum(a.shape[0] for a in answers.values())), answers

    ram_sess = GraphSession(graph, k=k, scheme=scheme, engine="opat",
                            config=EngineConfig(cap=cap),
                            cache_parts=cache_parts, seed=seed)
    ram_phase, ram_answers = phase(ram_sess, "in-ram")

    tmp = None
    if graph_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="oocore-bench-")
        graph_dir = tmp.name
    try:
        manifest = ram_sess.save(graph_dir)
        total_bytes = sum(p["nbytes"] for p in manifest["partitions"])
        cap_bytes = host_cache_parts * max(p["nbytes"]
                                           for p in manifest["partitions"])
        assert total_bytes > cap_bytes, \
            "out-of-core sweep needs total shard bytes above the host cap"
        ooc_sess = GraphSession.open(graph_dir, engine="opat",
                                     config=EngineConfig(cap=cap),
                                     cache_parts=cache_parts,
                                     host_cache_parts=host_cache_parts,
                                     seed=seed)
        ooc_phase, ooc_answers = phase(ooc_sess, "out-of-core")
    finally:
        if tmp is not None:
            tmp.cleanup()

    identical = all(np.array_equal(ram_answers[n], ooc_answers[n])
                    for n in ram_answers)
    oracle_ok = all(np.array_equal(ram_answers[dq.name], refs[dq.name])
                    and np.array_equal(ooc_answers[dq.name], refs[dq.name])
                    for dq in mix)
    return OocoreSweepResult(
        phases=[ram_phase, ooc_phase], answers_identical=identical,
        oracle_match=bool(oracle_ok), total_part_bytes=total_bytes,
        host_cache_parts=host_cache_parts, host_cap_bytes=cap_bytes, k=k,
        wall_s=time.time() - t0)


def fmt_table(rows: List[List[str]], header: List[str]) -> str:
    widths = [max(len(str(r[i])) for r in [header] + rows)
              for i in range(len(header))]
    def line(r):
        return "  ".join(str(c).ljust(w) for c, w in zip(r, widths))
    sep = "  ".join("-" * w for w in widths)
    return "\n".join([line(header), sep] + [line(r) for r in rows])
