"""Shared benchmark harness: run (dataset x scheme x heuristic x query)
sweeps and collect the paper's RunStats.

Each (workload, scheme) pair opens one ``GraphSession`` (core/session.py)
and serves every query/heuristic through it — the paper's serving shape:
one engine compile, partitions staged into the session's ``PartitionStore``
once (cold) and reused across the batch (warm), with per-run RunStats
carrying the scheme name and the cold/warm split.

Scales: ``--paper-scale`` regenerates the paper's sizes (IMDB 1750K/5100K,
synthetic 400K/1200K); default sizes finish on a laptop CPU in minutes and
preserve every structural property the heuristics depend on (unique IMDB
labels, embedded template instances that span partitions).
"""
from __future__ import annotations

import dataclasses
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

sys.path.insert(0, "src")

from repro.core import (ALL_HEURISTICS, BUDGET_HEURISTICS, EngineConfig,
                        GraphSession, MAX_SN, MAX_YIELD, MIN_SN, RANDOM_SN,
                        RunStats, SCHEMES, avg_load_ratio_across_schemes,
                        avg_load_ratio_for_batch, build_catalog,
                        total_connected_components)
from repro.data.generators import (imdb_like_graph, imdb_queries,
                                   subgen_like_graph, subgen_queries)

K_PARTITIONS = 4   # the paper's experimental setting


@dataclasses.dataclass
class Workload:
    name: str
    graph: object
    dqueries: list


def build_workloads(scale: float = 1.0, seed: int = 0) -> List[Workload]:
    imdb = imdb_like_graph(n_movies=int(300 * scale),
                           n_people=int(400 * scale),
                           n_companies=max(4, int(40 * scale)), seed=seed)
    synth = subgen_like_graph(n_nodes=int(2000 * scale),
                              n_edges=int(6000 * scale),
                              n_embed=max(10, int(50 * scale)), seed=seed)
    return [Workload("IMDB", imdb, imdb_queries(imdb, seed=seed)),
            Workload("Synthetic", synth, subgen_queries(synth))]


@dataclasses.dataclass
class SweepResult:
    stats: List[RunStats]
    total_cc: Dict[Tuple[str, str], int]     # (workload, scheme) -> total CC
    wall_s: float


def aggregate_disjuncts(per_disjunct: Sequence[RunStats], query: str,
                        scheme: str, heuristic: str, **extra) -> RunStats:
    """Fold the per-disjunct RunStats of one DisjunctiveQuery into the
    single record the tables consume (shared by every sweep so the
    aggregation convention cannot diverge between them)."""
    loads: List[int] = []
    l_ideal = 0
    n_answers = 0
    iters = 0
    for s in per_disjunct:
        loads += s.loads
        l_ideal = max(l_ideal, s.l_ideal)
        n_answers += s.n_answers
        iters += s.iterations

    def _fold(field):  # sum the store counters when every disjunct has them
        vals = [getattr(s, field) for s in per_disjunct]
        return sum(vals) if all(v is not None for v in vals) else None

    return RunStats(query=query, scheme=scheme, heuristic=heuristic,
                    loads=loads, l_ideal=l_ideal, n_answers=n_answers,
                    iterations=iters, cold_loads=_fold("cold_loads"),
                    warm_loads=_fold("warm_loads"),
                    prefetch_hits=_fold("prefetch_hits"), **extra)


def run_sweep(workloads: Sequence[Workload],
              schemes: Sequence[str] = tuple(sorted(SCHEMES)),
              heuristics: Sequence[str] = ALL_HEURISTICS,
              seed: int = 0, cap: int = 32768,
              k: int = K_PARTITIONS) -> SweepResult:
    t0 = time.time()
    stats: List[RunStats] = []
    total_cc: Dict[Tuple[str, str], int] = {}
    for wl in workloads:
        catalog = build_catalog(wl.graph)
        for scheme in schemes:
            sess = GraphSession(wl.graph, k=k, scheme=scheme, engine="opat",
                                config=EngineConfig(cap=cap), seed=seed,
                                catalog=catalog)
            total_cc[(wl.name, scheme)] = total_connected_components(sess.pg)
            for dq in wl.dqueries:
                for heuristic in heuristics:
                    res = sess.submit(dq, heuristic=heuristic)
                    stats.append(aggregate_disjuncts(
                        res.stats, f"{wl.name}:{dq.name}", scheme,
                        heuristic))
    return SweepResult(stats=stats, total_cc=total_cc,
                       wall_s=time.time() - t0)


BUDGET_KS = (1, 10, 100, None)   # None = exhaustive ("K = inf")


@dataclasses.dataclass
class BudgetSweepResult:
    """OPAT answer-budget runs: the response-time-vs-K raw data."""

    stats: List[RunStats]     # answers_requested / loads_saved_vs_full set
    wall_s: float


def run_budget_sweep(workloads: Sequence[Workload],
                     scheme: str = "kway_shem",
                     heuristics: Sequence[str] = BUDGET_HEURISTICS,
                     ks: Sequence[Optional[int]] = BUDGET_KS,
                     seed: int = 0, cap: int = 32768,
                     k_partitions: int = K_PARTITIONS) -> BudgetSweepResult:
    """Run every query at each answer budget K through one warm
    ``GraphSession`` and record how many partition loads the budget saved
    vs the exhaustive run (the paper's "specified number of answers" mode,
    Sec. 1/5)."""
    t0 = time.time()
    stats: List[RunStats] = []
    for wl in workloads:
        sess = GraphSession(wl.graph, k=k_partitions, scheme=scheme,
                            engine="opat", config=EngineConfig(cap=cap),
                            seed=seed)
        for dq in wl.dqueries:
            for heuristic in heuristics:
                # exhaustive baseline per (query, heuristic); each disjunct's
                # stats are reused verbatim whenever the budget cannot bind
                # on it: K=None, or K strictly above its total answer count
                # (at K == total the budgeted run may stop earlier than
                # exhaustion, so it must execute for real — and a re-run
                # would repeat the same deterministic load sequence anyway,
                # contributing 0 to `saved`)
                full = sess.submit(dq, heuristic=heuristic)
                for kk in ks:
                    per_disjunct = []
                    for q, fstat in zip(dq.disjuncts, full.stats):
                        if kk is None or fstat.n_answers < kk:
                            per_disjunct.append(fstat)
                        else:
                            per_disjunct.append(sess.submit(
                                q, max_answers=kk,
                                heuristic=heuristic).stats[0])
                    saved = sum(f.n_loads - r.n_loads
                                for f, r in zip(full.stats, per_disjunct))
                    stats.append(aggregate_disjuncts(
                        per_disjunct, f"{wl.name}:{dq.name}", scheme,
                        heuristic, answers_requested=kk,
                        loads_saved_vs_full=saved))
    return BudgetSweepResult(stats=stats, wall_s=time.time() - t0)


def fmt_table(rows: List[List[str]], header: List[str]) -> str:
    widths = [max(len(str(r[i])) for r in [header] + rows)
              for i in range(len(header))]
    def line(r):
        return "  ".join(str(c).ljust(w) for c, w in zip(r, widths))
    sep = "  ".join("-" * w for w in widths)
    return "\n".join([line(header), sep] + [line(r) for r in rows])
