"""Shared benchmark harness: run (dataset x scheme x heuristic x query)
sweeps through OPAT and collect the paper's RunStats.

Scales: ``--paper-scale`` regenerates the paper's sizes (IMDB 1750K/5100K,
synthetic 400K/1200K); default sizes finish on a laptop CPU in minutes and
preserve every structural property the heuristics depend on (unique IMDB
labels, embedded template instances that span partitions).
"""
from __future__ import annotations

import dataclasses
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

sys.path.insert(0, "src")

from repro.core import (ALL_HEURISTICS, EngineConfig, MAX_SN, MIN_SN,
                        RANDOM_SN, OPATEngine, RunStats, SCHEMES,
                        avg_load_ratio_across_schemes,
                        avg_load_ratio_for_batch, build_catalog,
                        build_partitions, generate_plan, partition_graph,
                        total_connected_components)
from repro.data.generators import (imdb_like_graph, imdb_queries,
                                   subgen_like_graph, subgen_queries)

K_PARTITIONS = 4   # the paper's experimental setting


@dataclasses.dataclass
class Workload:
    name: str
    graph: object
    dqueries: list


def build_workloads(scale: float = 1.0, seed: int = 0) -> List[Workload]:
    imdb = imdb_like_graph(n_movies=int(300 * scale),
                           n_people=int(400 * scale),
                           n_companies=max(4, int(40 * scale)), seed=seed)
    synth = subgen_like_graph(n_nodes=int(2000 * scale),
                              n_edges=int(6000 * scale),
                              n_embed=max(10, int(50 * scale)), seed=seed)
    return [Workload("IMDB", imdb, imdb_queries(imdb, seed=seed)),
            Workload("Synthetic", synth, subgen_queries(synth))]


@dataclasses.dataclass
class SweepResult:
    stats: List[RunStats]
    total_cc: Dict[Tuple[str, str], int]     # (workload, scheme) -> total CC
    wall_s: float


def run_sweep(workloads: Sequence[Workload],
              schemes: Sequence[str] = tuple(sorted(SCHEMES)),
              heuristics: Sequence[str] = ALL_HEURISTICS,
              seed: int = 0, cap: int = 32768,
              k: int = K_PARTITIONS) -> SweepResult:
    t0 = time.time()
    stats: List[RunStats] = []
    total_cc: Dict[Tuple[str, str], int] = {}
    for wl in workloads:
        catalog = build_catalog(wl.graph)
        for scheme in schemes:
            assign = partition_graph(wl.graph, k, scheme, seed=seed)
            pg = build_partitions(wl.graph, assign, k)
            total_cc[(wl.name, scheme)] = total_connected_components(pg)
            eng = OPATEngine(pg, EngineConfig(cap=cap))
            for dq in wl.dqueries:
                for heuristic in heuristics:
                    loads: List[int] = []
                    l_ideal = 0
                    n_answers = 0
                    iters = 0
                    for q in dq.disjuncts:
                        plan = generate_plan(q, wl.graph, catalog)
                        res = eng.run(plan, heuristic, seed=seed)
                        loads += res.stats.loads
                        l_ideal = max(l_ideal, res.stats.l_ideal)
                        n_answers += res.stats.n_answers
                        iters += res.stats.iterations
                    stats.append(RunStats(
                        query=f"{wl.name}:{dq.name}", scheme=scheme,
                        heuristic=heuristic, loads=loads, l_ideal=l_ideal,
                        n_answers=n_answers, iterations=iters))
    return SweepResult(stats=stats, total_cc=total_cc,
                       wall_s=time.time() - t0)


def fmt_table(rows: List[List[str]], header: List[str]) -> str:
    widths = [max(len(str(r[i])) for r in [header] + rows)
              for i in range(len(header))]
    def line(r):
        return "  ".join(str(c).ljust(w) for c, w in zip(r, widths))
    sep = "  ".join("-" * w for w in widths)
    return "\n".join([line(header), sep] + [line(r) for r in rows])
