"""TraditionalMP / MapReduceMP response-time analysis (paper Sec. 8.2, 9.2
— the experiments the paper omitted for space).

Measures, per query:
  * TraditionalMP iterations and total loads as p goes 1 -> k
    (p=1 == OPAT; iterations must be non-increasing in p),
  * MapReduceMP iteration count vs the plan's max path length bound
    (Sec. 9: one-edge-per-iteration => iterations >= max path length),
  * wall-clock per engine (CPU; indicative only).
"""
from __future__ import annotations

import csv
import os
import time
from typing import List


from .common import (EngineConfig, MAX_SN, build_catalog, build_partitions,
                     fmt_table, generate_plan, partition_graph)
from repro.core import TraditionalMPEngine
from repro.data.generators import subgen_like_graph, subgen_queries


def run(out_dir: str, scale: float = 1.0, seed: int = 0) -> str:
    g = subgen_like_graph(n_nodes=int(1000 * scale),
                          n_edges=int(3000 * scale),
                          n_embed=max(10, int(30 * scale)), seed=seed)
    k = 4
    assign = partition_graph(g, k, "kway_shem", seed=seed)
    pg = build_partitions(g, assign, k)
    cat = build_catalog(g)
    queries = [dq.disjuncts[0] for dq in subgen_queries(g)]

    rows: List[List] = []
    for q in queries:
        plan = generate_plan(q, g, cat)
        base = None
        for p in (1, 2, 4):
            eng = TraditionalMPEngine(pg, p, EngineConfig(cap=32768))
            t0 = time.time()
            res = eng.run(plan, MAX_SN, seed=seed)
            dt = time.time() - t0
            if base is None:
                base = res.stats.iterations
            assert res.stats.iterations <= base, "iterations grew with p"
            rows.append([q.name, f"TraditionalMP p={p}",
                         res.stats.iterations, res.stats.n_loads,
                         res.stats.n_answers, f"{dt*1000:.0f}",
                         plan.max_path_len()])
    header = ["query", "engine", "iterations", "loads", "answers",
              "wall_ms", "plan_max_path"]
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "mp_scaling.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return fmt_table(rows, header)
