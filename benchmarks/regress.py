"""Continuous perf-regression detection over bench_trajectory.json.

    PYTHONPATH=src python -m benchmarks.regress \
        --trajectory bench_trajectory.json --check-regression

track.py's gate compares one run against the single last committed
BENCH_*.json — good at catching a cliff, blind to slow drift and jumpy
on a noisy runner.  This module reads the whole trajectory instead and
asks, per tracked metric: is the newest point worse than an EWMA
baseline of its history by more than a noise-aware band?

  baseline  EWMA of every usable point before the newest (alpha 0.3:
            recent runs dominate, old points still anchor), so a
            months-long 3%/week drift eventually exits the band even
            though no single step ever trips a pairwise gate.
  band      max(z * sigma, rel_tol * |baseline|, abs_floor) where sigma
            prefers the *measured* across-trial stddev recorded by
            ``track.py --trials`` and falls back to the history's sample
            stddev.  The relative and absolute floors keep one-trial
            trajectories on shared CI runners from gating on jitter.
  verdict   a metric regresses only in its bad direction (p95 up, qps
            down, loads-per-query up, disk reads up, kernel speedup
            down); fewer than 2 usable points passes with a note —
            a new metric must accrue history before it can gate.

``kernel_speedup`` points are usable only off-CPU (interpret-mode Pallas
on CPU measures the interpreter, not the kernel; track.py records None
there) — so CPU-only CI never gates on it.

Exit status: 0 unless ``--check-regression`` is set and at least one
metric regressed.  Everything is importable (``ewma``, ``detect``) for
the unit tests in tests/test_profiling.py.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from typing import Any, Dict, List, Optional, Tuple

# newest-vs-baseline must exceed this relative band ...
REL_TOL = 0.20
# ... and z standard deviations of measured/ historical noise ...
Z_SCORE = 3.0
# ... and the metric's absolute floor (units of the metric itself)
EWMA_ALPHA = 0.3

# metric -> (bad direction, absolute noise floor, recorded-stddev key)
METRICS: Dict[str, Dict[str, Any]] = {
    "shared_b8_p95_ms": {
        "worse": "higher", "abs_floor": 75.0,
        "std_key": "shared_b8_p95_ms_std"},
    "shared_b8_qps": {
        "worse": "lower", "abs_floor": 0.5,
        "std_key": "shared_b8_qps_std"},
    "shared_b8_loads_per_query": {
        "worse": "higher", "abs_floor": 0.05, "std_key": None},
    "oocore_disk_reads": {
        "worse": "higher", "abs_floor": 1.0, "std_key": None},
    "kernel_speedup": {
        "worse": "lower", "abs_floor": 0.05, "std_key": None},
}


def ewma(values: List[float], alpha: float = EWMA_ALPHA) -> float:
    """Exponentially weighted moving average, oldest first."""
    if not values:
        raise ValueError("ewma of an empty series")
    m = float(values[0])
    for v in values[1:]:
        m = alpha * float(v) + (1.0 - alpha) * m
    return m


def _usable(traj: List[Dict], metric: str) -> List[Tuple[Dict, float]]:
    """(point, value) pairs carrying this metric, trajectory order."""
    out = []
    for pt in traj:
        v = pt.get(metric)
        if v is None:
            continue
        if metric == "kernel_speedup" and pt.get("kernel_backend") == "cpu":
            continue   # belt and braces: track.py already records None
        out.append((pt, float(v)))
    return out


def detect(traj: List[Dict], *, rel_tol: float = REL_TOL,
           z: float = Z_SCORE, alpha: float = EWMA_ALPHA) -> List[Dict]:
    """One finding per tracked metric over a trajectory (oldest first):
    ``{"metric", "status" ("ok"|"regression"|"skipped"), "value",
    "baseline", "band", "note"}``."""
    traj = sorted(traj, key=lambda p: str(p.get("utc_date", "")))
    findings: List[Dict] = []
    for metric, spec in METRICS.items():
        pts = _usable(traj, metric)
        if len(pts) < 2:
            findings.append({
                "metric": metric, "status": "skipped", "value": None,
                "baseline": None, "band": None,
                "note": f"{len(pts)} usable point(s); need 2 to gate"})
            continue
        hist = [v for _, v in pts[:-1]]
        cur_pt, cur = pts[-1]
        base = ewma(hist, alpha)
        # noise estimate: measured across-trial stddev when any point
        # recorded one (multi-trial runs), else the history's own spread
        std_key = spec["std_key"]
        measured = [float(pt[std_key]) for pt, _ in pts
                    if std_key and pt.get(std_key) is not None]
        if measured:
            sigma = max(measured)
        elif len(hist) >= 2:
            sigma = statistics.stdev(hist)
        else:
            sigma = 0.0
        band = max(z * sigma, rel_tol * abs(base), spec["abs_floor"])
        if spec["worse"] == "higher":
            regressed = cur > base + band
        else:
            regressed = cur < base - band
        findings.append({
            "metric": metric,
            "status": "regression" if regressed else "ok",
            "value": cur, "baseline": round(base, 4),
            "band": round(band, 4),
            "note": f"{len(pts)} points through {cur_pt.get('utc_date')}"
                    + (f"; sigma={sigma:.4g}"
                       + (" (measured)" if measured else " (history)")
                       if sigma else "")})
    return findings


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trajectory", default="bench_trajectory.json",
                    help="track.py's per-run summary series")
    ap.add_argument("--check-regression", action="store_true",
                    help="CI gate: exit non-zero when any tracked metric "
                         "drifts out of its EWMA noise band")
    ap.add_argument("--rel-tol", type=float, default=REL_TOL)
    ap.add_argument("--z", type=float, default=Z_SCORE)
    ap.add_argument("--alpha", type=float, default=EWMA_ALPHA)
    args = ap.parse_args(argv)

    try:
        with open(args.trajectory) as f:
            traj = json.load(f)
    except FileNotFoundError:
        print(f"regress: no trajectory at {args.trajectory}; nothing to "
              f"gate (run benchmarks.track first)")
        return 0
    if not isinstance(traj, list):
        print(f"regress: {args.trajectory} is not a JSON list",
              file=sys.stderr)
        return 2

    findings = detect(traj, rel_tol=args.rel_tol, z=args.z,
                      alpha=args.alpha)
    print(f"== trajectory regression check ({len(traj)} points, "
          f"{args.trajectory}) ==")
    for f_ in findings:
        mark = {"ok": "PASS", "regression": "FAIL",
                "skipped": "skip"}[f_["status"]]
        detail = (f"value={f_['value']} baseline={f_['baseline']} "
                  f"band=+/-{f_['band']}  " if f_["value"] is not None
                  else "")
        print(f"  [{mark}] {f_['metric']:<28} {detail}({f_['note']})")
    regressions = [f_ for f_ in findings if f_["status"] == "regression"]
    if regressions and args.check_regression:
        print(f"regress: {len(regressions)} metric(s) outside the EWMA "
              f"noise band", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
