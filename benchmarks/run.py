"""Benchmark driver: one section per paper table/figure + the roofline
report.

    PYTHONPATH=src python -m benchmarks.run [--scale 1.0] [--skip-sweep]

Writes CSVs to results/bench/ and prints the tables.  The OPAT sweep
(2 datasets x 6 schemes x 3 queries x 3 heuristics = 108 runs) takes a few
minutes at the default scale; --paper-scale regenerates paper-sized inputs
(hours — sized for a cluster, not this container).
"""
from __future__ import annotations

import argparse
import sys

sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--paper-scale", action="store_true",
                    help="IMDB 1750K/5100K, synthetic 400K/1200K")
    ap.add_argument("--out", default="results/bench")
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--skip-sweep", action="store_true",
                    help="only print the roofline report")
    ap.add_argument("--shared-smoke", action="store_true",
                    help="only run the shared-vs-isolated scheduler sweep "
                         "(small batches; the CI throughput smoke)")
    ap.add_argument("--oocore-smoke", action="store_true",
                    help="only run the out-of-core sweep (save -> reopen "
                         "with a host cache below the graph's shard bytes;"
                         " the CI disk-tier smoke, gated on oracle match "
                         "and real disk/read-ahead traffic)")
    ap.add_argument("--track", action="store_true",
                    help="emit a BENCH_<utc-date>.json trajectory point "
                         "(smoke-size sweeps + kernel timing) and gate "
                         "against the last committed one — see track.py")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.track:
        from . import track
        track.main(["--seed", str(args.seed),
                    "--dryrun-dir", args.dryrun_dir])
        return

    from . import mp_scaling, paper_tables, roofline
    from .common import (build_workloads, run_budget_sweep, run_oocore_sweep,
                         run_shared_sweep, run_sweep, run_waw_sweep)

    if args.shared_smoke:
        print("== Shared-load scheduling (QueryScheduler, isolated vs "
              "shared) ==", flush=True)
        shared = run_shared_sweep(batch_sizes=(2, 8), seed=args.seed)
        print(f"   {len(shared.phases)} phases in {shared.wall_s:.1f}s")
        print(paper_tables.table_shared(shared, args.out))
        if not (shared.answers_identical and shared.oracle_match):
            sys.exit("shared-smoke: answer sets differ across modes or "
                     "mismatch the oracle")   # a real CI gate, like serve
        return

    if args.oocore_smoke:
        print("== Out-of-core serving (disk -> host LRU -> device LRU) ==",
              flush=True)
        oocore = run_oocore_sweep(seed=args.seed)
        print(f"   2 phases in {oocore.wall_s:.1f}s")
        print(paper_tables.table_oocore(oocore, args.out))
        ooc = oocore.phase("out-of-core")
        if not (oocore.answers_identical and oocore.oracle_match):
            sys.exit("oocore-smoke: answer sets differ across modes or "
                     "mismatch the oracle")   # a real CI gate, like serve
        if ooc.disk_reads <= 0 or ooc.read_ahead_hits <= 0:
            sys.exit("oocore-smoke: the out-of-core phase paid no disk "
                     f"reads ({ooc.disk_reads}) or no read-ahead hits "
                     f"({ooc.read_ahead_hits}) — the tier was not "
                     "exercised")
        return

    if not args.skip_sweep:
        scale = 600.0 if args.paper_scale else args.scale
        print(f"== building workloads (scale={scale}) ==", flush=True)
        workloads = build_workloads(scale=scale, seed=args.seed)
        for wl in workloads:
            print(f"   {wl.name}: {wl.graph.n_nodes} nodes, "
                  f"{wl.graph.n_edges} edges")
        print("== OPAT sweep (6 schemes x 3 heuristics x query batch) ==",
              flush=True)
        sweep = run_sweep(workloads, seed=args.seed)
        print(f"   {len(sweep.stats)} runs in {sweep.wall_s:.1f}s\n")

        print("== Table 3: h(D)^query_pschemes (mean load ratio across "
              "schemes) ==")
        print(paper_tables.table3(sweep, args.out), "\n")
        print("== Table 4: h(D)^pscheme_qbatch (mean load ratio per scheme) ==")
        print(paper_tables.table4(sweep, args.out), "\n")
        print("== Table 5: connected-components heuristic ==")
        print(paper_tables.table5(sweep, args.out), "\n")
        print("== Figures 7-10 (loads per query/scheme/heuristic) ==")
        print(paper_tables.figs_loads(sweep, args.out), "\n")

        failures = paper_tables.validate_claims(sweep)
        if failures:
            print("!! paper-claim validation FAILURES:")
            for f in failures:
                print("   -", f)
        else:
            print("paper-claim validation: all qualitative claims hold "
                  "(MAX-SN >= MIN-SN >= RANDOM; IMDB MAX==MIN; MIN-CC >= "
                  "MAX-CC)\n")

        print("== Response time vs K (answer budget, OPAT runner API) ==")
        budget = run_budget_sweep(workloads, seed=args.seed)
        print(f"   {len(budget.stats)} budget runs in {budget.wall_s:.1f}s")
        print(paper_tables.table_k_budget(budget, args.out), "\n")

        print("== Workload-aware repartitioning (WawPart loop, "
              "baseline vs waw) ==")
        waw = run_waw_sweep(seed=args.seed)
        print(f"   2 phases x {len(waw.baseline.stats)} queries in "
              f"{waw.wall_s:.1f}s")
        print(paper_tables.table_waw(waw, args.out), "\n")

        print("== Shared-load scheduling (QueryScheduler, isolated vs "
              "shared) ==")
        shared = run_shared_sweep(seed=args.seed)
        print(f"   {len(shared.phases)} phases in {shared.wall_s:.1f}s")
        print(paper_tables.table_shared(shared, args.out), "\n")

        print("== Out-of-core serving (disk -> host LRU -> device LRU) ==")
        oocore = run_oocore_sweep(seed=args.seed)
        print(f"   2 phases in {oocore.wall_s:.1f}s")
        print(paper_tables.table_oocore(oocore, args.out), "\n")

        print("== TraditionalMP / MapReduceMP scaling (Sec. 8-9) ==")
        print(mp_scaling.run(args.out, scale=args.scale, seed=args.seed), "\n")

    print("== Roofline (from multi-pod dry-run artifacts) ==")
    print(roofline.report(args.dryrun_dir, args.out))
    tuned = roofline.report(args.dryrun_dir, args.out, tag="tuned")
    if not tuned.startswith("("):
        print("\n== Roofline — tuned defaults (§Perf), train cells ==")
        print(tuned)


if __name__ == "__main__":
    main()
