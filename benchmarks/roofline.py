"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun/*.json (written by launch/dryrun.py), prints the
per-(arch x shape x mesh) three-term roofline with the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs useful-compute ratio, and per-device memory.
"""
from __future__ import annotations

import csv
import glob
import json
import os
from typing import Dict, List

from .common import fmt_table

GIB = 1024 ** 3


def load_cells(dry_dir: str, tag: str = "baseline") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dry_dir, f"*__{tag}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def rows_for(cells: List[Dict]) -> List[List]:
    rows = []
    for c in cells:
        if c.get("status") == "skipped":
            rows.append([c["arch"], c["shape"], c.get("mesh", "?"),
                         "SKIP", "-", "-", "-", "-", "-", "-"])
            continue
        if c.get("status") != "ok":
            rows.append([c["arch"], c["shape"], c.get("mesh", "?"),
                         "ERROR", "-", "-", "-", "-", "-", "-"])
            continue
        r = c["roofline"]
        ratio = c.get("useful_flops_ratio")
        mem = c["info"].get("temp_size_in_bytes", 0) + \
            c["info"].get("argument_size_in_bytes", 0)
        rows.append([
            c["arch"], c["shape"], c["mesh"], r["dominant"],
            f"{r['t_compute_s']:.4g}", f"{r['t_memory_s']:.4g}",
            f"{r['t_collective_s']:.4g}",
            f"{(r['t_compute_s'] / r['t_bound_s']):.3f}" if r["t_bound_s"] else "-",
            f"{ratio:.3f}" if ratio else "-",
            f"{mem / GIB:.2f}",
        ])
    return rows


HEADER = ["arch", "shape", "mesh", "bound", "t_comp_s", "t_mem_s",
          "t_coll_s", "roofline_frac", "useful_flops", "mem_GiB/dev"]


def report(dry_dir: str = "results/dryrun", out_dir: str = "results/bench",
           tag: str = "baseline") -> str:
    cells = load_cells(dry_dir, tag)
    if not cells:
        return (f"(no dry-run artifacts under {dry_dir} with tag {tag!r}; "
                f"run: PYTHONPATH=src python -m repro.launch.dryrun)")
    rows = rows_for(cells)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"roofline_{tag}.csv"), "w",
              newline="") as f:
        w = csv.writer(f)
        w.writerow(HEADER)
        w.writerows(rows)
    return fmt_table(rows, HEADER)
