"""Out-of-core partition storage: the disk tier under ``PartitionStore``.

  format.py      — versioned graph-directory layout (manifest.json +
                   part-<pid>.npz shards, sha256 checksums), DiskCatalog,
                   OutOfCorePartitionedGraph
  host_cache.py  — the pinned-host LRU between disk and device, with
                   background-thread read-ahead

See docs/storage.md for the format and the three-tier cache semantics.
"""
from .format import (DiskCatalog, FORMAT_VERSION, OutOfCorePartitionedGraph,
                     StorageFormatError, array_checksum,
                     open_partitioned_graph, save_partitioned_graph,
                     shard_name)
from .host_cache import HostArrayTier, HostBundle, HostShardCache

__all__ = [
    "DiskCatalog", "FORMAT_VERSION", "OutOfCorePartitionedGraph",
    "StorageFormatError", "array_checksum", "open_partitioned_graph",
    "save_partitioned_graph", "shard_name",
    "HostArrayTier", "HostBundle", "HostShardCache",
]
