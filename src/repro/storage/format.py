"""On-disk partition storage: a versioned shard-per-partition layout.

The paper's founding premise is a graph too large for main memory, and
its partitioned representation is exactly the unit that makes disk
residency natural: every partition is already a fixed-geometry array
bundle (core/graph.py), so the storage layer can treat "one partition"
as "one shard file" and never needs to understand traversal semantics.
Averbuch & Neumann (arXiv:1301.5121) make the case that partitioned graph
stores live or die by their on-disk layout and cache behaviour; this
module is the layout half (the cache half is storage/host_cache.py).

A *graph directory* written by ``save_partitioned_graph`` holds:

  manifest.json     — format version, partition geometry (k, scheme,
                      node_pad / edge_pad / ell_width, cut_edges), the
                      label vocabularies, and a per-partition catalog:
                      shard file name, vertex / edge counts, connected
                      components, byte size, a core-node label histogram,
                      and a sha256 checksum per array.  Everything the
                      heuristics need to *rank* partitions (SNI counts,
                      MAX-YIELD admission) is derivable from the manifest
                      plus ``graph.npz`` — no shard needs to be resident.
  graph.npz         — the whole-graph host arrays (node labels / values,
                      edge lists, the [V] partition assignment).  O(V+E)
                      raw data; the padded, denormalized shard bundles
                      below are the memory hog this tier keeps on disk.
  part-<pid>-<key>.npz — one shard per partition: the evaluator input
                      dict (``part_to_device_dict`` arrays, ELLPACK
                      tiles included) plus that partition's g2l row.
                      Written uncompressed so a round trip is
                      bit-identical; ``<key>`` is a digest of the
                      arrays' checksums (content-addressed).

Durability: every file is written via temp + atomic rename, shard names
are content-addressed, and the manifest is written LAST.  A directory
without a manifest is simply not a graph directory, so an interrupted
first ``save`` can never be opened; an interrupted RE-save leaves the
old manifest naming the old (untouched) shard generation, so the old
layout stays fully servable — changed shards land under new names, and
superseded generations are garbage-collected only after the fresh
manifest is live.

``DiskCatalog`` opens a graph directory and serves shard reads (checksum
verified) plus the manifest-level metrics; ``OutOfCorePartitionedGraph``
is the ``PartitionedGraph`` the rest of the system sees — same fields and
methods, but ``parts`` is empty and partition bytes only ever enter
memory through the store's host/device cache tiers.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.graph import Graph, LabelVocab, PartitionedGraph, WILDCARD

FORMAT_VERSION = 1
FORMAT_KIND = "pgqp-graph-dir"
MANIFEST_NAME = "manifest.json"
GRAPH_NAME = "graph.npz"

# ---------------------------------------------------------------------------
# Fault injection (tests/fault_injection.py): every durable filesystem step
# in this module (and storage/deltas.py, which writes through the same
# helpers) announces itself here BEFORE executing.  A test installs a hook
# that raises at step N to simulate a crash at that exact point; production
# leaves it None at zero cost.  Because every final file lands via atomic
# rename, "crash before step N" enumerates every observable intermediate
# on-disk state.
# ---------------------------------------------------------------------------

fault_hook: Optional[Callable[[str, str], None]] = None


def _fault_point(step: str, path: str) -> None:
    if fault_hook is not None:
        fault_hook(step, path)


class StorageFormatError(RuntimeError):
    """A graph directory is missing, unversioned, or fails verification."""


def shard_name(pid: int, content_key: str) -> str:
    """Shard file names are CONTENT-ADDRESSED (pid + a digest of the
    arrays' checksums): a re-save with changed content writes NEW files
    while the old manifest keeps naming the old ones, so an interrupted
    re-save can never mix layouts — the old directory stays fully live
    until the fresh manifest lands, and identical content maps to the
    identical (byte-identical) file."""
    return f"part-{int(pid):05d}-{content_key}.npz"


def _content_key(checksums: Dict[str, str]) -> str:
    h = hashlib.sha256()
    for k in sorted(checksums):
        h.update(k.encode())
        h.update(checksums[k].encode())
    return h.hexdigest()[:12]


def _atomic_savez(path: str, arrs: Dict[str, np.ndarray]) -> None:
    """Write an npz via temp file + rename, so a torn write can never be
    mistaken for a shard (np.savez appends '.npz' to bare names, hence
    the explicit file handle)."""
    tmp = path + ".tmp"
    _fault_point("write", path)
    with open(tmp, "wb") as f:
        np.savez(f, **arrs)
    _fault_point("rename", path)
    os.replace(tmp, path)


def _atomic_write_text(path: str, text: str) -> None:
    """Text twin of ``_atomic_savez`` (manifests and delta logs)."""
    tmp = path + ".tmp"
    _fault_point("write", path)
    with open(tmp, "w") as f:
        f.write(text)
    _fault_point("rename", path)
    os.replace(tmp, path)


def graph_file_name(checksums: Dict[str, str]) -> str:
    """Whole-graph files are content-addressed like shards, so a re-save
    never overwrites the file the live manifest points at — the legacy
    fixed name ``graph.npz`` is still read (old directories) but never
    written by this build."""
    return f"graph-{_content_key(checksums)}.npz"


def array_checksum(a: np.ndarray) -> str:
    """sha256 over (dtype, shape, bytes) — shape/dtype are part of the
    identity so a reshaped or recast array never passes as unchanged."""
    a = np.ascontiguousarray(np.asarray(a))
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(a.tobytes())
    return h.hexdigest()


def _shard_arrays(pg: PartitionedGraph, pid: int) -> Dict[str, np.ndarray]:
    """One partition's shard content: evaluator inputs + its g2l row."""
    from ..core.engine import part_to_device_dict
    arrs = {k: np.asarray(v) for k, v in part_to_device_dict(pg.parts[pid]).items()}
    arrs["g2l"] = np.asarray(pg.g2l[pid])
    return arrs


def _pad_axis(a: np.ndarray, n: int, fill, axis: int = 0) -> np.ndarray:
    if a.shape[axis] >= n:
        return a
    shape = list(a.shape)
    shape[axis] = n - a.shape[axis]
    pad = np.full(shape, fill, dtype=a.dtype)
    return np.concatenate([a, pad], axis=axis)


def pad_bundle(arrs: Dict[str, np.ndarray], node_pad: int, ell_width: int,
               n_nodes: int) -> Dict[str, np.ndarray]:
    """Grow a shard bundle to a target geometry with semantically inert
    padding (same fill values as core/graph.build_partitions): padded
    node rows have ``node_gid == -1`` and padded ELLPACK cells have
    ``ell_dst == -1``, which every evaluator predicate already gates on.
    The g2l row extends to the target vertex count with -1 (no new gid
    is ever local to a partition it doesn't touch).  Compaction publishes
    grown geometry in the manifest without rewriting untouched shards
    (storage/deltas.py), so a shard may be stored smaller than the
    manifest geometry — this pads it back to uniform at read time."""
    out = dict(arrs)
    out["node_gid"] = _pad_axis(arrs["node_gid"], node_pad, -1)
    out["node_label"] = _pad_axis(arrs["node_label"], node_pad, -2)
    out["node_value"] = _pad_axis(arrs["node_value"], node_pad, np.nan)
    for k, fill in (("ell_dst", -1), ("ell_label", -2), ("ell_dir", 0),
                    ("ell_dlab", -2), ("ell_dval", np.nan),
                    ("ell_dgid", -1)):
        a = _pad_axis(arrs[k], ell_width, fill, axis=1)
        out[k] = _pad_axis(a, node_pad, fill, axis=0)
    out["g2l"] = _pad_axis(arrs["g2l"], n_nodes, -1)
    return out


def _label_histogram(node_label: np.ndarray) -> List[List[int]]:
    """Sparse [label_id, count] pairs over a partition's core nodes — the
    manifest-level SNI input (start-node counts per label)."""
    labels, counts = np.unique(node_label, return_counts=True)
    return [[int(l), int(c)] for l, c in zip(labels, counts) if l >= 0]


def save_partitioned_graph(pg: PartitionedGraph, path: str, *,
                           generation: Optional[int] = None,
                           applied_seq: Optional[int] = None,
                           shard_seq: Optional[List[int]] = None,
                           keep_files: Optional[set] = None
                           ) -> Dict[str, Any]:
    """Write ``pg`` as a graph directory; returns the manifest dict.

    Works for both in-RAM graphs (shards serialized from ``pg.parts``)
    and disk-opened ones (shards streamed partition-at-a-time through the
    backing catalog — never more than one partition's bytes in flight).
    The manifest is written last, so the directory only becomes openable
    once every shard it names is on disk.

    Generations: every manifest carries a monotone ``generation`` number
    (default: one past the directory's current manifest, 0 for a fresh
    directory) plus the delta-log watermark ``applied_seq`` / per-pid
    ``shard_seq`` (storage/deltas.py).  ``keep_files`` names extra
    content-addressed files the post-publish GC must leave alone (shards
    and graph files still referenced by pinned generations).
    """
    assert pg.node_pad > 0, "uniform padding required (build_partitions default)"
    os.makedirs(path, exist_ok=True)
    backing: Optional[DiskCatalog] = getattr(pg, "backing", None)
    g = pg.graph
    prev_gen = -1
    prev_seq = 0
    if os.path.exists(os.path.join(path, MANIFEST_NAME)):
        try:
            with open(os.path.join(path, MANIFEST_NAME)) as f:
                prev = json.load(f)
            prev_gen = int(prev.get("generation", 0))
            prev_seq = int(prev.get("applied_seq", 0))
        except (OSError, ValueError):
            pass
    if generation is None:
        generation = prev_gen + 1
    if applied_seq is None:
        applied_seq = prev_seq
    if shard_seq is None:
        shard_seq = [int(applied_seq)] * pg.k

    parts_meta: List[Dict[str, Any]] = []
    part_keys: Optional[List[str]] = None
    for pid in range(pg.k):
        if backing is not None:
            arrs, g2l_row = backing.read_part(pid)
            arrs = dict(arrs)
            arrs["g2l"] = g2l_row
        else:
            arrs = _shard_arrays(pg, pid)
        checksums = {k: array_checksum(v) for k, v in arrs.items()}
        fname = shard_name(pid, _content_key(checksums))
        if not os.path.exists(os.path.join(path, fname)):
            _atomic_savez(os.path.join(path, fname), arrs)
        core_mask = pg.assignment == pid
        parts_meta.append({
            "pid": pid,
            "shard": fname,
            "n_core": int(core_mask.sum()),
            "n_nodes": int(np.asarray(arrs["node_gid"] >= 0).sum()),
            "n_edges": int(np.asarray(arrs["ell_dst"] >= 0).sum()),
            "nbytes": int(sum(np.asarray(v).nbytes for v in arrs.values())),
            "components": 0,   # filled below in one pass over all partitions
            "label_histogram": _label_histogram(
                np.asarray(g.node_label)[core_mask]),
            "checksums": checksums,
        })
        if part_keys is None:
            part_keys = [k for k in arrs.keys() if k != "g2l"]
    # one pass for the per-partition CC metric (paper Sec. 5.2) instead of
    # the accidental O(k^2) of calling it inside the loop above
    ccs = pg.connected_components_per_partition()
    for meta in parts_meta:
        meta["components"] = int(ccs[meta["pid"]])

    garrs = dict(node_label=np.asarray(g.node_label),
                 node_value=np.asarray(g.node_value),
                 edge_src=np.asarray(g.edge_src),
                 edge_dst=np.asarray(g.edge_dst),
                 edge_label=np.asarray(g.edge_label),
                 edge_directed=np.asarray(g.edge_directed),
                 assignment=pg.assignment.astype(np.int32))
    graph_checksums = {k: array_checksum(v) for k, v in garrs.items()}
    graph_file = graph_file_name(graph_checksums)
    # content-addressed: the old manifest's graph file is never overwritten
    # (a crash between here and the manifest rename leaves the previous
    # generation's pairing of manifest + graph arrays fully intact)
    if not os.path.exists(os.path.join(path, graph_file)):
        _atomic_savez(os.path.join(path, graph_file), garrs)

    manifest = {
        "kind": FORMAT_KIND,
        "format_version": FORMAT_VERSION,
        "scheme": pg.scheme,
        "k": pg.k,
        "generation": int(generation),
        "applied_seq": int(applied_seq),
        "shard_seq": [int(s) for s in shard_seq],
        "graph_file": graph_file,
        "graph_checksums": graph_checksums,
        "node_pad": int(pg.node_pad),
        "edge_pad": int(pg.edge_pad),
        "ell_width": int(pg.ell_width),
        "cut_edges": int(pg.cut_edges),
        "n_nodes": int(g.n_nodes),
        "n_edges": int(g.n_edges),
        "part_keys": part_keys,
        "node_vocab": [g.node_vocab.str_of(i) for i in range(len(g.node_vocab))],
        "edge_vocab": [g.edge_vocab.str_of(i) for i in range(len(g.edge_vocab))],
        "partitions": parts_meta,
    }
    write_manifest(path, manifest)
    # the manifest is live: garbage-collect content-addressed files no
    # manifest or pinned generation references any more
    live = {m["shard"] for m in parts_meta} | {graph_file}
    if keep_files:
        live |= set(keep_files)
    gc_directory(path, live)
    return manifest


def write_manifest(path: str, manifest: Dict[str, Any]) -> None:
    """Atomically publish ``manifest`` — THE commit point of every save
    and compaction.  Callers must have every file it names durable first."""
    _atomic_write_text(os.path.join(path, MANIFEST_NAME),
                       json.dumps(manifest, indent=2))


def gc_directory(path: str, keep: set) -> int:
    """Remove content-addressed files (``part-*.npz`` / ``graph-*.npz``)
    not in ``keep``.  Never touches the manifest, delta logs, or the
    legacy fixed-name ``graph.npz``.  Returns the number removed."""
    removed = 0
    for fname in sorted(os.listdir(path)):
        if fname in keep or fname == GRAPH_NAME:
            continue
        if (fname.startswith("part-") or fname.startswith("graph-")) \
                and fname.endswith(".npz"):
            _fault_point("unlink", os.path.join(path, fname))
            os.remove(os.path.join(path, fname))
            removed += 1
    return removed


class DiskCatalog:
    """An opened graph directory: manifest metrics + verified shard reads.

    The catalog itself holds only O(V) state (the manifest and, lazily,
    ``graph.npz``); partition shards are read on demand by the host cache
    tier (storage/host_cache.py).  ``verify_checksums`` (default on)
    checks every array's sha256 against the manifest at read time — a
    torn or corrupted shard raises ``StorageFormatError`` instead of
    silently producing wrong answers.
    """

    def __init__(self, path: str, verify_checksums: bool = True):
        self.path = path
        self.verify_checksums = verify_checksums
        mpath = os.path.join(path, MANIFEST_NAME)
        if not os.path.exists(mpath):
            raise StorageFormatError(f"{path!r} has no {MANIFEST_NAME} — "
                                     f"not a graph directory (or an "
                                     f"interrupted save)")
        with open(mpath) as f:
            self.manifest = json.load(f)
        if self.manifest.get("kind") != FORMAT_KIND:
            raise StorageFormatError(f"unrecognized manifest kind "
                                     f"{self.manifest.get('kind')!r}")
        version = self.manifest.get("format_version")
        if version != FORMAT_VERSION:
            raise StorageFormatError(f"format_version {version} not "
                                     f"supported (this build reads "
                                     f"{FORMAT_VERSION})")
        self._parts = {p["pid"]: p for p in self.manifest["partitions"]}
        if sorted(self._parts) != list(range(self.k)):
            raise StorageFormatError("manifest partition list is not "
                                     f"0..{self.k - 1}")
        self._global: Optional[Dict[str, np.ndarray]] = None
        # cumulative bytes this catalog read off disk (shard files, as
        # stored — before geometry padding); obs/metrics.py exports it as
        # repro_store_disk_bytes_total
        self.bytes_read: int = 0

    # -- manifest-level metadata -------------------------------------------

    @property
    def k(self) -> int:
        return int(self.manifest["k"])

    @property
    def scheme(self) -> str:
        return self.manifest["scheme"]

    @property
    def part_keys(self) -> List[str]:
        return list(self.manifest["part_keys"])

    @property
    def generation(self) -> int:
        """The manifest's publish generation (0 for pre-delta directories)."""
        return int(self.manifest.get("generation", 0))

    @property
    def applied_seq(self) -> int:
        """Delta records with seq <= this are already folded into the
        manifest's graph file and shards (storage/deltas.py)."""
        return int(self.manifest.get("applied_seq", 0))

    def shard_seq(self, pid: int) -> int:
        """Per-partition fold watermark: records with seq <= this are
        baked into partition ``pid``'s shard file."""
        seqs = self.manifest.get("shard_seq")
        if seqs is None:
            return self.applied_seq
        return int(seqs[int(pid)])

    @property
    def graph_file(self) -> str:
        return self.manifest.get("graph_file", GRAPH_NAME)

    def part_meta(self, pid: int) -> Dict[str, Any]:
        return self._parts[int(pid)]

    def part_nbytes(self, pid: int) -> int:
        return int(self._parts[int(pid)]["nbytes"])

    def total_part_bytes(self) -> int:
        return sum(int(p["nbytes"]) for p in self.manifest["partitions"])

    def components_per_partition(self) -> np.ndarray:
        return np.asarray([self._parts[p]["components"]
                           for p in range(self.k)], dtype=np.int64)

    # -- whole-graph arrays (O(V+E), loaded once on first use) -------------

    def _globals(self) -> Dict[str, np.ndarray]:
        if self._global is None:
            with np.load(os.path.join(self.path, self.graph_file)) as z:
                arrs = {k: z[k] for k in z.files}
            want = self.manifest.get("graph_checksums")
            if self.verify_checksums and want:
                for k, a in arrs.items():
                    if array_checksum(a) != want.get(k):
                        raise StorageFormatError(
                            f"checksum mismatch on graph array {k!r} "
                            f"({self.graph_file}): file is corrupt or "
                            f"belongs to a different generation")
            self._global = arrs
        return self._global

    @property
    def assignment(self) -> np.ndarray:
        return self._globals()["assignment"]

    def load_graph(self) -> Graph:
        """Rebuild the host ``Graph`` (planner / oracle / profile input)."""
        g = self._globals()
        node_vocab, edge_vocab = LabelVocab(), LabelVocab()
        for s in self.manifest["node_vocab"]:
            node_vocab.intern(s)
        for s in self.manifest["edge_vocab"]:
            edge_vocab.intern(s)
        graph = Graph(
            n_nodes=int(self.manifest["n_nodes"]),
            node_label=g["node_label"], node_value=g["node_value"],
            edge_src=g["edge_src"], edge_dst=g["edge_dst"],
            edge_label=g["edge_label"], edge_directed=g["edge_directed"],
            node_vocab=node_vocab, edge_vocab=edge_vocab)
        graph.validate()
        return graph

    # -- the ranking input: SNI counts without any shard resident ----------

    def start_label_counts(self, label_id: int, value_op: int = 0,
                           value: float = 0.0) -> np.ndarray:
        """#core nodes matching (label, value predicate) per partition.

        Pure label queries are answered from the manifest's per-partition
        label histograms alone; value predicates additionally consult the
        O(V) ``graph.npz`` node arrays (through the same helper the
        in-RAM path uses, so semantics cannot diverge).  Partition shards
        are never read.
        """
        if not value_op:
            counts = np.zeros(self.k, dtype=np.int64)
            for pid in range(self.k):
                hist = self._parts[pid]["label_histogram"]
                if label_id == WILDCARD:
                    counts[pid] = sum(c for _, c in hist)
                else:
                    counts[pid] = next((c for l, c in hist
                                        if l == int(label_id)), 0)
            return counts
        from ..core.graph import start_label_counts_from_arrays
        g = self._globals()
        return start_label_counts_from_arrays(
            g["node_label"], g["node_value"], g["assignment"], self.k,
            label_id, value_op, value)

    # -- shard reads --------------------------------------------------------

    def shard_path(self, pid: int) -> str:
        return os.path.join(self.path, self._parts[int(pid)]["shard"])

    def read_part(self, pid: int) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """One shard off disk: (evaluator input dict, g2l row), checksum
        verified against the manifest when ``verify_checksums``.  Arrays
        are padded up to the manifest geometry after verification, so a
        directory whose compactions grew the padding still serves every
        shard at one uniform shape."""
        pid = int(pid)
        with np.load(self.shard_path(pid)) as z:
            arrs = {k: z[k] for k in z.files}
        self.bytes_read += sum(int(a.nbytes) for a in arrs.values())
        if self.verify_checksums:
            want = self._parts[pid]["checksums"]
            for k, a in arrs.items():
                got = array_checksum(a)
                if got != want.get(k):
                    raise StorageFormatError(
                        f"checksum mismatch on partition {pid} array "
                        f"{k!r} ({self.shard_path(pid)}): shard is "
                        f"corrupt or was written by a different layout")
        arrs = pad_bundle(arrs, int(self.manifest["node_pad"]),
                          int(self.manifest["ell_width"]),
                          int(self.manifest["n_nodes"]))
        g2l = arrs.pop("g2l")
        return arrs, g2l


class OutOfCorePartitionedGraph(PartitionedGraph):
    """A ``PartitionedGraph`` whose partition arrays live on disk.

    Same dataclass fields and methods as the in-RAM class — engines,
    sessions, and the scheduler are oblivious — except:

      * ``parts`` is empty and ``g2l`` is ``None``: partition bytes only
        enter memory through ``PartitionStore``'s host/device tiers
        (each shard carries its own g2l row);
      * ``start_label_counts`` / ``connected_components_per_partition``
        answer from the manifest catalog, so heuristic ranking and
        scheduler admission never touch a shard;
      * ``backing`` is the ``DiskCatalog`` the store reads shards from.
    """

    def __init__(self, catalog: DiskCatalog, graph: Optional[Graph] = None):
        m = catalog.manifest
        graph = graph if graph is not None else catalog.load_graph()
        assignment = np.asarray(catalog.assignment, dtype=np.int32)
        super().__init__(
            graph=graph, k=catalog.k, assignment=assignment, parts=[],
            owner=assignment.copy(), g2l=None,
            cut_edges=int(m["cut_edges"]),
            node_pad=int(m["node_pad"]), edge_pad=int(m["edge_pad"]),
            scheme=m["scheme"])
        self.backing = catalog
        self._ell_width = int(m["ell_width"])

    @property
    def ell_width(self) -> int:
        return self._ell_width

    def start_label_counts(self, label_id: int, value_op: int = 0,
                           value: float = 0.0) -> np.ndarray:
        return self.backing.start_label_counts(label_id, value_op, value)

    def connected_components_per_partition(self) -> np.ndarray:
        return self.backing.components_per_partition()


def open_partitioned_graph(path: str, verify_checksums: bool = True
                           ) -> OutOfCorePartitionedGraph:
    """Open a graph directory as an out-of-core ``PartitionedGraph``."""
    return OutOfCorePartitionedGraph(DiskCatalog(path, verify_checksums))
