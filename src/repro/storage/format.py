"""On-disk partition storage: a versioned shard-per-partition layout.

The paper's founding premise is a graph too large for main memory, and
its partitioned representation is exactly the unit that makes disk
residency natural: every partition is already a fixed-geometry array
bundle (core/graph.py), so the storage layer can treat "one partition"
as "one shard file" and never needs to understand traversal semantics.
Averbuch & Neumann (arXiv:1301.5121) make the case that partitioned graph
stores live or die by their on-disk layout and cache behaviour; this
module is the layout half (the cache half is storage/host_cache.py).

A *graph directory* written by ``save_partitioned_graph`` holds:

  manifest.json     — format version, partition geometry (k, scheme,
                      node_pad / edge_pad / ell_width, cut_edges), the
                      label vocabularies, and a per-partition catalog:
                      shard file name, vertex / edge counts, connected
                      components, byte size, a core-node label histogram,
                      and a sha256 checksum per array.  Everything the
                      heuristics need to *rank* partitions (SNI counts,
                      MAX-YIELD admission) is derivable from the manifest
                      plus ``graph.npz`` — no shard needs to be resident.
  graph.npz         — the whole-graph host arrays (node labels / values,
                      edge lists, the [V] partition assignment).  O(V+E)
                      raw data; the padded, denormalized shard bundles
                      below are the memory hog this tier keeps on disk.
  part-<pid>-<key>.npz — one shard per partition: the evaluator input
                      dict (``part_to_device_dict`` arrays, ELLPACK
                      tiles included) plus that partition's g2l row.
                      Written uncompressed so a round trip is
                      bit-identical; ``<key>`` is a digest of the
                      arrays' checksums (content-addressed).

Durability: every file is written via temp + atomic rename, shard names
are content-addressed, and the manifest is written LAST.  A directory
without a manifest is simply not a graph directory, so an interrupted
first ``save`` can never be opened; an interrupted RE-save leaves the
old manifest naming the old (untouched) shard generation, so the old
layout stays fully servable — changed shards land under new names, and
superseded generations are garbage-collected only after the fresh
manifest is live.

``DiskCatalog`` opens a graph directory and serves shard reads (checksum
verified) plus the manifest-level metrics; ``OutOfCorePartitionedGraph``
is the ``PartitionedGraph`` the rest of the system sees — same fields and
methods, but ``parts`` is empty and partition bytes only ever enter
memory through the store's host/device cache tiers.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.graph import Graph, LabelVocab, PartitionedGraph, WILDCARD

FORMAT_VERSION = 1
FORMAT_KIND = "pgqp-graph-dir"
MANIFEST_NAME = "manifest.json"
GRAPH_NAME = "graph.npz"


class StorageFormatError(RuntimeError):
    """A graph directory is missing, unversioned, or fails verification."""


def shard_name(pid: int, content_key: str) -> str:
    """Shard file names are CONTENT-ADDRESSED (pid + a digest of the
    arrays' checksums): a re-save with changed content writes NEW files
    while the old manifest keeps naming the old ones, so an interrupted
    re-save can never mix layouts — the old directory stays fully live
    until the fresh manifest lands, and identical content maps to the
    identical (byte-identical) file."""
    return f"part-{int(pid):05d}-{content_key}.npz"


def _content_key(checksums: Dict[str, str]) -> str:
    h = hashlib.sha256()
    for k in sorted(checksums):
        h.update(k.encode())
        h.update(checksums[k].encode())
    return h.hexdigest()[:12]


def _atomic_savez(path: str, arrs: Dict[str, np.ndarray]) -> None:
    """Write an npz via temp file + rename, so a torn write can never be
    mistaken for a shard (np.savez appends '.npz' to bare names, hence
    the explicit file handle)."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrs)
    os.replace(tmp, path)


def array_checksum(a: np.ndarray) -> str:
    """sha256 over (dtype, shape, bytes) — shape/dtype are part of the
    identity so a reshaped or recast array never passes as unchanged."""
    a = np.ascontiguousarray(np.asarray(a))
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(a.tobytes())
    return h.hexdigest()


def _shard_arrays(pg: PartitionedGraph, pid: int) -> Dict[str, np.ndarray]:
    """One partition's shard content: evaluator inputs + its g2l row."""
    from ..core.engine import part_to_device_dict
    arrs = {k: np.asarray(v) for k, v in part_to_device_dict(pg.parts[pid]).items()}
    arrs["g2l"] = np.asarray(pg.g2l[pid])
    return arrs


def _label_histogram(node_label: np.ndarray) -> List[List[int]]:
    """Sparse [label_id, count] pairs over a partition's core nodes — the
    manifest-level SNI input (start-node counts per label)."""
    labels, counts = np.unique(node_label, return_counts=True)
    return [[int(l), int(c)] for l, c in zip(labels, counts) if l >= 0]


def save_partitioned_graph(pg: PartitionedGraph, path: str) -> Dict[str, Any]:
    """Write ``pg`` as a graph directory; returns the manifest dict.

    Works for both in-RAM graphs (shards serialized from ``pg.parts``)
    and disk-opened ones (shards streamed partition-at-a-time through the
    backing catalog — never more than one partition's bytes in flight).
    The manifest is written last, so the directory only becomes openable
    once every shard it names is on disk.
    """
    assert pg.node_pad > 0, "uniform padding required (build_partitions default)"
    os.makedirs(path, exist_ok=True)
    backing: Optional[DiskCatalog] = getattr(pg, "backing", None)
    g = pg.graph

    parts_meta: List[Dict[str, Any]] = []
    part_keys: Optional[List[str]] = None
    for pid in range(pg.k):
        if backing is not None:
            arrs, g2l_row = backing.read_part(pid)
            arrs = dict(arrs)
            arrs["g2l"] = g2l_row
        else:
            arrs = _shard_arrays(pg, pid)
        checksums = {k: array_checksum(v) for k, v in arrs.items()}
        fname = shard_name(pid, _content_key(checksums))
        _atomic_savez(os.path.join(path, fname), arrs)
        core_mask = pg.assignment == pid
        parts_meta.append({
            "pid": pid,
            "shard": fname,
            "n_core": int(core_mask.sum()),
            "n_nodes": int(np.asarray(arrs["node_gid"] >= 0).sum()),
            "n_edges": int(np.asarray(arrs["ell_dst"] >= 0).sum()),
            "nbytes": int(sum(np.asarray(v).nbytes for v in arrs.values())),
            "components": 0,   # filled below in one pass over all partitions
            "label_histogram": _label_histogram(
                np.asarray(g.node_label)[core_mask]),
            "checksums": checksums,
        })
        if part_keys is None:
            part_keys = [k for k in arrs.keys() if k != "g2l"]
    # one pass for the per-partition CC metric (paper Sec. 5.2) instead of
    # the accidental O(k^2) of calling it inside the loop above
    ccs = pg.connected_components_per_partition()
    for meta in parts_meta:
        meta["components"] = int(ccs[meta["pid"]])

    np.savez(os.path.join(path, GRAPH_NAME),
             node_label=g.node_label, node_value=g.node_value,
             edge_src=g.edge_src, edge_dst=g.edge_dst,
             edge_label=g.edge_label, edge_directed=g.edge_directed,
             assignment=pg.assignment.astype(np.int32))

    manifest = {
        "kind": FORMAT_KIND,
        "format_version": FORMAT_VERSION,
        "scheme": pg.scheme,
        "k": pg.k,
        "node_pad": int(pg.node_pad),
        "edge_pad": int(pg.edge_pad),
        "ell_width": int(pg.ell_width),
        "cut_edges": int(pg.cut_edges),
        "n_nodes": int(g.n_nodes),
        "n_edges": int(g.n_edges),
        "part_keys": part_keys,
        "node_vocab": [g.node_vocab.str_of(i) for i in range(len(g.node_vocab))],
        "edge_vocab": [g.edge_vocab.str_of(i) for i in range(len(g.edge_vocab))],
        "partitions": parts_meta,
    }
    tmp = os.path.join(path, MANIFEST_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=2)
    os.replace(tmp, os.path.join(path, MANIFEST_NAME))
    # the manifest is live: garbage-collect shards of older generations
    # (content-addressed names mean they were never touched by this save)
    live = {m["shard"] for m in parts_meta}
    for fname in os.listdir(path):
        if fname.startswith("part-") and fname.endswith(".npz") \
                and fname not in live:
            os.remove(os.path.join(path, fname))
    return manifest


class DiskCatalog:
    """An opened graph directory: manifest metrics + verified shard reads.

    The catalog itself holds only O(V) state (the manifest and, lazily,
    ``graph.npz``); partition shards are read on demand by the host cache
    tier (storage/host_cache.py).  ``verify_checksums`` (default on)
    checks every array's sha256 against the manifest at read time — a
    torn or corrupted shard raises ``StorageFormatError`` instead of
    silently producing wrong answers.
    """

    def __init__(self, path: str, verify_checksums: bool = True):
        self.path = path
        self.verify_checksums = verify_checksums
        mpath = os.path.join(path, MANIFEST_NAME)
        if not os.path.exists(mpath):
            raise StorageFormatError(f"{path!r} has no {MANIFEST_NAME} — "
                                     f"not a graph directory (or an "
                                     f"interrupted save)")
        with open(mpath) as f:
            self.manifest = json.load(f)
        if self.manifest.get("kind") != FORMAT_KIND:
            raise StorageFormatError(f"unrecognized manifest kind "
                                     f"{self.manifest.get('kind')!r}")
        version = self.manifest.get("format_version")
        if version != FORMAT_VERSION:
            raise StorageFormatError(f"format_version {version} not "
                                     f"supported (this build reads "
                                     f"{FORMAT_VERSION})")
        self._parts = {p["pid"]: p for p in self.manifest["partitions"]}
        if sorted(self._parts) != list(range(self.k)):
            raise StorageFormatError("manifest partition list is not "
                                     f"0..{self.k - 1}")
        self._global: Optional[Dict[str, np.ndarray]] = None

    # -- manifest-level metadata -------------------------------------------

    @property
    def k(self) -> int:
        return int(self.manifest["k"])

    @property
    def scheme(self) -> str:
        return self.manifest["scheme"]

    @property
    def part_keys(self) -> List[str]:
        return list(self.manifest["part_keys"])

    def part_meta(self, pid: int) -> Dict[str, Any]:
        return self._parts[int(pid)]

    def part_nbytes(self, pid: int) -> int:
        return int(self._parts[int(pid)]["nbytes"])

    def total_part_bytes(self) -> int:
        return sum(int(p["nbytes"]) for p in self.manifest["partitions"])

    def components_per_partition(self) -> np.ndarray:
        return np.asarray([self._parts[p]["components"]
                           for p in range(self.k)], dtype=np.int64)

    # -- whole-graph arrays (O(V+E), loaded once on first use) -------------

    def _globals(self) -> Dict[str, np.ndarray]:
        if self._global is None:
            with np.load(os.path.join(self.path, GRAPH_NAME)) as z:
                self._global = {k: z[k] for k in z.files}
        return self._global

    @property
    def assignment(self) -> np.ndarray:
        return self._globals()["assignment"]

    def load_graph(self) -> Graph:
        """Rebuild the host ``Graph`` (planner / oracle / profile input)."""
        g = self._globals()
        node_vocab, edge_vocab = LabelVocab(), LabelVocab()
        for s in self.manifest["node_vocab"]:
            node_vocab.intern(s)
        for s in self.manifest["edge_vocab"]:
            edge_vocab.intern(s)
        graph = Graph(
            n_nodes=int(self.manifest["n_nodes"]),
            node_label=g["node_label"], node_value=g["node_value"],
            edge_src=g["edge_src"], edge_dst=g["edge_dst"],
            edge_label=g["edge_label"], edge_directed=g["edge_directed"],
            node_vocab=node_vocab, edge_vocab=edge_vocab)
        graph.validate()
        return graph

    # -- the ranking input: SNI counts without any shard resident ----------

    def start_label_counts(self, label_id: int, value_op: int = 0,
                           value: float = 0.0) -> np.ndarray:
        """#core nodes matching (label, value predicate) per partition.

        Pure label queries are answered from the manifest's per-partition
        label histograms alone; value predicates additionally consult the
        O(V) ``graph.npz`` node arrays (through the same helper the
        in-RAM path uses, so semantics cannot diverge).  Partition shards
        are never read.
        """
        if not value_op:
            counts = np.zeros(self.k, dtype=np.int64)
            for pid in range(self.k):
                hist = self._parts[pid]["label_histogram"]
                if label_id == WILDCARD:
                    counts[pid] = sum(c for _, c in hist)
                else:
                    counts[pid] = next((c for l, c in hist
                                        if l == int(label_id)), 0)
            return counts
        from ..core.graph import start_label_counts_from_arrays
        g = self._globals()
        return start_label_counts_from_arrays(
            g["node_label"], g["node_value"], g["assignment"], self.k,
            label_id, value_op, value)

    # -- shard reads --------------------------------------------------------

    def shard_path(self, pid: int) -> str:
        return os.path.join(self.path, self._parts[int(pid)]["shard"])

    def read_part(self, pid: int) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """One shard off disk: (evaluator input dict, g2l row), checksum
        verified against the manifest when ``verify_checksums``."""
        pid = int(pid)
        with np.load(self.shard_path(pid)) as z:
            arrs = {k: z[k] for k in z.files}
        if self.verify_checksums:
            want = self._parts[pid]["checksums"]
            for k, a in arrs.items():
                got = array_checksum(a)
                if got != want.get(k):
                    raise StorageFormatError(
                        f"checksum mismatch on partition {pid} array "
                        f"{k!r} ({self.shard_path(pid)}): shard is "
                        f"corrupt or was written by a different layout")
        g2l = arrs.pop("g2l")
        return arrs, g2l


class OutOfCorePartitionedGraph(PartitionedGraph):
    """A ``PartitionedGraph`` whose partition arrays live on disk.

    Same dataclass fields and methods as the in-RAM class — engines,
    sessions, and the scheduler are oblivious — except:

      * ``parts`` is empty and ``g2l`` is ``None``: partition bytes only
        enter memory through ``PartitionStore``'s host/device tiers
        (each shard carries its own g2l row);
      * ``start_label_counts`` / ``connected_components_per_partition``
        answer from the manifest catalog, so heuristic ranking and
        scheduler admission never touch a shard;
      * ``backing`` is the ``DiskCatalog`` the store reads shards from.
    """

    def __init__(self, catalog: DiskCatalog, graph: Optional[Graph] = None):
        m = catalog.manifest
        graph = graph if graph is not None else catalog.load_graph()
        assignment = np.asarray(catalog.assignment, dtype=np.int32)
        super().__init__(
            graph=graph, k=catalog.k, assignment=assignment, parts=[],
            owner=assignment.copy(), g2l=None,
            cut_edges=int(m["cut_edges"]),
            node_pad=int(m["node_pad"]), edge_pad=int(m["edge_pad"]),
            scheme=m["scheme"])
        self.backing = catalog
        self._ell_width = int(m["ell_width"])

    @property
    def ell_width(self) -> int:
        return self._ell_width

    def start_label_counts(self, label_id: int, value_op: int = 0,
                           value: float = 0.0) -> np.ndarray:
        return self.backing.start_label_counts(label_id, value_op, value)

    def connected_components_per_partition(self) -> np.ndarray:
        return self.backing.components_per_partition()


def open_partitioned_graph(path: str, verify_checksums: bool = True
                           ) -> OutOfCorePartitionedGraph:
    """Open a graph directory as an out-of-core ``PartitionedGraph``."""
    return OutOfCorePartitionedGraph(DiskCatalog(path, verify_checksums))
