"""Streaming mutations over a graph directory: per-partition delta logs,
generation-pinned snapshot views, and log→shard compaction.

PR 5's storage layer (storage/format.py) made the graph directory a
content-addressed, atomically published *generation*; this module makes
it mutable without ever serving an inconsistent snapshot — the
snapshot-vs-freshness trade-off of "Systems for Near Real-Time Analysis
of Large-Scale Dynamic Graphs" (PAPERS.md):

  delta logs     — writers append edge/vertex insert+delete records to
      per-partition JSON-lines logs (``deltas-<pid>.log``).  Every record
      carries a monotone global ``seq`` and a checksum; every append is a
      whole-file atomic rewrite (temp + rename, same discipline as
      shards), and records are appended ONE AT A TIME in seq order, so a
      crash always leaves a durable *prefix* of the mutation history —
      never a record whose dependency (an earlier seq) was lost.
  snapshot views — ``MutableGraphDirectory.snapshot()`` returns a
      ``GenerationView``: the manifest at snapshot time plus the pending
      records, pinned against GC.  Readers overlay pending deltas onto a
      shard at staging time (``GenerationView.load_bundle`` — the loader
      ``PartitionStore._stage`` routes through the host tier with a
      generation-aware cache token), so queries running on a view answer
      from one consistent generation while writers keep appending.
  compaction     — ``compact(pid)`` folds the pending history into a new
      content-addressed shard for ``pid`` plus a new content-addressed
      whole-graph file, then publishes both with ONE atomic manifest
      rename (generation+1).  A crash at any intermediate step leaves the
      previous generation fully servable (fault_hook in format.py turns
      this claim into tests/test_fault_injection.py).  Superseded files
      are garbage-collected only once no pinned view references them.

Deletion semantics: ``vertex_del`` removes every incident edge and
re-labels the vertex with the reserved label ``__deleted__`` (value NaN),
keeping its gid slot so answers stay stable and a from-scratch rebuild of
the same final state is gid-identical.  A tombstone still matches a
wildcard-label query node (it matches "any label" by definition) but no
concrete label — and with no edges it can never extend a path.

Watermarks: the manifest's ``applied_seq`` says the whole-graph file
reflects records up to that seq; per-partition ``shard_seq[pid]`` says
the same for each shard.  A partition is *stale* in a view iff some
pending record touching it has ``seq > shard_seq[pid]``; stale bundles
are rebuilt from the overlay graph (same ``build_partitions`` code path
as a from-scratch save, so the delta path cannot diverge from a rebuild
— the property tested in tests/test_property.py).  A record leaves the
log once folded into the graph file AND every touched shard.

Pins are in-process (one writer process per directory); multi-process
coordination is the multi-host open item in ROADMAP.md.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.graph import (Graph, LabelVocab, PartitionedGraph,
                          build_partitions)
from .format import (DiskCatalog, OutOfCorePartitionedGraph,
                     StorageFormatError, _atomic_savez, _atomic_write_text,
                     _content_key, _fault_point, _label_histogram,
                     array_checksum, gc_directory, graph_file_name,
                     pad_bundle, save_partitioned_graph, shard_name,
                     write_manifest)

DELTA_LOG_KIND = "pgqp-delta-log"
DELTA_LOG_VERSION = 1
DELETED_LABEL = "__deleted__"

EDGE_ADD = "edge_add"
EDGE_DEL = "edge_del"
VERTEX_ADD = "vertex_add"
VERTEX_DEL = "vertex_del"
DELTA_OPS = (EDGE_ADD, EDGE_DEL, VERTEX_ADD, VERTEX_DEL)


def log_name(pid: int) -> str:
    return f"deltas-{int(pid):05d}.log"


# ---------------------------------------------------------------------------
# Records
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeltaRecord:
    """One mutation.  ``u``/``v`` are endpoint gids for edge ops; ``u`` is
    the vertex gid for vertex ops.  Labels travel as STRINGS (interned at
    apply time, so records survive vocab growth across generations).
    ``touched`` is the pid set whose shards the record invalidates."""

    seq: int
    op: str
    u: int = -1
    v: int = -1
    label: str = ""
    directed: bool = False
    value: float = math.nan
    pid: int = -1                      # vertex_add: assigned partition
    touched: Tuple[int, ...] = ()

    def payload(self) -> Dict[str, Any]:
        return {"seq": int(self.seq), "op": self.op, "u": int(self.u),
                "v": int(self.v), "label": self.label,
                "directed": bool(self.directed),
                "value": None if math.isnan(self.value) else float(self.value),
                "pid": int(self.pid),
                "touched": [int(p) for p in self.touched]}

    def checksum(self) -> str:
        blob = json.dumps(self.payload(), sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]

    def to_json(self) -> str:
        d = self.payload()
        d["checksum"] = self.checksum()
        return json.dumps(d, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "DeltaRecord":
        if d.get("op") not in DELTA_OPS:
            raise StorageFormatError(f"unknown delta op {d.get('op')!r}")
        rec = cls(seq=int(d["seq"]), op=d["op"], u=int(d.get("u", -1)),
                  v=int(d.get("v", -1)), label=d.get("label", ""),
                  directed=bool(d.get("directed", False)),
                  value=(math.nan if d.get("value") is None
                         else float(d["value"])),
                  pid=int(d.get("pid", -1)),
                  touched=tuple(int(p) for p in d.get("touched", ())))
        want = d.get("checksum")
        if want is not None and want != rec.checksum():
            raise StorageFormatError(
                f"delta record seq={rec.seq} checksum mismatch "
                f"(log is corrupt or torn)")
        return rec


# ---------------------------------------------------------------------------
# The log
# ---------------------------------------------------------------------------

class DeltaLog:
    """Per-partition JSON-lines logs under one graph directory.

    A record's *primary* log is ``deltas-<min(touched)>.log`` (one durable
    write per record, in seq order → crash-prefix durability).  Reading
    merges every log, verifies per-record checksums, and checks the merged
    seq sequence is strictly increasing — a gap or duplicate means a torn
    or foreign log and raises rather than serving wrong answers.
    """

    def __init__(self, path: str):
        self.path = path
        # per-file line cache so appends don't re-read O(n) from disk
        self._lines: Dict[str, List[str]] = {}

    def _log_files(self) -> List[str]:
        return sorted(f for f in os.listdir(self.path)
                      if f.startswith("deltas-") and f.endswith(".log"))

    def _read_file(self, fname: str) -> List[str]:
        if fname not in self._lines:
            fpath = os.path.join(self.path, fname)
            if not os.path.exists(fpath):
                self._lines[fname] = []
            else:
                with open(fpath) as f:
                    lines = [ln.rstrip("\n") for ln in f if ln.strip()]
                if lines:
                    head = json.loads(lines[0])
                    if head.get("kind") != DELTA_LOG_KIND:
                        raise StorageFormatError(
                            f"{fname} is not a delta log")
                self._lines[fname] = lines[1:] if lines else []
        return self._lines[fname]

    def load(self) -> List[DeltaRecord]:
        """Every record across every log, checksum-verified, seq-sorted,
        monotonicity-checked."""
        recs: List[DeltaRecord] = []
        for fname in self._log_files():
            for ln in self._read_file(fname):
                recs.append(DeltaRecord.from_dict(json.loads(ln)))
        recs.sort(key=lambda r: r.seq)
        for a, b in zip(recs, recs[1:]):
            if b.seq <= a.seq:
                raise StorageFormatError(
                    f"delta logs have duplicate seq {b.seq}")
        return recs

    def append(self, rec: DeltaRecord) -> None:
        """Durably append one record (whole-file atomic rewrite of its
        primary log).  Callers append in seq order, one at a time."""
        if not rec.touched:
            raise ValueError("delta record must touch at least one pid")
        fname = log_name(min(rec.touched))
        lines = list(self._read_file(fname))
        lines.append(rec.to_json())
        header = json.dumps({"kind": DELTA_LOG_KIND,
                             "version": DELTA_LOG_VERSION})
        _atomic_write_text(os.path.join(self.path, fname),
                           "\n".join([header] + lines) + "\n")
        self._lines[fname] = lines

    def trim(self, applied_seq: int, shard_seq: Sequence[int]) -> int:
        """Drop records folded into the graph file AND every touched
        shard; rewrite (or delete) each log atomically.  Returns the
        number of records dropped — crash-safe: a partial trim leaves
        some folded records behind, and the next open trims them again.
        """

        def folded(r: DeltaRecord) -> bool:
            return (r.seq <= int(applied_seq)
                    and all(r.seq <= int(shard_seq[p]) for p in r.touched))

        dropped = 0
        for fname in self._log_files():
            lines = self._read_file(fname)
            keep = []
            for ln in lines:
                if folded(DeltaRecord.from_dict(json.loads(ln))):
                    dropped += 1
                else:
                    keep.append(ln)
            if len(keep) == len(lines):
                continue
            fpath = os.path.join(self.path, fname)
            if keep:
                header = json.dumps({"kind": DELTA_LOG_KIND,
                                     "version": DELTA_LOG_VERSION})
                _atomic_write_text(fpath, "\n".join([header] + keep) + "\n")
                self._lines[fname] = keep
            else:
                _fault_point("unlink", fpath)
                os.remove(fpath)
                self._lines[fname] = []
        return dropped


# ---------------------------------------------------------------------------
# Overlay application
# ---------------------------------------------------------------------------

def _copy_vocab(v: LabelVocab) -> LabelVocab:
    out = LabelVocab()
    for i in range(len(v)):
        out.intern(v.str_of(i))
    return out


def apply_records(graph: Graph, assignment: np.ndarray,
                  records: Sequence[DeltaRecord]
                  ) -> Tuple[Graph, np.ndarray]:
    """Overlay ``records`` (seq order) onto ``graph``; returns a NEW
    (graph, assignment) — inputs are never mutated, so snapshot views can
    share the arrays they were built from."""
    if not records:
        return graph, assignment
    node_label = np.array(graph.node_label)
    node_value = np.array(graph.node_value)
    esrc = np.array(graph.edge_src)
    edst = np.array(graph.edge_dst)
    elab = np.array(graph.edge_label)
    edir = np.array(graph.edge_directed)
    assign = np.array(assignment, dtype=np.int32)
    node_vocab = _copy_vocab(graph.node_vocab)
    edge_vocab = _copy_vocab(graph.edge_vocab)

    for r in sorted(records, key=lambda r: r.seq):
        if r.op == VERTEX_ADD:
            if r.u != len(node_label):
                raise StorageFormatError(
                    f"vertex_add seq={r.seq} gid {r.u} != next gid "
                    f"{len(node_label)} (log replayed out of order?)")
            node_label = np.append(node_label,
                                   np.int32(node_vocab.intern(r.label)))
            node_value = np.append(
                node_value, np.asarray(r.value, dtype=node_value.dtype))
            assign = np.append(assign, np.int32(r.pid))
        elif r.op == VERTEX_DEL:
            node_label[r.u] = node_vocab.intern(DELETED_LABEL)
            node_value[r.u] = np.nan
            keep = (esrc != r.u) & (edst != r.u)
            esrc, edst = esrc[keep], edst[keep]
            elab, edir = elab[keep], edir[keep]
        elif r.op == EDGE_ADD:
            esrc = np.append(esrc, np.int32(r.u))
            edst = np.append(edst, np.int32(r.v))
            elab = np.append(elab, np.int32(edge_vocab.intern(r.label)))
            edir = np.append(edir, edir.dtype.type(r.directed))
        elif r.op == EDGE_DEL:
            lid = edge_vocab.get(r.label, -10)
            keep = ~((esrc == r.u) & (edst == r.v) & (elab == lid))
            esrc, edst = esrc[keep], edst[keep]
            elab, edir = elab[keep], edir[keep]
    g = Graph(n_nodes=int(len(node_label)),
              node_label=node_label, node_value=node_value,
              edge_src=esrc, edge_dst=edst, edge_label=elab,
              edge_directed=edir,
              node_vocab=node_vocab, edge_vocab=edge_vocab)
    g.validate()
    return g, assign


# ---------------------------------------------------------------------------
# Generation views
# ---------------------------------------------------------------------------

class GenerationView:
    """One pinned, immutable snapshot: the manifest at snapshot time plus
    the pending delta records.  Everything a query needs — the overlay
    graph, per-partition staging bundles at one uniform geometry, SNI
    counts — comes from this object, so answers are always consistent
    with exactly one generation + seq watermark."""

    def __init__(self, mdir: "MutableGraphDirectory", catalog: DiskCatalog,
                 records: Tuple[DeltaRecord, ...], graph: Graph,
                 assignment: np.ndarray, seq: int):
        self.mdir = mdir
        self.catalog = catalog
        self.records = records
        self.graph = graph
        self.assignment = np.asarray(assignment, dtype=np.int32)
        self.seq = int(seq)
        self.generation = catalog.generation
        self._stale = {p for r in records for p in r.touched
                       if r.seq > catalog.shard_seq(p)}
        self._geom: Optional[Tuple[int, int, int]] = None
        self._rebuilt: Optional[PartitionedGraph] = None
        self._lock = threading.Lock()

    # -- geometry ----------------------------------------------------------

    def _ensure_geometry(self) -> None:
        with self._lock:
            if self._geom is not None:
                return
            m = self.catalog.manifest
            if not self._stale:
                self._geom = (int(m["node_pad"]), int(m["edge_pad"]),
                              int(m["ell_width"]))
                return
            # rebuild the overlay layout through the SAME code path a
            # from-scratch save uses — the delta path cannot diverge
            self._rebuilt = build_partitions(
                self.graph, self.assignment.astype(np.int64),
                self.catalog.k, scheme=self.catalog.scheme)
            self._geom = (max(int(m["node_pad"]), self._rebuilt.node_pad),
                          max(int(m["edge_pad"]), self._rebuilt.edge_pad),
                          max(int(m["ell_width"]), self._rebuilt.ell_width))

    @property
    def node_pad(self) -> int:
        self._ensure_geometry()
        return self._geom[0]

    @property
    def edge_pad(self) -> int:
        self._ensure_geometry()
        return self._geom[1]

    @property
    def ell_width(self) -> int:
        self._ensure_geometry()
        return self._geom[2]

    @property
    def stale_pids(self) -> set:
        return set(self._stale)

    def seq_for(self, pid: int) -> int:
        """The seq watermark of partition ``pid``'s bundle in this view."""
        pid = int(pid)
        pending = [r.seq for r in self.records
                   if pid in r.touched and r.seq > self.catalog.shard_seq(pid)]
        return max(pending) if pending else self.catalog.shard_seq(pid)

    def bundle_token(self, pid: int) -> Tuple:
        """The host-cache key of ``pid``'s staging bundle: pid + what it
        was built from (generation, delta watermark, target geometry) —
        two views with identical tokens produce byte-identical bundles,
        so the host tier can share them across generations."""
        self._ensure_geometry()
        return (int(pid), self.generation, self.seq_for(pid),
                self._geom[0], self._geom[2], int(self.graph.n_nodes))

    # -- staging -----------------------------------------------------------

    def load_bundle(self, pid: int) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """One partition's evaluator bundle under this view: the shard as
        stored when clean, the overlay rebuild when stale — both padded
        to the view's uniform geometry.  Returns (part dict, g2l row)."""
        pid = int(pid)
        self._ensure_geometry()
        if pid in self._stale:
            from .format import _shard_arrays
            with self.mdir.tracer.span("deltas.overlay_rebuild", pid=pid,
                                       generation=int(self.generation),
                                       seq=int(self.seq_for(pid))) as sp:
                arrs = _shard_arrays(self._rebuilt, pid)
                sp.set(nbytes=sum(int(a.nbytes) for a in arrs.values()))
        else:
            part, g2l = self.catalog.read_part(pid)
            arrs = dict(part)
            arrs["g2l"] = g2l
        arrs = pad_bundle(arrs, self._geom[0], self._geom[2],
                          int(self.graph.n_nodes))
        g2l = arrs.pop("g2l")
        return arrs, g2l

    # -- catalog-level metrics (SNI / CC) ---------------------------------

    def start_label_counts(self, label_id: int, value_op: int = 0,
                           value: float = 0.0) -> np.ndarray:
        """SNI per partition under THIS view.  A clean view answers from
        the manifest histograms (no shard touched, PR 5 behaviour); a
        view with pending deltas counts over the overlay arrays — the
        counts seed scheduler admission, so they must match what the
        evaluator will actually find or answers would be missed."""
        if not self.records:
            return self.catalog.start_label_counts(label_id, value_op, value)
        from ..core.graph import start_label_counts_from_arrays
        return start_label_counts_from_arrays(
            np.asarray(self.graph.node_label),
            np.asarray(self.graph.node_value),
            self.assignment, self.catalog.k, label_id, value_op, value)

    def connected_components_per_partition(self) -> np.ndarray:
        # ranking-only metric (MAX-YIELD tie-break, cost model): the
        # catalog's folded values are close enough between compactions
        return self.catalog.components_per_partition()

    def cut_edges(self) -> int:
        if not self.records:
            return int(self.catalog.manifest["cut_edges"])
        return int(np.sum(self.assignment[np.asarray(self.graph.edge_src)]
                          != self.assignment[np.asarray(self.graph.edge_dst)]))

    def files(self) -> set:
        """Content-addressed files this view needs alive (GC keep-set)."""
        m = self.catalog.manifest
        return ({p["shard"] for p in m["partitions"]}
                | {self.catalog.graph_file})

    def as_partitioned_graph(self) -> "SnapshotPartitionedGraph":
        return SnapshotPartitionedGraph(self)

    # -- pinning -----------------------------------------------------------

    def pin(self) -> "GenerationView":
        self.mdir.pin(self)
        return self

    def release(self) -> None:
        self.mdir.unpin(self)


class SnapshotPartitionedGraph(OutOfCorePartitionedGraph):
    """The ``PartitionedGraph`` a session binds for one generation view:
    overlay graph + assignment, the view's uniform geometry, SNI answered
    from the view — engines and the scheduler stay oblivious."""

    def __init__(self, view: GenerationView):
        assignment = view.assignment
        PartitionedGraph.__init__(
            self, graph=view.graph, k=view.catalog.k,
            assignment=assignment, parts=[], owner=assignment.copy(),
            g2l=None, cut_edges=view.cut_edges(),
            node_pad=view.node_pad, edge_pad=view.edge_pad,
            scheme=view.catalog.scheme)
        self.backing = view.catalog
        self.view = view
        self._ell_width = view.ell_width

    def start_label_counts(self, label_id: int, value_op: int = 0,
                           value: float = 0.0) -> np.ndarray:
        return self.view.start_label_counts(label_id, value_op, value)

    def connected_components_per_partition(self) -> np.ndarray:
        return self.view.connected_components_per_partition()


# ---------------------------------------------------------------------------
# The mutable directory
# ---------------------------------------------------------------------------

class MutableGraphDirectory:
    """One writable graph directory: append deltas, snapshot generations,
    compact, GC — the single-process writer side of the storage layer.

    Opening replays (and re-trims) the logs, so a crash anywhere —
    mid-append, mid-compaction, mid-GC — recovers to the last published
    generation plus every durably appended record.
    """

    def __init__(self, path: str, verify_checksums: bool = True):
        self.path = path
        self.verify_checksums = verify_checksums
        self.catalog = DiskCatalog(path, verify_checksums)
        self.log = DeltaLog(path)
        records = self.log.load()
        # a crash after a publish but before the log trim leaves folded
        # records behind; trim them now (idempotent)
        self.log.trim(self.catalog.applied_seq,
                      [self.catalog.shard_seq(p)
                       for p in range(self.catalog.k)])
        self._records: List[DeltaRecord] = [
            r for r in records
            if not (r.seq <= self.catalog.applied_seq
                    and all(r.seq <= self.catalog.shard_seq(p)
                            for p in r.touched))]
        # the running overlay (what snapshot() hands out); graph-file
        # records (seq <= applied_seq) are already IN the catalog graph
        base = self.catalog.load_graph()
        base_assign = np.asarray(self.catalog.assignment, dtype=np.int32)
        pending_graph = [r for r in self._records
                         if r.seq > self.catalog.applied_seq]
        self._graph, self._assign = apply_records(base, base_assign,
                                                  pending_graph)
        self._pins: Dict[int, List] = {}   # id(view) -> [view, refcount]
        self._lock = threading.RLock()
        self.compactions = 0
        # observability: GraphSession.open swaps in its live tracer; the
        # default no-op keeps standalone directory use untraced
        from ..obs.trace import NULL_TRACER
        self.tracer = NULL_TRACER

    # -- introspection ------------------------------------------------------

    @property
    def k(self) -> int:
        return self.catalog.k

    @property
    def generation(self) -> int:
        return self.catalog.generation

    def max_seq(self) -> int:
        with self._lock:
            seqs = [self.catalog.applied_seq]
            seqs += [self.catalog.shard_seq(p) for p in range(self.k)]
            seqs += [r.seq for r in self._records]
            return max(seqs)

    def pending_counts(self) -> np.ndarray:
        """Per-partition pending-delta volume — the ``workload_profile``
        signal that drives continuous repartitioning of hot-update
        partitions (WawPart, PAPERS.md)."""
        counts = np.zeros(self.k, dtype=np.int64)
        with self._lock:
            for r in self._records:
                for p in r.touched:
                    if r.seq > self.catalog.shard_seq(p):
                        counts[p] += 1
        return counts

    # -- writes -------------------------------------------------------------

    def _append(self, rec: DeltaRecord) -> DeltaRecord:
        # durable first (crash after this point keeps the record), then
        # the in-memory overlay
        with self.tracer.span("deltas.append", op=str(rec.op),
                              seq=int(rec.seq), touched=list(rec.touched)):
            self.log.append(rec)
            self._records.append(rec)
            self._graph, self._assign = apply_records(
                self._graph, self._assign, [rec])
        return rec

    def add_edge(self, u: int, v: int, label: str,
                 directed: bool = False) -> DeltaRecord:
        with self._lock:
            u, v = int(u), int(v)
            for g in (u, v):
                if not (0 <= g < len(self._assign)):
                    raise ValueError(f"edge endpoint gid {g} out of range")
                if self._graph.node_vocab.str_of(
                        int(self._graph.node_label[g])) == DELETED_LABEL:
                    raise ValueError(f"gid {g} is deleted")
            touched = tuple(sorted({int(self._assign[u]),
                                    int(self._assign[v])}))
            return self._append(DeltaRecord(
                seq=self.max_seq() + 1, op=EDGE_ADD, u=u, v=v, label=label,
                directed=bool(directed), touched=touched))

    def del_edge(self, u: int, v: int, label: str) -> DeltaRecord:
        with self._lock:
            u, v = int(u), int(v)
            touched = tuple(sorted({int(self._assign[u]),
                                    int(self._assign[v])}))
            return self._append(DeltaRecord(
                seq=self.max_seq() + 1, op=EDGE_DEL, u=u, v=v, label=label,
                touched=touched))

    def add_vertex(self, label: str, value: float = math.nan,
                   pid: Optional[int] = None) -> DeltaRecord:
        with self._lock:
            if pid is None:   # least-loaded partition under the overlay
                pid = int(np.argmin(np.bincount(
                    self._assign[self._assign >= 0], minlength=self.k)))
            gid = int(self._graph.n_nodes)
            return self._append(DeltaRecord(
                seq=self.max_seq() + 1, op=VERTEX_ADD, u=gid, label=label,
                value=float(value), pid=int(pid), touched=(int(pid),)))

    def del_vertex(self, gid: int) -> DeltaRecord:
        with self._lock:
            gid = int(gid)
            esrc = np.asarray(self._graph.edge_src)
            edst = np.asarray(self._graph.edge_dst)
            nbrs = np.concatenate([edst[esrc == gid], esrc[edst == gid]])
            touched = {int(self._assign[gid])}
            touched |= {int(self._assign[n]) for n in nbrs}
            return self._append(DeltaRecord(
                seq=self.max_seq() + 1, op=VERTEX_DEL, u=gid,
                touched=tuple(sorted(touched))))

    def apply_op(self, d: Dict[str, Any]) -> DeltaRecord:
        """Dict-shaped mutation entry point (serve.py's mutate workload):
        ``{"op": "edge_add", "u": 3, "v": 9, "label": "knows"}`` etc."""
        op = d.get("op")
        if op == EDGE_ADD:
            return self.add_edge(d["u"], d["v"], d["label"],
                                 bool(d.get("directed", False)))
        if op == EDGE_DEL:
            return self.del_edge(d["u"], d["v"], d["label"])
        if op == VERTEX_ADD:
            return self.add_vertex(d["label"],
                                   float(d.get("value", math.nan)),
                                   d.get("pid"))
        if op == VERTEX_DEL:
            return self.del_vertex(d["u"])
        raise ValueError(f"unknown delta op {op!r}")

    # -- snapshots & pins ----------------------------------------------------

    def snapshot(self) -> GenerationView:
        """The current generation + pending records, pinned against GC
        until ``release()``."""
        with self._lock:
            view = GenerationView(self, self.catalog, tuple(self._records),
                                  self._graph, self._assign, self.max_seq())
            return view.pin()

    def pin(self, view: GenerationView) -> None:
        with self._lock:
            ent = self._pins.setdefault(id(view), [view, 0])
            ent[1] += 1

    def unpin(self, view: GenerationView) -> None:
        with self._lock:
            ent = self._pins.get(id(view))
            if ent is None:
                return
            ent[1] -= 1
            if ent[1] <= 0:
                del self._pins[id(view)]

    def pinned_files(self) -> set:
        with self._lock:
            out: set = set()
            for view, _ in self._pins.values():
                out |= view.files()
            return out

    def gc(self) -> int:
        """Remove content-addressed files no longer referenced by the
        live manifest or any pinned view."""
        with self._lock:
            keep = ({p["shard"] for p in self.catalog.manifest["partitions"]}
                    | {self.catalog.graph_file} | self.pinned_files())
            return gc_directory(self.path, keep)

    # -- compaction ----------------------------------------------------------

    def compact(self, pid: int) -> int:
        """Fold the pending history into partition ``pid``'s shard and the
        whole-graph file, publish generation+1 (one atomic manifest
        rename), trim the logs, GC — returns the new generation.

        Ordering is the crash-safety argument, executed through the
        fault-pointed helpers so tests/test_fault_injection.py can stop
        it anywhere: (1) new shard (content-addressed — the old one is
        untouched), (2) new graph file (ditto), (3) manifest rename (THE
        publish), (4) log trim, (5) GC.  Crash before (3): the old
        manifest still pairs the old shard + old graph file + intact
        logs.  Crash after (3): the new generation is live and steps
        (4)/(5) re-run idempotently at the next open.
        """
        with self._lock, \
                self.tracer.span("deltas.compact", pid=int(pid),
                                 generation=int(self.generation)) as _csp:
            pid = int(pid)
            _csp.set(pending=int(self.pending_counts()[pid]))
            view = GenerationView(self, self.catalog, tuple(self._records),
                                  self._graph, self._assign, self.max_seq())
            view._ensure_geometry()
            g = view.graph
            m = self.catalog.manifest

            # (1) the folded shard for pid (a no-op rewrite when clean —
            # same content key — but geometry growth changes the key)
            arrs, g2l = view.load_bundle(pid)
            arrs = dict(arrs)
            arrs["g2l"] = g2l
            checksums = {k: array_checksum(v) for k, v in arrs.items()}
            fname = shard_name(pid, _content_key(checksums))
            if not os.path.exists(os.path.join(self.path, fname)):
                _atomic_savez(os.path.join(self.path, fname), arrs)

            # (2) the folded whole-graph file
            garrs = dict(node_label=np.asarray(g.node_label),
                         node_value=np.asarray(g.node_value),
                         edge_src=np.asarray(g.edge_src),
                         edge_dst=np.asarray(g.edge_dst),
                         edge_label=np.asarray(g.edge_label),
                         edge_directed=np.asarray(g.edge_directed),
                         assignment=view.assignment.astype(np.int32))
            graph_checksums = {k: array_checksum(v) for k, v in garrs.items()}
            graph_file = graph_file_name(graph_checksums)
            if not os.path.exists(os.path.join(self.path, graph_file)):
                _atomic_savez(os.path.join(self.path, graph_file), garrs)

            # (3) the manifest: pid's entry refolded, the rest describing
            # their (untouched) shards; geometry/vocabs/counts from the
            # overlay — the single publish point
            core_mask = view.assignment == pid
            hist_labels = np.asarray(g.node_label)[core_mask]
            new_meta = {
                "pid": pid,
                "shard": fname,
                "n_core": int(core_mask.sum()),
                "n_nodes": int(np.asarray(arrs["node_gid"] >= 0).sum()),
                "n_edges": int(np.asarray(arrs["ell_dst"] >= 0).sum()),
                "nbytes": int(sum(np.asarray(v).nbytes
                                  for v in arrs.values())),
                "components": int(
                    view._rebuilt.connected_components_per_partition()[pid]
                    if view._rebuilt is not None
                    else m["partitions"][pid]["components"]),
                "label_histogram": _label_histogram(hist_labels),
                "checksums": checksums,
            }
            seq = view.seq
            shard_seq = [self.catalog.shard_seq(p) for p in range(self.k)]
            shard_seq[pid] = seq
            partitions = [new_meta if p["pid"] == pid else p
                          for p in m["partitions"]]
            manifest = dict(m)
            manifest.update({
                "generation": self.generation + 1,
                "applied_seq": seq,
                "shard_seq": shard_seq,
                "graph_file": graph_file,
                "graph_checksums": graph_checksums,
                "node_pad": view.node_pad,
                "edge_pad": view.edge_pad,
                "ell_width": view.ell_width,
                "cut_edges": view.cut_edges(),
                "n_nodes": int(g.n_nodes),
                "n_edges": int(g.n_edges),
                "node_vocab": [g.node_vocab.str_of(i)
                               for i in range(len(g.node_vocab))],
                "edge_vocab": [g.edge_vocab.str_of(i)
                               for i in range(len(g.edge_vocab))],
                "partitions": partitions,
            })
            write_manifest(self.path, manifest)

            # the new generation is live
            self.catalog = DiskCatalog(self.path, self.verify_checksums)
            self.compactions += 1
            _csp.set(new_generation=int(self.generation))
            # (4) trim folded records, (5) GC unpinned superseded files
            self.log.trim(self.catalog.applied_seq,
                          [self.catalog.shard_seq(p)
                           for p in range(self.k)])
            self._records = [
                r for r in self._records
                if not (r.seq <= self.catalog.applied_seq
                        and all(r.seq <= self.catalog.shard_seq(p)
                                for p in r.touched))]
            self.gc()
            return self.generation

    def compact_all(self) -> int:
        """Fold every partition (k publishes); returns the generation."""
        gen = self.generation
        for pid in range(self.k):
            gen = self.compact(pid)
        return gen

    def resave(self, pg: PartitionedGraph) -> Dict[str, Any]:
        """Publish a full re-save (e.g. a repartitioned layout) as the
        next generation of THIS directory: every pending record is folded
        (``pg`` must already reflect the overlay graph), logs clear, and
        pinned generations' files survive GC."""
        with self._lock:
            seq = self.max_seq()
            manifest = save_partitioned_graph(
                pg, self.path, generation=self.generation + 1,
                applied_seq=seq, shard_seq=[seq] * pg.k,
                keep_files=self.pinned_files())
            self.catalog = DiskCatalog(self.path, self.verify_checksums)
            self.log.trim(seq, [seq] * self.catalog.k)
            self._records = []
            self._graph = self.catalog.load_graph()
            self._assign = np.asarray(self.catalog.assignment,
                                      dtype=np.int32)
            return manifest


def open_mutable(path: str, verify_checksums: bool = True
                 ) -> MutableGraphDirectory:
    return MutableGraphDirectory(path, verify_checksums)
