"""The pinned-host tier of the three-level partition cache.

``PartitionStore`` (core/store.py) owns device residency; this module
owns what sits between the device and the disk:

  disk (DiskCatalog shards)  →  host LRU (here)  →  device LRU (store)

Two implementations share one small protocol — ``get(pid)`` returning a
``HostBundle``, ``resident``, ``read_ahead``, ``nbytes``, ``clear``:

``HostArrayTier``  — the in-RAM case (a session built from a live
    ``PartitionedGraph``): every partition's host bundle is always
    resident, exactly the pre-PR behaviour.  ``read_ahead`` is a no-op.

``HostShardCache`` — the out-of-core case: an LRU of host bundles
    (capacity in partitions or bytes) backed by a ``DiskCatalog``.
    ``read_ahead(pid)`` starts a background thread that pulls the shard
    off disk while the caller keeps evaluating — the host-tier mirror of
    the store's device prefetch, so the heuristic's runner-up partition
    is in host RAM by the time its turn comes.  A later ``get`` joins
    the thread (a ``read_ahead_hit``: the disk latency overlapped useful
    work) instead of paying a demand read on the critical path.

Counter attribution (LoadStats, core/store.py): ``disk_reads`` and
``bytes_disk`` are incremented on the *calling* thread at issue time —
for demand reads and read-aheads alike — so snapshots/deltas taken by
the engines and the scheduler's round-scoped accounting never race the
worker thread; the worker only moves bytes.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, NamedTuple, Optional

import numpy as np


class HostBundle(NamedTuple):
    """One partition's host-resident staging unit."""

    part: Dict[str, np.ndarray]   # evaluator input dict
    g2l: np.ndarray               # that partition's [V] g2l row
    nbytes: int


def bundle_nbytes(part: Dict[str, np.ndarray], g2l: np.ndarray) -> int:
    return int(sum(np.asarray(v).nbytes for v in part.values())
               + np.asarray(g2l).nbytes)


class HostArrayTier:
    """All partitions pinned in host RAM (built once from a live pg)."""

    def __init__(self, pg):
        from ..core.engine import part_to_device_dict
        self._bundles = [
            HostBundle(part=(d := part_to_device_dict(p)),
                       g2l=pg.g2l[p.pid],
                       nbytes=bundle_nbytes(d, pg.g2l[p.pid]))
            for p in pg.parts]

    @property
    def part_keys(self):
        return self._bundles[0].part.keys()

    def resident(self, pid: int) -> bool:
        return True

    def get(self, pid: int) -> HostBundle:
        return self._bundles[int(pid)]

    def read_ahead(self, pid: int) -> bool:
        return False   # nothing to stage: everything is already host-resident

    def nbytes(self, pid: int) -> int:
        return self._bundles[int(pid)].nbytes

    def clear(self) -> None:
        pass   # pinned bundles are the graph itself; nothing to invalidate


class HostShardCache:
    """Disk-backed host LRU with background read-ahead.

    ``stats`` is the owning store's ``LoadStats``; this tier increments
    ``disk_reads`` / ``bytes_disk`` / ``read_ahead_issued`` /
    ``read_ahead_hits`` / ``host_evictions`` on it (main thread only,
    see module docstring).  With no capacity the tier holds every shard
    it has ever read — the "unbounded host cache" configuration that
    degrades gracefully to the in-RAM behaviour after one pass.
    """

    def __init__(self, catalog, stats,
                 capacity_parts: Optional[int] = None,
                 capacity_bytes: Optional[int] = None,
                 read_ahead: bool = True,
                 tracer=None):
        if capacity_parts is not None and capacity_parts < 1:
            raise ValueError(f"host capacity_parts must be >= 1, "
                             f"got {capacity_parts}")
        if capacity_bytes is not None and capacity_bytes < 1:
            raise ValueError(f"host capacity_bytes must be >= 1, "
                             f"got {capacity_bytes}")
        self.catalog = catalog
        self.stats = stats
        self.capacity_parts = capacity_parts
        self.capacity_bytes = capacity_bytes
        self.read_ahead_enabled = read_ahead
        if tracer is None:
            from ..obs.trace import NULL_TRACER
            tracer = NULL_TRACER
        self.tracer = tracer
        self._cache: "OrderedDict[int, HostBundle]" = OrderedDict()
        self._pending: Dict[int, threading.Thread] = {}
        self._errors: Dict[int, BaseException] = {}
        # pids whose cache entry landed via read-ahead and has not been
        # touched by get() yet (the first get counts a read_ahead_hit)
        self._prefetched: set = set()
        self._lock = threading.Lock()

    @property
    def part_keys(self):
        return self.catalog.part_keys

    @staticmethod
    def _norm(key):
        """Cache keys are ints (plain pid, pre-delta behaviour) or the
        store's bundle tokens ``(pid, generation, seq, geometry...)`` —
        anything hashable whose first element identifies the pid."""
        return int(key) if isinstance(key, (int, np.integer)) else key

    @staticmethod
    def _pid_of(key) -> int:
        return int(key if isinstance(key, (int, np.integer)) else key[0])

    def resident(self, key) -> bool:
        """Host-resident NOW — an in-flight read-ahead does not count
        (the store must not try to device-stage a pid whose bytes are
        still on their way: its host get would block on the worker)."""
        with self._lock:
            return self._norm(key) in self._cache

    def nbytes(self, key) -> int:
        return self.catalog.part_nbytes(self._pid_of(key))

    def _default_loader(self, key):
        def load() -> HostBundle:
            part, g2l = self.catalog.read_part(self._pid_of(key))
            return HostBundle(part=part, g2l=g2l,
                              nbytes=bundle_nbytes(part, g2l))
        return load

    def get(self, key, loader=None) -> HostBundle:
        """``loader`` builds the bundle on a miss (default: a plain
        checksum-verified shard read); a delta-aware caller passes the
        generation view's overlay loader with its token as ``key``."""
        key = self._norm(key)
        with self._lock:
            worker = self._pending.get(key)
        if worker is not None:
            worker.join()   # the worker inserts into the cache itself
        with self._lock:
            err = self._errors.pop(key, None)
            if err is not None:
                raise err   # e.g. StorageFormatError from a corrupt shard
            got = self._cache.get(key)
            if got is not None:
                self._cache.move_to_end(key)
                if key in self._prefetched:
                    self._prefetched.discard(key)
                    self.stats.read_ahead_hits += 1
                self.stats.bytes_host += got.nbytes
                return got
        # demand read: disk on the critical path
        with self.tracer.span("store.disk_read",
                              pid=self._pid_of(key)) as sp:
            self.stats.disk_reads += 1
            bundle = (loader or self._default_loader(key))()
            self.stats.bytes_disk += bundle.nbytes
            sp.set(nbytes=bundle.nbytes)
        with self._lock:
            self._insert(key, bundle)
        self.stats.bytes_host += bundle.nbytes
        return bundle

    def read_ahead(self, key, loader=None) -> bool:
        """Start pulling ``key`` off disk on a background thread; returns
        True when a read was actually issued (False: resident, already in
        flight, or read-ahead disabled).  The worker lands its bundle in
        the LRU itself (under the host budget, evicting as needed) and
        removes itself from the pending set, so a read-ahead nobody ever
        ``get``s is still capacity-bounded and thread-clean; a worker
        failure (corrupt shard, IO error) is re-raised by the next
        ``get(key)`` instead of being swallowed."""
        key = self._norm(key)
        if not self.read_ahead_enabled:
            return False
        with self._lock:
            if key in self._cache or key in self._pending:
                return False
        # counters on the calling thread (see module docstring); nbytes
        # comes from the manifest, so no shard I/O happens here
        self.stats.disk_reads += 1
        self.stats.read_ahead_issued += 1
        self.stats.bytes_disk += self.nbytes(key)
        load = loader or self._default_loader(key)

        def _work() -> None:
            try:
                # span recorded from the worker thread: the tracer is
                # thread-safe and the timebase is shared, so read-ahead
                # I/O shows up in its own thread lane overlapping the
                # main thread's eval spans
                with self.tracer.span("store.read_ahead",
                                      pid=self._pid_of(key)) as sp:
                    bundle = load()
                    sp.set(nbytes=bundle.nbytes)
                with self._lock:
                    self._pending.pop(key, None)
                    self._insert(key, bundle)
                    self._prefetched.add(key)
            except BaseException as e:   # surfaced by the next get(key)
                with self._lock:
                    self._pending.pop(key, None)
                    self._errors[key] = e

        t = threading.Thread(target=_work, daemon=True,
                             name=f"read-ahead-part-{self._pid_of(key)}")
        with self._lock:
            self._pending[key] = t
        t.start()
        return True

    def clear(self) -> None:
        """Drop every host entry and join in-flight read-aheads — the
        invalidation hook ``repartition()`` relies on (stale shards of an
        old layout must never be served)."""
        with self._lock:
            pending = list(self._pending.values())
        for t in pending:
            t.join()
        with self._lock:
            self._pending.clear()
            self._errors.clear()
            self._prefetched.clear()
            self._cache.clear()

    # -- internals (callers hold self._lock) -------------------------------

    def _insert(self, pid: int, bundle: HostBundle) -> None:
        self._cache[pid] = bundle
        self._cache.move_to_end(pid)
        self._prefetched.discard(pid)   # a demand insert is not a prefetch
        self._evict(keep=pid)

    def _evict(self, keep: int) -> None:
        def over() -> bool:
            if self.capacity_parts is not None \
                    and len(self._cache) > self.capacity_parts:
                return True
            if self.capacity_bytes is not None \
                    and sum(b.nbytes for b in self._cache.values()) \
                    > self.capacity_bytes:
                return True
            return False

        while over():
            victim = next((p for p in self._cache if p != keep), None)
            if victim is None:
                break   # the just-read shard alone exceeds the budget
            del self._cache[victim]
            self._prefetched.discard(victim)
            self.stats.host_evictions += 1
