"""AdamW with global-norm clipping and cosine schedule (self-contained —
no optax offline).  Optimizer moments are f32 and inherit each parameter's
sharding, so FSDP-sharded params get FSDP-sharded optimizer state (ZeRO).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def abstract_opt_state(params):
    return jax.eval_shape(init_opt_state, params)


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _decay_mask(path_leaf_name: str) -> bool:
    """Weight decay applies to matrices, not norms/biases (by leaf name)."""
    nodecay = ("ln1", "ln2", "final_norm", "norm", "out_norm", "q_norm",
               "k_norm", "bq", "bk", "bv", "b", "lam", "b_i", "b_f")
    return path_leaf_name not in nodecay


def adamw_update(cfg: OptConfig, params, grads, opt_state
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_p = jax.tree_util.tree_flatten_with_path(params)
    paths = [p for p, _ in flat_p[0]]

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        leaf = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if _decay_mask(str(leaf)):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    out = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, opt_state["m"], opt_state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"step": step, "m": new_m, "v": new_v}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
