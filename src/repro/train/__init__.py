from .optimizer import (OptConfig, init_opt_state, adamw_update,
                        abstract_opt_state)
from .step import TrainConfig, loss_fn, make_train_step

__all__ = ["OptConfig", "init_opt_state", "adamw_update",
           "abstract_opt_state", "TrainConfig", "loss_fn", "make_train_step"]
