"""Loss and train_step builders.

``make_train_step`` returns a pure function
    (params, opt_state, batch, rng) -> (params, opt_state, metrics)
suitable for ``jax.jit`` with in/out shardings from launch/sharding.py.
Cross-entropy is computed against vocab-sharded logits (XLA inserts the
model-axis reductions); MoE aux loss and z-loss are folded in.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.transformer import forward
from .optimizer import OptConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    aux_loss_weight: float = 0.01     # MoE load-balancing
    z_loss_weight: float = 1e-4       # logit normalizer regularizer
    remat: bool = True
    q_chunk: int = 512
    kv_chunk: int = 512
    causal_skip: bool = False
    tp_act: bool = False     # shard [B,S,d] activations over model too
    attn_remat: bool = False # recompute attention tiles in backward (§Perf-C4)
    flash_cv: bool = False   # custom-VJP flash attention (§Perf-C8)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None,
                  z_loss_weight: float = 0.0):
    """logits [B,S,V] f32, labels [B,S] int32.  Mean NLL over unmasked
    positions, plus z-loss.  Stable log-softmax."""
    lse = jax.nn.logsumexp(logits, axis=-1)                      # [B,S]
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]                  # [B,S]
    nll = lse - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    zl = ((lse * lse) * mask).sum() / denom
    return loss + z_loss_weight * zl, loss


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            tcfg: TrainConfig, act_shard=None, logit_shard=None,
            moe_fn=None):
    logits, aux = forward(params, cfg, batch, remat=tcfg.remat,
                          q_chunk=tcfg.q_chunk, kv_chunk=tcfg.kv_chunk,
                          causal_skip=tcfg.causal_skip, act_shard=act_shard,
                          logit_shard=logit_shard, moe_fn=moe_fn,
                          attn_remat=tcfg.attn_remat, flash_cv=tcfg.flash_cv)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    total, nll = cross_entropy(logits, labels, mask, tcfg.z_loss_weight)
    total = total + tcfg.aux_loss_weight * aux
    return total, {"nll": nll, "aux": aux}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    act_shard=None, logit_shard=None,
                    moe_fn=None) -> Callable:
    def train_step(params, opt_state, batch):
        (total, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, tcfg, act_shard, logit_shard,
                              moe_fn),
            has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(
            tcfg.opt, params, grads, opt_state)
        metrics = {"loss": total, **parts, **opt_metrics}
        return params, opt_state, metrics
    return train_step
