"""Sharded, atomic, resumable checkpointing (no orbax offline).

Layout (one directory per step):

    <dir>/step_000420/
        meta.json            — step, tree structure, dtypes/shapes, mesh note
        host0000.npz         — this host's param/opt shards (flattened keys)
        done                 — commit marker (atomic rename of tmp dir)

Fault-tolerance contract (DESIGN.md §6):
  * writes go to ``step_X.tmp`` and are renamed only after every file +
    the ``done`` marker are flushed — a crash mid-save never corrupts the
    latest checkpoint;
  * ``load_checkpoint`` restores onto ANY mesh: arrays are saved logically
    (full array per host for host-local shards via process-local
    addressable data) and re-sharded by jax.device_put on restore, so an
    elastic restart with a different device count works;
  * data-pipeline state (PRNG key counters, step) is stored in meta.json so
    restarts are bitwise reproducible;
  * ``keep`` bounds disk usage (oldest checkpoints pruned post-commit).

On multi-host deployments each host writes only the shards it owns
(``addressable_shards``); this CPU container has one host, which is the
degenerate case of the same code path.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx)
            if hasattr(p, "idx") else str(p) for p in path)
        out.append((key, leaf))
    return out


def _unflatten_like(template, flat: Dict[str, np.ndarray]):
    paths = _flatten(template)
    leaves = []
    for key, leaf in paths:
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key])
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, state: Dict[str, Any],
                    extra_meta: Optional[Dict[str, Any]] = None,
                    keep: int = 3) -> str:
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    flat = _flatten(state)
    arrays = {}
    meta_leaves = {}
    for key, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        arrays[key] = arr
        meta_leaves[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    host = jax.process_index() if jax.process_count() > 1 else 0
    np.savez(os.path.join(tmp, f"host{host:04d}.npz"), **arrays)
    meta = {"step": step, "time": time.time(), "leaves": meta_leaves,
            "n_hosts": jax.process_count(), **(extra_meta or {})}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    with open(os.path.join(tmp, "done"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    # prune old checkpoints (committed ones only)
    steps = sorted(latest_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def latest_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "done")):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = latest_steps(directory)
    return steps[-1] if steps else None


def load_checkpoint(directory: str, template, step: Optional[int] = None,
                    shardings=None) -> Tuple[int, Any, Dict[str, Any]]:
    """Restore ``template``-shaped state; re-shard via ``shardings`` if given
    (elastic restore onto a different mesh)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    flat: Dict[str, np.ndarray] = {}
    for name in sorted(os.listdir(path)):
        if name.endswith(".npz"):
            with np.load(os.path.join(path, name)) as z:
                for k in z.files:
                    flat[k] = z[k]
    state = _unflatten_like(template, flat)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    return step, state, meta


@dataclasses.dataclass
class CheckpointManager:
    """Save-every-N manager with restart-on-construction semantics."""

    directory: str
    every: int = 100
    keep: int = 3

    def restore_or_none(self, template, shardings=None):
        if latest_step(self.directory) is None:
            return None
        return load_checkpoint(self.directory, template, shardings=shardings)

    def maybe_save(self, step: int, state, extra_meta=None) -> Optional[str]:
        if step % self.every == 0 and step > 0:
            return save_checkpoint(self.directory, step, state,
                                   extra_meta=extra_meta, keep=self.keep)
        return None
