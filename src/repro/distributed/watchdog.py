"""Straggler watchdog: per-step wall-time tracking with robust outlier
flagging.

At cluster scale the launcher runs one of these per host; a step whose
duration exceeds ``threshold`` x rolling median is flagged (the fleet
controller would reschedule or evict the host — here we log and count,
and the training loop exposes the counters in its metrics).  This mirrors
the paper's m < required(i) analysis: progress continues with whatever
subset of workers is fast, and the quota/backpressure design in
MapReduceMP tolerates partial participation per iteration.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Optional


@dataclasses.dataclass
class StepWatchdog:
    window: int = 50
    threshold: float = 3.0        # x median
    _times: Deque[float] = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=200))
    slow_steps: int = 0
    _t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.time()

    def stop(self) -> float:
        assert self._t0 is not None, "start() not called"
        dt = time.time() - self._t0
        self._t0 = None
        flagged = self.is_straggler(dt)
        self._times.append(dt)
        if flagged:
            self.slow_steps += 1
        return dt

    def is_straggler(self, dt: float) -> bool:
        if len(self._times) < max(5, self.window // 10):
            return False
        med = sorted(self._times)[len(self._times) // 2]
        return dt > self.threshold * med

    @property
    def median(self) -> float:
        if not self._times:
            return 0.0
        return sorted(self._times)[len(self._times) // 2]
