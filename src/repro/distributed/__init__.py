from .checkpoint import (CheckpointManager, save_checkpoint, load_checkpoint,
                         latest_step)
from .watchdog import StepWatchdog

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "latest_step", "StepWatchdog"]
