"""Shared model layers: norms, RoPE, memory-efficient attention, FFN, MoE.

Attention is implemented flash-style in pure JAX — a double scan over query
and key/value chunks with an online-softmax accumulator — so prefill at 32k
(and beyond) compiles with bounded live memory instead of an S^2 score
tensor.  Local (sliding-window) attention gathers only the banded KV chunks
per query chunk, making it sub-quadratic end-to-end (RecurrentGemma blocks).

The MoE layer uses the static-capacity sort-based dispatch (MaxText-style
"dropping" implementation): tokens are argsorted by expert, gathered into an
[E, C, d] buffer, run through a batched per-expert SwiGLU, and combined with
their gate weights.  Compiled FLOPs therefore track *active* (top-k) params,
matching 6·N_active·D roofline accounting.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np



def rms_norm(x, scale, eps: float = 1e-6):
    """Variance reduction in f32; the elementwise apply stays in the input
    dtype, so no full-width f32 [B,S,d] tensor crosses HBM (§Perf-C5)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x [..., S, H, hd] (or [..., H, hd] with scalar positions)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs    # [..., S, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                              # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash attention with a custom VJP (tiled backward, p recomputed on-chip)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_cv(q, k, v, q_chunk: int = 1024, kv_chunk: int = 1024):
    """Causal GQA attention with the FlashAttention-2 style backward: the
    [Cq, Ck] probability tiles are recomputed inside the backward scan from
    (q, k, v, m, l) instead of being stashed — nothing O(S^2) ever crosses
    HBM (§Perf-C8).  q [B,S,H,hd]; k,v [B,S,Hkv,hd]."""
    out, _, _ = _flash_fwd_impl(q, k, v, q_chunk, kv_chunk)
    return out


def _flash_fwd_impl(q, k, v, q_chunk, kv_chunk):
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    Cq, Ck = min(q_chunk, S), min(kv_chunk, S)
    nq, nk = S // Cq, S // Ck
    scale = 1.0 / np.sqrt(hd)
    qs = q.reshape(B, nq, Cq, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, Ck, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, Ck, Hkv, hd).transpose(1, 0, 2, 3, 4)
    q_idx = jnp.arange(Cq)
    k_idx = jnp.arange(Ck)

    def one_q(qi, q_i):
        m0 = jnp.full((B, Cq, Hkv, G), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Cq, Hkv, G), jnp.float32)
        o0 = jnp.zeros((B, Cq, Hkv, G, hd), jnp.float32)

        def kv_step(carry, kj):
            m, l, o = carry
            k_j, v_j, j = kj
            s = jnp.einsum("bqhgd,bchd->bqhgc", q_i.astype(jnp.float32),
                           k_j.astype(jnp.float32)) * scale
            mask = (qi * Cq + q_idx)[:, None] >= (j * Ck + k_idx)[None, :]
            s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(mask[None, :, None, None, :],
                          jnp.exp(s - m_safe[..., None]), 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bqhgc,bchd->bqhgd", p, v_j.astype(jnp.float32))
            return (m_new, l_new, o_new), None

        (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0),
                                    (ks, vs, jnp.arange(nk)))
        out = (o / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
        return out, m, l

    outs, ms, ls = jax.lax.map(lambda a: one_q(*a), (jnp.arange(nq), qs))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd)
    return out, ms, ls                      # ms/ls [nq, B, Cq, Hkv, G]


def _flash_cv_fwd(q, k, v, q_chunk, kv_chunk):
    out, ms, ls = _flash_fwd_impl(q, k, v, q_chunk, kv_chunk)
    return out, (q, k, v, out, ms, ls)


def _flash_cv_bwd(q_chunk, kv_chunk, res, dout):
    q, k, v, out, ms, ls = res
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    Cq, Ck = min(q_chunk, S), min(kv_chunk, S)
    nq, nk = S // Cq, S // Ck
    scale = 1.0 / np.sqrt(hd)
    qs = q.reshape(B, nq, Cq, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, Ck, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, Ck, Hkv, hd).transpose(1, 0, 2, 3, 4)
    dos = dout.reshape(B, nq, Cq, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    os_ = out.reshape(B, nq, Cq, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    q_idx = jnp.arange(Cq)
    k_idx = jnp.arange(Ck)

    def one_q(carry, xs):
        dk_acc, dv_acc = carry              # [nk, B, Ck, Hkv, hd] f32
        qi, q_i, do_i, o_i, m_i, l_i = xs
        do_f = do_i.astype(jnp.float32)
        # D = rowsum(dout * out)  [B,Cq,Hkv,G]
        D = jnp.einsum("bqhgd,bqhgd->bqhg", do_f, o_i.astype(jnp.float32))
        l_safe = jnp.maximum(l_i, 1e-30)

        def kv_step(inner, kj):
            dq_i, dk_acc, dv_acc = inner
            k_j, v_j, j = kj
            s = jnp.einsum("bqhgd,bchd->bqhgc", q_i.astype(jnp.float32),
                           k_j.astype(jnp.float32)) * scale
            mask = (qi * Cq + q_idx)[:, None] >= (j * Ck + k_idx)[None, :]
            m_safe = jnp.where(jnp.isfinite(m_i), m_i, 0.0)
            p = jnp.where(mask[None, :, None, None, :],
                          jnp.exp(s - m_safe[..., None]), 0.0) / \
                l_safe[..., None]                                  # [B,q,h,g,c]
            dv_j = jnp.einsum("bqhgc,bqhgd->bchd", p, do_f)
            dp = jnp.einsum("bqhgd,bchd->bqhgc", do_f,
                            v_j.astype(jnp.float32))
            ds = p * (dp - D[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bqhgc,bchd->bqhgd", ds,
                                     k_j.astype(jnp.float32))
            dk_j = jnp.einsum("bqhgc,bqhgd->bchd", ds,
                              q_i.astype(jnp.float32))
            dk_acc = dk_acc.at[j].add(dk_j)
            dv_acc = dv_acc.at[j].add(dv_j)
            return (dq_i, dk_acc, dv_acc), None

        dq0 = jnp.zeros((B, Cq, Hkv, G, hd), jnp.float32)
        (dq_i, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), (ks, vs, jnp.arange(nk)))
        return (dk_acc, dv_acc), dq_i

    dk0 = jnp.zeros((nk, B, Ck, Hkv, hd), jnp.float32)
    dv0 = jnp.zeros((nk, B, Ck, Hkv, hd), jnp.float32)
    (dk_acc, dv_acc), dqs = jax.lax.scan(
        one_q, (dk0, dv0), (jnp.arange(nq), qs, dos, os_, ms, ls))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd).astype(q.dtype)
    dk = dk_acc.transpose(1, 0, 2, 3, 4).reshape(B, S, Hkv, hd).astype(k.dtype)
    dv = dv_acc.transpose(1, 0, 2, 3, 4).reshape(B, S, Hkv, hd).astype(v.dtype)
    return dq, dk, dv


flash_attention_cv.defvjp(_flash_cv_fwd, _flash_cv_bwd)


# ---------------------------------------------------------------------------
# Flash-style attention (double-chunk scan, online softmax)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True,
                    q_chunk: int = 512, kv_chunk: int = 512,
                    window: Optional[int] = None,
                    causal_skip: bool = False,
                    remat_qchunk: bool = False):
    """q [B,S,H,hd]; k,v [B,S,Hkv,hd] (GQA: H = Hkv * G).  Returns [B,S,H,hd].

    ``causal_skip``: bound the inner KV loop at each query chunk's causal
    horizon (a dynamic fori_loop bound) — removes the ~2x wasted FLOPs of the
    masked upper triangle.  NOTE: not reverse-mode differentiable (dynamic
    fori_loop bound) — inference paths only; §Perf-C2 documents the failed
    training attempt.

    ``remat_qchunk``: wrap each query chunk in jax.checkpoint so backward
    recomputes the [Cq, Ck] probability tiles instead of stashing the full
    O(S^2) f32 score tensor per layer (§Perf-C4).
    """
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    Cq = min(q_chunk, S)
    Ck = min(kv_chunk, S)
    assert S % Cq == 0 and S % Ck == 0, (S, Cq, Ck)
    nq, nk = S // Cq, S // Ck
    scale = 1.0 / np.sqrt(hd)

    qs = q.reshape(B, nq, Cq, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, Ck, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, Ck, Hkv, hd).transpose(1, 0, 2, 3, 4)

    q_idx = jnp.arange(Cq)
    k_idx = jnp.arange(Ck)

    def one_q_chunk(qi, q_i):
        # online-softmax state
        m0 = jnp.full((B, Cq, Hkv, G), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Cq, Hkv, G), jnp.float32)
        o0 = jnp.zeros((B, Cq, Hkv, G, hd), jnp.float32)

        def kv_step(carry, kj):
            m, l, o = carry
            k_j, v_j, j = kj
            s = jnp.einsum("bqhgd,bchd->bqhgc", q_i.astype(jnp.float32),
                           k_j.astype(jnp.float32)) * scale
            gq = qi * Cq + q_idx                       # global positions
            gk = j * Ck + k_idx
            mask = jnp.ones((Cq, Ck), bool)
            if causal:
                mask &= gq[:, None] >= gk[None, :]
            if window is not None:
                mask &= gq[:, None] - gk[None, :] < window
            s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows (m_new = -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, :, None, None, :], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bqhgc,bchd->bqhgd", p, v_j.astype(jnp.float32))
            return (m_new, l_new, o_new), None

        if causal_skip and causal and Cq == Ck:
            # dynamic horizon: only kv chunks j <= qi contribute
            def body(j, carry):
                carry, _ = kv_step(carry, (ks[j], vs[j], j))
                return carry
            m, l, o = jax.lax.fori_loop(0, qi + 1, body, (m0, l0, o0))
        else:
            (m, l, o), _ = jax.lax.scan(
                kv_step, (m0, l0, o0),
                (ks, vs, jnp.arange(nk)))
        out = o / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)

    chunk_fn = one_q_chunk
    if remat_qchunk:
        chunk_fn = jax.checkpoint(one_q_chunk)
    outs = jax.lax.map(lambda args: chunk_fn(*args),
                       (jnp.arange(nq), qs))           # [nq, B, Cq, Hkv, G, hd]
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd)
    return out


def local_attention(q, k, v, *, window: int, q_chunk: int = 512):
    """Banded sliding-window causal attention: each query chunk attends to a
    dynamic slice of [window + Cq] keys — compiled FLOPs are O(S * window),
    not O(S^2)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    Cq = min(q_chunk, S)
    assert S % Cq == 0
    nq = S // Cq
    Wk = min(window + Cq, S)        # keys visible to one q chunk
    scale = 1.0 / np.sqrt(hd)

    qs = q.reshape(B, nq, Cq, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def one_q_chunk(qi, q_i):
        start = jnp.clip(qi * Cq + Cq - Wk, 0, S - Wk)
        k_w = jax.lax.dynamic_slice(k, (0, start, 0, 0), (B, Wk, Hkv, hd))
        v_w = jax.lax.dynamic_slice(v, (0, start, 0, 0), (B, Wk, Hkv, hd))
        s = jnp.einsum("bqhgd,bchd->bqhgc", q_i.astype(jnp.float32),
                       k_w.astype(jnp.float32)) * scale
        gq = qi * Cq + jnp.arange(Cq)
        gk = start + jnp.arange(Wk)
        mask = (gq[:, None] >= gk[None, :]) & (gq[:, None] - gk[None, :] < window)
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bqhgc,bchd->bqhgd", p, v_w.astype(jnp.float32))
        return out.astype(q.dtype)

    outs = jax.lax.map(lambda args: one_q_chunk(*args), (jnp.arange(nq), qs))
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd)


def decode_attention(q, k_cache, v_cache, pos, *, window: Optional[int] = None):
    """One-token attention over a padded cache.

    q [B,H,hd]; caches [B,Smax,Hkv,hd]; pos scalar int32 (#valid positions
    BEFORE this token; the new token's kv must already be written at pos).
    """
    B, H, hd = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    idx = jnp.arange(k_cache.shape[1])
    mask = idx <= pos
    if window is not None:
        mask &= idx > pos - window
    s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# FFN (SwiGLU) and MoE
# ---------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def moe_ffn_tp(x, router_w, w_gate, w_up, w_down, *, top_k: int,
               capacity_factor: float = 1.25, axis: str = "model"):
    """Expert-parallel MoE dispatch over the ``axis`` mesh dimension
    (§Perf-B): activations are replicated over ``axis`` (the TP axis), the
    expert weights are sharded [E/axis_size, d, f] per rank; each rank
    compacts ONLY the tokens routed to its local experts (the paper's
    MapReduceMP "emit to owner" step — here the owner already holds the
    data, so dispatch is comm-free), runs its experts, and the per-rank
    partial outputs are summed with one psum (the combine).

    Per-MoE-layer comm: ONE all-reduce of [N, d] — versus the global
    sort-based path whose sharded sort/gather makes GSPMD replicate
    [N*k, d] buffers per device.  Must be called inside shard_map with
    ``axis`` in scope; x [N, d] local tokens, expert weights local shards.
    """
    N, d = x.shape
    E_loc = w_gate.shape[0]
    from repro.compat import axis_size
    n_ranks = axis_size(axis)
    E = E_loc * n_ranks
    rank = jax.lax.axis_index(axis)
    e_lo = rank * E_loc

    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_e = jax.lax.top_k(probs, top_k)                # [N, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    frac = jnp.zeros(E, jnp.float32).at[top_e.reshape(-1)].add(1.0) / (N * top_k)
    aux = E * jnp.sum(frac * probs.mean(0))

    # local compaction: (token, k) pairs whose expert lives on this rank
    eflat = top_e.reshape(-1)                                     # [N*k]
    local = (eflat >= e_lo) & (eflat < e_lo + E_loc)
    le = jnp.where(local, eflat - e_lo, E_loc)                    # E_loc = drop
    order = jnp.argsort(le)                                       # locals first
    sorted_e = jnp.take(le, order)
    C = int(np.ceil(N * top_k / E * capacity_factor))
    grp = jnp.searchsorted(sorted_e, jnp.arange(E_loc + 1, dtype=sorted_e.dtype))
    pos = jnp.arange(N * top_k, dtype=jnp.int32) - grp[
        jnp.clip(sorted_e, 0, E_loc)].astype(jnp.int32)
    keep = (sorted_e < E_loc) & (pos < C)
    slot = jnp.where(keep, sorted_e.astype(jnp.int32) * C + pos, E_loc * C)
    token_of = (order // top_k).astype(jnp.int32)

    xg = jnp.zeros((E_loc * C, d), x.dtype).at[slot].set(
        jnp.take(x, token_of, axis=0), mode="drop").reshape(E_loc, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", xg, w_up)
    ye = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E_loc * C, d)

    y_sorted = jnp.take(ye, jnp.clip(slot, 0, E_loc * C - 1), axis=0)
    gates_sorted = jnp.take(gate_vals.reshape(-1), order)
    w = jnp.where(keep, gates_sorted, 0.0).astype(jnp.float32)
    y_partial = jnp.zeros((N, d), jnp.float32).at[token_of].add(
        y_sorted.astype(jnp.float32) * w[:, None])
    y = jax.lax.psum(y_partial, axis)           # the combine (one all-reduce)
    return y.astype(x.dtype), aux


def make_tp_moe_fn(mesh, dp_spec, cfg):
    """Build the shard_map wrapper installing moe_ffn_tp as the routed-FFN
    implementation (forward's ``moe_fn`` hook).  Shared experts stay on the
    dense pjit path (transformer._apply_ffn)."""
    from jax.sharding import PartitionSpec as P
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def inner(x_l, router, wg, wu, wd):
        B, S, d = x_l.shape
        y, aux = moe_ffn_tp(x_l.reshape(B * S, d), router, wg, wu, wd,
                            top_k=cfg.top_k,
                            capacity_factor=cfg.capacity_factor)
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)
        return y.reshape(B, S, d), aux

    xspec = P(dp_spec, None, None)
    espec = P("model", None, None)
    from repro.compat import shard_map
    fn = shard_map(
        inner, mesh=mesh,
        in_specs=(xspec, P(), espec, espec, espec),
        out_specs=(xspec, P()),
        check_vma=False)

    def moe_fn(p, x):
        return fn(x, p["router"], p["e_gate"], p["e_up"], p["e_down"])
    return moe_fn


def moe_ffn(x, router_w, w_gate, w_up, w_down, *, top_k: int,
            capacity_factor: float = 1.25):
    """Sort-based static-capacity MoE dispatch.

    x [N, d]; router_w [d, E]; expert weights [E, d, ff] / [E, ff, d].
    Returns ([N, d] output, aux load-balancing loss).
    """
    N, d = x.shape
    E = router_w.shape[1]
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # [N, E]
    gate_vals, top_e = jax.lax.top_k(probs, top_k)              # [N, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                 # renormalize

    # switch-style aux loss: E * sum_e (frac_tokens_e * mean_prob_e)
    frac = jnp.zeros(E, jnp.float32).at[top_e.reshape(-1)].add(1.0) / (N * top_k)
    aux = E * jnp.sum(frac * probs.mean(0))

    C = int(np.ceil(N * top_k / E * capacity_factor))
    eflat = top_e.reshape(-1)                                   # [N*k]
    order = jnp.argsort(eflat)                                  # group by expert
    sorted_e = jnp.take(eflat, order)
    grp_start = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
    pos_in_e = jnp.arange(N * top_k, dtype=jnp.int32) - grp_start[
        jnp.clip(sorted_e, 0, E - 1)].astype(jnp.int32)
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e.astype(jnp.int32) * C + pos_in_e, E * C)
    token_of = (order // top_k).astype(jnp.int32)

    xg = jnp.zeros((E * C, d), x.dtype).at[slot].set(
        jnp.take(x, token_of, axis=0), mode="drop").reshape(E, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", xg, w_up)
    ye = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E * C, d)

    # combine: gather each (token, k) result and weight by its gate
    y_sorted = jnp.take(ye, jnp.clip(slot, 0, E * C - 1), axis=0)
    gates_sorted = jnp.take(gate_vals.reshape(-1), order)
    w = jnp.where(keep, gates_sorted, 0.0).astype(jnp.float32)
    y = jnp.zeros((N, d), jnp.float32).at[token_of].add(
        y_sorted.astype(jnp.float32) * w[:, None])
    return y.astype(x.dtype), aux
