"""xLSTM blocks (Beck et al., 2024): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory with recurrent gate connections).

Both are implemented as exact recurrences with ``lax.scan`` over time —
mLSTM additionally exposes single-step functions for decode.  States are
O(1) in sequence length, which is what makes the 500k-context decode shape
runnable for this family (see DESIGN.md §long-context).

Simplifications vs. the paper (noted in DESIGN.md): block-diagonal
projections are dense per head; sLSTM omits the post-block projection
factor, mLSTM uses projection factor 2.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .layers import rms_norm


def _causal_conv1d(x, w, cache=None):
    """x [B,S,D], w [cw, D] depthwise.  Returns (y [B,S,D], new_cache)."""
    cw = w.shape[0]
    if cache is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([cache, x], axis=1)        # [B, cw-1+S, D]
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(cw))
    new_cache = xp[:, xp.shape[1] - (cw - 1):]
    return jax.nn.silu(y), new_cache


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, d_model: int, n_heads: int, conv_width: int = 4,
               dtype=jnp.bfloat16) -> Dict:
    up = 2 * d_model
    hd = up // n_heads
    k = jax.random.split(key, 8)
    s = lambda *sh: 0.02 * jax.random.normal(k[len(sh) % 8], sh, jnp.float32)
    return {
        "norm": jnp.zeros(d_model, jnp.float32),
        "w_up": s(d_model, up).astype(dtype),
        "w_gate": s(d_model, up).astype(dtype),
        "conv_w": (0.1 * jax.random.normal(k[2], (conv_width, up), jnp.float32)),
        "w_q": s(up, up).astype(dtype),
        "w_k": s(up, up).astype(dtype),
        "w_v": s(up, up).astype(dtype),
        "w_i": s(up, n_heads).astype(jnp.float32),
        "b_i": jnp.zeros(n_heads, jnp.float32),
        "w_f": s(up, n_heads).astype(jnp.float32),
        "b_f": 3.0 * jnp.ones(n_heads, jnp.float32),   # forget-gate bias init
        "out_norm": jnp.zeros(up, jnp.float32),
        "w_down": s(up, d_model).astype(dtype),
    }


def mlstm_state_init(batch: int, d_model: int, n_heads: int,
                     conv_width: int = 4):
    up = 2 * d_model
    hd = up // n_heads
    return {
        "C": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
        "m": jnp.full((batch, n_heads), -jnp.inf, jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, up), jnp.bfloat16),
    }


def _mlstm_step(state, qkvif):
    """One stabilized mLSTM recurrence step (per head)."""
    q, k_, v, logi, logf = qkvif      # q/k/v [B,H,hd]; logi/logf [B,H]
    C, n, m = state
    m_new = jnp.maximum(logf + m, logi)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    i_ = jnp.exp(logi - m_safe)
    f_ = jnp.where(jnp.isfinite(m), jnp.exp(logf + m - m_safe), 0.0)
    C_new = f_[..., None, None] * C + i_[..., None, None] * (
        v[..., None, :] * k_[..., :, None])           # [B,H,hd_k,hd_v]
    n_new = f_[..., None] * n + i_[..., None] * k_
    h_num = jnp.einsum("bhkv,bhk->bhv", C_new, q)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)), 1.0)
    h = h_num / h_den[..., None]
    return (C_new, n_new, m_new), h


def mlstm_apply(params, x, state=None, *, n_heads: int, chunk: int = 0):
    """x [B,S,d] (S may be 1 for decode).  Returns (y [B,S,d], new_state).

    ``chunk > 0`` selects the CHUNKWISE-PARALLEL evaluation (exact, same
    recurrence): per-timestep outer-product updates become per-chunk
    matmuls and the autodiff stash shrinks from O(S·|C|) to O(S/T·|C|) —
    the beyond-paper optimization recorded in EXPERIMENTS.md §Perf-A.
    """
    B, S, d = x.shape
    up = 2 * d
    hd = up // n_heads
    if state is None:
        state = mlstm_state_init(B, d, n_heads, params["conv_w"].shape[0])
    xn = rms_norm(x, params["norm"])
    xu = xn @ params["w_up"]
    xz = xn @ params["w_gate"]
    xc, conv_cache = _causal_conv1d(xu, params["conv_w"], state["conv"])

    def heads(t, w):
        return (t @ w).reshape(B, S, n_heads, hd)

    q = heads(xc, params["w_q"]).astype(jnp.float32) / np.sqrt(hd)
    k_ = heads(xc, params["w_k"]).astype(jnp.float32) / np.sqrt(hd)
    v = heads(xu, params["w_v"]).astype(jnp.float32)
    logi = (xu.astype(jnp.float32) @ params["w_i"] + params["b_i"])   # [B,S,H]
    logf = jax.nn.log_sigmoid(
        xu.astype(jnp.float32) @ params["w_f"] + params["b_f"])

    if chunk and S > 1 and S % min(chunk, S) == 0:
        (C, n, m), h = _mlstm_chunkwise(
            q, k_, v, logi, logf,
            (state["C"], state["n"], state["m"]), min(chunk, S))
    else:
        def scan_step(carry, t):
            qt, kt, vt, it, ft = t
            return _mlstm_step(carry, (qt, kt, vt, it, ft))

        seq = (q.transpose(1, 0, 2, 3), k_.transpose(1, 0, 2, 3),
               v.transpose(1, 0, 2, 3), logi.transpose(1, 0, 2),
               logf.transpose(1, 0, 2))
        (C, n, m), hs = jax.lax.scan(
            scan_step, (state["C"], state["n"], state["m"]), seq)
        h = hs.transpose(1, 0, 2, 3)                   # [B,S,H,hd]
    h = h.reshape(B, S, up)
    h = rms_norm(h.astype(x.dtype), params["out_norm"])
    y = (h * jax.nn.silu(xz)) @ params["w_down"]
    new_state = {"C": C, "n": n, "m": m, "conv": conv_cache.astype(jnp.bfloat16)}
    return x + y, new_state


def _mlstm_chunkwise(q, k_, v, logi, logf, carry, T: int):
    """Exact chunkwise-parallel mLSTM (stabilized, matches _mlstm_step).

    Sequential recurrence, unrolled within a chunk of length T (chunk-local
    cumulative log-forget F_t = sum_{s<=t} logf_s, u_s = logi_s - F_s,
    g_t = max(m_prev, cummax_{s<=t} u_s), m_t = F_t + g_t):

      h_t  = [ exp(m_prev - g_t) * q_t C_prev
               + sum_{s<=t} exp(u_s - g_t) (q_t.k_s) v_s ] / den_t
      den_t = max(|exp(m_prev - g_t) * q_t.n_prev
               + sum_{s<=t} exp(u_s - g_t) (q_t.k_s)|, 1)

    i.e. one [T,T] decay-masked attention matmul per chunk plus a rank-T
    carry update — O(S/T) state round-trips instead of O(S).
    """
    B, S, H, hd = q.shape
    nc = S // T

    def chunk_step(carry, inp):
        C, n, m = carry                     # [B,H,hd,hd], [B,H,hd], [B,H]
        qc, kc, vc, ic, fc = inp            # [B,T,H,hd] / [B,T,H]
        F = jnp.cumsum(fc, axis=1)          # [B,T,H]
        u = ic - F                          # [B,T,H]
        g = jnp.maximum(m[:, None], jax.lax.cummax(u, axis=1))   # [B,T,H]
        # intra-chunk decay-masked scores
        scores = jnp.einsum("bthd,bshd->bhts", qc, kc)           # [B,H,T,T]
        w = jnp.exp(u.transpose(0, 2, 1)[:, :, None, :]
                    - g.transpose(0, 2, 1)[:, :, :, None])       # [B,H,T,S<=T]
        mask = jnp.tril(jnp.ones((T, T), bool))
        wts = jnp.where(mask[None, None], scores * w, 0.0)
        # carry path
        cdec = jnp.exp(m[:, None] - g)                           # [B,T,H]
        h_carry = jnp.einsum("bthd,bhde->bthe", qc, C) * cdec[..., None]
        n_carry = jnp.einsum("bthd,bhd->bth", qc, n) * cdec
        h_num = h_carry + jnp.einsum("bhts,bshe->bthe", wts, vc)
        den = n_carry + wts.sum(axis=-1).transpose(0, 2, 1)      # [B,T,H]
        h = h_num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # chunk-end carry update (position T): m_T = F_T + g_T
        FT = F[:, -1]                                            # [B,H]
        gT = g[:, -1]
        m_new = FT + gT
        dec_prev = jnp.exp(m + FT - m_new)                       # [B,H]
        kv_w = jnp.exp(u - gT[:, None])                          # [B,T,H]
        C_new = dec_prev[..., None, None] * C + jnp.einsum(
            "bthd,bthe,bth->bhde", kc, vc, kv_w)
        n_new = dec_prev[..., None] * n + jnp.einsum(
            "bthd,bth->bhd", kc, kv_w)
        return (C_new, n_new, m_new), h

    qs = q.reshape(B, nc, T, H, hd).transpose(1, 0, 2, 3, 4)
    ks = k_.reshape(B, nc, T, H, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nc, T, H, hd).transpose(1, 0, 2, 3, 4)
    is_ = logi.reshape(B, nc, T, H).transpose(1, 0, 2, 3)
    fs = logf.reshape(B, nc, T, H).transpose(1, 0, 2, 3)
    carry, hs = jax.lax.scan(chunk_step, carry, (qs, ks, vs, is_, fs))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return carry, h


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, d_model: int, n_heads: int, dtype=jnp.bfloat16) -> Dict:
    hd = d_model // n_heads
    k = jax.random.split(key, 4)
    w = lambda i: (0.02 * jax.random.normal(k[i], (d_model, 4 * d_model),
                                            jnp.float32)).astype(dtype)
    r = (0.02 * jax.random.normal(k[1], (n_heads, hd, 4 * hd), jnp.float32))
    return {
        "norm": jnp.zeros(d_model, jnp.float32),
        "w_x": w(0),                       # input projections (i,f,z,o packed)
        "r_h": r.astype(dtype),            # recurrent per-head (i,f,z,o packed)
        "b": jnp.concatenate([jnp.zeros(d_model), 3.0 * jnp.ones(d_model),
                              jnp.zeros(2 * d_model)]).astype(jnp.float32),
        "w_out": (0.02 * jax.random.normal(k[2], (d_model, d_model),
                                           jnp.float32)).astype(dtype),
    }


def slstm_state_init(batch: int, d_model: int, n_heads: int):
    hd = d_model // n_heads
    return {
        "c": jnp.zeros((batch, n_heads, hd), jnp.float32),
        "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
        "m": jnp.full((batch, n_heads, hd), -jnp.inf, jnp.float32),
        "h": jnp.zeros((batch, n_heads, hd), jnp.float32),
    }


def slstm_apply(params, x, state=None, *, n_heads: int, remat_chunk: int = 0):
    """Exact sequential sLSTM (recurrent gate connections force a true scan).

    ``remat_chunk > 0``: nested scan — outer over S/T chunks (carries
    checkpointed), inner T steps wrapped in jax.checkpoint, so the autodiff
    stash holds per-CHUNK states instead of per-STEP states (§Perf-A4).
    The recurrence itself cannot be parallelized (recurrent gate
    connections), so only the stash traffic shrinks, not the depth.
    """
    B, S, d = x.shape
    hd = d // n_heads
    if state is None:
        state = slstm_state_init(B, d, n_heads)
    xn = rms_norm(x, params["norm"])
    gx = (xn @ params["w_x"]).astype(jnp.float32) + params["b"]   # [B,S,4d]
    gx = gx.reshape(B, S, n_heads, 4 * hd)

    def step(carry, gxt):
        c, n, m, h = carry
        rec = jnp.einsum("bhd,hdk->bhk", h, params["r_h"].astype(jnp.float32))
        g = gxt + rec                                   # [B,H,4hd]
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        logf = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(logf + m, gi)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        i_ = jnp.exp(gi - m_safe)
        f_ = jnp.where(jnp.isfinite(m), jnp.exp(logf + m - m_safe), 0.0)
        c_new = f_ * c + i_ * jnp.tanh(gz)
        n_new = f_ * n + i_
        h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, h_new), h_new

    carry0 = (state["c"], state["n"], state["m"], state["h"])
    T = min(remat_chunk, S) if remat_chunk else 0
    if T and S % T == 0 and S > T:
        nc = S // T

        @jax.checkpoint
        def chunk(carry, gxc):                          # gxc [T,B,H,4hd]
            return jax.lax.scan(step, carry, gxc)

        gxc = gx.transpose(1, 0, 2, 3).reshape(nc, T, B, n_heads, 4 * hd)
        (c, n, m, h), hs = jax.lax.scan(chunk, carry0, gxc)
        hs = hs.reshape(S, B, n_heads, hd)
    else:
        (c, n, m, h), hs = jax.lax.scan(step, carry0, gx.transpose(1, 0, 2, 3))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype) @ params["w_out"]
    return x + y, {"c": c, "n": n, "m": m, "h": h}
