"""Unified decoder-only model covering all ten assigned architectures.

The layer stack is a repeating ``block_pattern`` over
{attn, local, rglru, mlstm, slstm}; the forward pass scans over pattern
*periods* with stacked per-period parameters (``jax.lax.scan``) so HLO size
and compile time stay ~depth-independent.  Three stack segments:

  head : the leading ``first_dense_layers`` (MoE models put dense FFNs
         there), applied unrolled,
  body : ``n_periods`` repetitions of the pattern, scanned,
  tail : ``n_layers`` mod pattern leftovers, unrolled.

Parameters are plain nested dicts of jnp arrays; leaf NAMES carry the
sharding meaning (launch/sharding.py maps name -> logical axes -> mesh axes),
so the same tree works for real init and for ``jax.eval_shape`` dry-runs.

Modality frontends (audio frames / VLM patches) are STUBS per the
assignment: ``input_specs`` hands the model precomputed frame/patch
embeddings; the in-model part (linear/MLP projector, embedding merge) is
real.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import (BLOCK_ATTN, BLOCK_LOCAL_ATTN, BLOCK_MLSTM,
                     BLOCK_RECURRENT, BLOCK_SLSTM, FAMILY_AUDIO, FAMILY_VLM,
                     ModelConfig)
from .layers import (apply_rope, flash_attention, flash_attention_cv, local_attention, moe_ffn,
                     rms_norm, swiglu)
from . import rglru as rg
from . import xlstm as xl

Params = Dict[str, Any]


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Layer segments: head (unrolled) / body (scanned periods) / tail (unrolled)
# ---------------------------------------------------------------------------

def stack_segments(cfg: ModelConfig) -> Tuple[List[int], List[List[int]], List[int]]:
    """Layer indices of (head, body-periods, tail)."""
    head = list(range(cfg.first_dense_layers))
    rest = list(range(cfg.first_dense_layers, cfg.n_layers))
    period = len(cfg.block_pattern) if cfg.block_pattern else 1
    n_periods = len(rest) // period
    body = [rest[i * period:(i + 1) * period] for i in range(n_periods)]
    tail = rest[n_periods * period:]
    return head, body, tail


# ---------------------------------------------------------------------------
# Parameter init (per block kind)
# ---------------------------------------------------------------------------

def _norm_init(d):  # RMSNorm scale (stored as delta from 1)
    return jnp.zeros((d,), jnp.float32)


def _dense(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (s * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def init_attn_block(key, cfg: ModelConfig, layer: int, local: bool) -> Params:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 12)
    p: Params = {
        "ln1": _norm_init(d),
        "wq": _dense(ks[0], (d, H, hd), dt),
        "wk": _dense(ks[1], (d, Hkv, hd), dt),
        "wv": _dense(ks[2], (d, Hkv, hd), dt),
        "wo": _dense(ks[3], (H, hd, d), dt, scale=1.0 / np.sqrt(H * hd)),
        "ln2": _norm_init(d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), jnp.float32)
        p["bk"] = jnp.zeros((Hkv, hd), jnp.float32)
        p["bv"] = jnp.zeros((Hkv, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = _norm_init(hd)
        p["k_norm"] = _norm_init(hd)
    p["ffn"] = init_ffn(ks[4], cfg, layer)
    return p


def init_ffn(key, cfg: ModelConfig, layer: int) -> Params:
    d = cfg.d_model
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    if cfg.is_moe and layer >= cfg.first_dense_layers:
        E, f = cfg.n_experts, cfg.expert_d_ff
        p: Params = {
            "router": _dense(ks[0], (d, E), jnp.float32),
            "e_gate": _dense(ks[1], (E, d, f), dt),
            "e_up": _dense(ks[2], (E, d, f), dt),
            "e_down": _dense(ks[3], (E, f, d), dt, scale=1.0 / np.sqrt(f)),
        }
        if cfg.n_shared_experts:
            fs = cfg.n_shared_experts * f
            p["s_gate"] = _dense(ks[4], (d, fs), dt)
            p["s_up"] = _dense(ks[5], (d, fs), dt)
            p["s_down"] = _dense(ks[6], (fs, d), dt, scale=1.0 / np.sqrt(fs))
        return p
    ff = cfg.dense_d_ff if (cfg.is_moe and cfg.dense_d_ff) else cfg.d_ff
    return {
        "w_gate": _dense(ks[0], (d, ff), dt),
        "w_up": _dense(ks[1], (d, ff), dt),
        "w_down": _dense(ks[2], (ff, d), dt, scale=1.0 / np.sqrt(ff)),
    }


def init_rglru_block(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 2)
    p = rg.rglru_init(ks[0], d, w, cfg.conv1d_width, _dtype(cfg.param_dtype))
    if cfg.d_ff:
        p["ffn"] = init_ffn(ks[1], cfg, layer=10**6)  # always-dense FFN
        p["ln2"] = _norm_init(d)
    return p


def init_block(key, cfg: ModelConfig, layer: int) -> Params:
    kind = cfg.block_kind(layer)
    if kind == BLOCK_ATTN:
        return init_attn_block(key, cfg, layer, local=False)
    if kind == BLOCK_LOCAL_ATTN:
        return init_attn_block(key, cfg, layer, local=True)
    if kind == BLOCK_RECURRENT:
        return init_rglru_block(key, cfg)
    if kind == BLOCK_MLSTM:
        p = xl.mlstm_init(key, cfg.d_model, cfg.n_heads, cfg.conv1d_width,
                          _dtype(cfg.param_dtype))
        return p
    if kind == BLOCK_SLSTM:
        return xl.slstm_init(key, cfg.d_model, cfg.n_heads,
                             _dtype(cfg.param_dtype))
    raise ValueError(kind)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    d, dt = cfg.d_model, _dtype(cfg.param_dtype)
    head, body, tail = stack_segments(cfg)
    keys = jax.random.split(key, cfg.n_layers + 4)

    p: Params = {}
    if cfg.family == FAMILY_AUDIO:
        # EnCodec frame embeddings arrive precomputed (stub); in-model proj
        p["in_proj"] = _dense(keys[-1], (cfg.frontend_dim(), d), dt)
    else:
        p["embed"] = _dense(keys[-2], (cfg.vocab, d), dt, scale=0.02)
    if cfg.family == FAMILY_VLM:
        dv = cfg.frontend_dim()
        p["img_proj_w1"] = _dense(keys[-3], (dv, d), dt)
        p["img_proj_w2"] = _dense(keys[-4], (d, d), dt)

    if head:
        p["head_layers"] = [init_block(keys[i], cfg, i) for i in head]
    if body:
        per_layer = [[init_block(keys[ls[j]], cfg, ls[j]) for ls in body]
                     for j in range(len(body[0]))]
        # stack across periods: leaf -> [n_periods, ...]
        p["body"] = [jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
                     for stacked in per_layer]
    if tail:
        p["tail_layers"] = [init_block(keys[i], cfg, i) for i in tail]

    p["final_norm"] = _norm_init(d)
    if not cfg.tie_embeddings:
        p["lm_head"] = _dense(keys[-3], (d, cfg.vocab), dt, scale=0.02)
    return p


def abstract_params(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct tree (no allocation) — used by the dry-run."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Block application (shared by train forward / prefill / decode)
# ---------------------------------------------------------------------------

def _qkv(p, cfg: ModelConfig, x):
    """x [B,S,d] -> q [B,S,H,hd], k/v [B,S,Hkv,hd] with bias/qk-norm."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _apply_ffn(p, cfg: ModelConfig, x, layer_is_moe: bool, moe_fn=None):
    """x [B,S,d] -> (y, aux_loss).  ``moe_fn`` (optional) overrides the
    routed-expert implementation (e.g. layers.make_tp_moe_fn — §Perf-B)."""
    if layer_is_moe:
        B, S, d = x.shape
        if moe_fn is not None:
            y, aux = moe_fn(p, x)
        else:
            flat = x.reshape(B * S, d)
            y, aux = moe_ffn(flat, p["router"], p["e_gate"], p["e_up"],
                             p["e_down"], top_k=cfg.top_k,
                             capacity_factor=cfg.capacity_factor)
            y = y.reshape(B, S, d)
        if "s_gate" in p:
            y = y + swiglu(x, p["s_gate"], p["s_up"], p["s_down"])
        return y, aux
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"]), jnp.float32(0.0)


def apply_attn_block(p, cfg: ModelConfig, x, positions, *, local: bool,
                     layer_is_moe: bool, q_chunk: int = 512,
                     kv_chunk: int = 512, causal_skip: bool = False,
                     moe_fn=None, attn_remat: bool = False,
                     flash_cv: bool = False):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, h)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    S = x.shape[1]
    qc = min(q_chunk, S)
    kc = min(kv_chunk, S)
    if local:
        attn = local_attention(q, k, v, window=cfg.local_window, q_chunk=qc)
    elif flash_cv:
        attn = flash_attention_cv(q, k, v, qc, kc)   # custom-VJP (§Perf-C8)
    else:
        attn = flash_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=kc,
                               causal_skip=causal_skip,
                               remat_qchunk=attn_remat)
    x = x + jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    y, aux = _apply_ffn(p["ffn"], cfg, h2, layer_is_moe, moe_fn)
    return x + y, aux


def apply_block(p, cfg: ModelConfig, kind: str, x, positions, *,
                layer_is_moe: bool, q_chunk: int = 512, kv_chunk: int = 512,
                causal_skip: bool = False, moe_fn=None,
                attn_remat: bool = False, flash_cv: bool = False):
    """Training/prefill-mode application (full sequence, no carried state)."""
    if kind in (BLOCK_ATTN, BLOCK_LOCAL_ATTN):
        return apply_attn_block(p, cfg, x, positions,
                                local=(kind == BLOCK_LOCAL_ATTN),
                                layer_is_moe=layer_is_moe, q_chunk=q_chunk,
                                kv_chunk=kv_chunk, causal_skip=causal_skip,
                                moe_fn=moe_fn, attn_remat=attn_remat,
                                flash_cv=flash_cv)
    if kind == BLOCK_RECURRENT:
        y, _ = rg.rglru_apply(p, x)
        if cfg.d_ff:
            h2 = rms_norm(y, p["ln2"], cfg.norm_eps)
            f, _aux = _apply_ffn(p["ffn"], cfg, h2, False)
            y = y + f
        return y, jnp.float32(0.0)
    if kind == BLOCK_MLSTM:
        y, _ = xl.mlstm_apply(p, x, n_heads=cfg.n_heads,
                              chunk=cfg.mlstm_chunk)
        return y, jnp.float32(0.0)
    if kind == BLOCK_SLSTM:
        y, _ = xl.slstm_apply(p, x, n_heads=cfg.n_heads,
                              remat_chunk=cfg.mlstm_chunk)
        return y, jnp.float32(0.0)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Full forward (training / scoring)
# ---------------------------------------------------------------------------

def embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    """Token/frontend embedding -> [B,S,d] activations."""
    dt = _dtype(cfg.compute_dtype)
    if cfg.family == FAMILY_AUDIO:
        # precomputed EnCodec frame embeddings [B,S,d_frame] (frontend stub)
        x = batch["frame_embeds"].astype(dt) @ params["in_proj"].astype(dt)
        return x
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    if cfg.family == FAMILY_VLM and "image_embeds" in batch:
        # anyres patch embeddings [B,F,dv] (frontend stub) -> 2-layer projector
        img = batch["image_embeds"].astype(dt)
        img = jax.nn.gelu(img @ params["img_proj_w1"].astype(dt))
        img = img @ params["img_proj_w2"].astype(dt)
        F = img.shape[1]
        # image tokens occupy the first F positions (anyres prefix layout)
        x = jnp.concatenate([img, x[:, F:]], axis=1)
    return x


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            remat: bool = True, q_chunk: int = 512, kv_chunk: int = 512,
            causal_skip: bool = False, act_shard=None,
            logit_shard=None, moe_fn=None,
            attn_remat: bool = False,
            flash_cv: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits [B,S,vocab] f32, aux_loss scalar).

    ``logit_shard`` (a with_sharding_constraint closure) keeps the [B,S,V]
    logits vocab-sharded over the model axis — REQUIRED to fit HBM at
    production shapes (an unsharded f32 logits tensor for B=16/dev, S=4096,
    V=152k is ~40 GB/device; see EXPERIMENTS.md §Perf iteration 0)."""
    x = embed_inputs(params, cfg, batch)
    B, S, d = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    head, body, tail = stack_segments(cfg)
    aux_total = jnp.float32(0.0)
    constrain = act_shard if act_shard is not None else (lambda t: t)

    for i, li in enumerate(head):
        x, aux = apply_block(params["head_layers"][i], cfg, cfg.block_kind(li),
                             x, positions, layer_is_moe=False,
                             q_chunk=q_chunk, kv_chunk=kv_chunk,
                             causal_skip=causal_skip, moe_fn=moe_fn,
                             attn_remat=attn_remat, flash_cv=flash_cv)
        x = constrain(x)
        aux_total += aux

    if body:
        kinds = [cfg.block_kind(li) for li in body[0]]
        moe_flags = [cfg.is_moe and li >= cfg.first_dense_layers
                     for li in body[0]]

        def period_fn(x, period_params):
            aux_p = jnp.float32(0.0)
            for j, kind in enumerate(kinds):
                x, aux = apply_block(period_params[j], cfg, kind, x, positions,
                                     layer_is_moe=moe_flags[j],
                                     q_chunk=q_chunk, kv_chunk=kv_chunk,
                                     causal_skip=causal_skip, moe_fn=moe_fn,
                                     attn_remat=attn_remat, flash_cv=flash_cv)
                x = constrain(x)
                aux_p += aux
            return x, aux_p

        if remat:
            period_fn = jax.checkpoint(period_fn)

        def scan_body(carry, period_params):
            x, aux_acc = carry
            x, aux_p = period_fn(x, period_params)
            return (x, aux_acc + aux_p), None

        (x, aux_total), _ = jax.lax.scan(
            scan_body, (x, aux_total), params["body"])

    for i, li in enumerate(tail):
        x, aux = apply_block(params["tail_layers"][i], cfg, cfg.block_kind(li),
                             x, positions,
                             layer_is_moe=cfg.is_moe and li >= cfg.first_dense_layers,
                             q_chunk=q_chunk, kv_chunk=kv_chunk,
                             causal_skip=causal_skip, moe_fn=moe_fn,
                             attn_remat=attn_remat, flash_cv=flash_cv)
        x = constrain(x)
        aux_total += aux

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w_out = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w_out.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if logit_shard is not None:
        logits = logit_shard(logits)
    return logits, aux_total
