from .config import ModelConfig
from . import layers, transformer, xlstm, rglru

__all__ = ["ModelConfig", "layers", "transformer", "xlstm", "rglru"]
