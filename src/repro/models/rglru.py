"""RG-LRU recurrent block (RecurrentGemma / Griffin, De et al., 2024).

The Real-Gated Linear Recurrent Unit is a *diagonal* linear recurrence

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(Lambda) * r_t),  r_t, i_t input-dependent gates,

which we evaluate with ``jax.lax.associative_scan`` during training/prefill —
O(log S) depth, fully parallel across the sequence (the TPU-native
formulation; the original GPU implementation uses a custom linear-scan
kernel) — and as a single fused step during decode.  State is O(1) in
sequence length.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .layers import rms_norm
from .xlstm import _causal_conv1d

_C = 8.0  # the paper's fixed gate sharpness


def rglru_init(key, d_model: int, width: int, conv_width: int = 4,
               dtype=jnp.bfloat16) -> Dict:
    k = jax.random.split(key, 6)
    s = lambda i, *sh: (0.02 * jax.random.normal(k[i], sh, jnp.float32))
    # Lambda init so a^(1/c) is uniform in [0.9, 0.999] (paper appendix)
    u = jax.random.uniform(k[5], (width,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u)))      # inverse softplus
    return {
        "norm": jnp.zeros(d_model, jnp.float32),
        "w_in": s(0, d_model, width).astype(dtype),
        "w_gate_branch": s(1, d_model, width).astype(dtype),
        "conv_w": 0.1 * jax.random.normal(k[2], (conv_width, width), jnp.float32),
        "w_rgate": s(3, width, width).astype(dtype),   # r_t gate
        "w_igate": s(4, width, width).astype(dtype),   # i_t gate
        "lam": lam,
        "w_out": s(5, width, d_model).astype(dtype),
    }


def rglru_state_init(batch: int, width: int, conv_width: int = 4):
    return {
        "h": jnp.zeros((batch, width), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, width), jnp.bfloat16),
    }


def _gates(params, xc):
    r = jax.nn.sigmoid(xc.astype(jnp.float32) @ params["w_rgate"].astype(jnp.float32))
    i = jax.nn.sigmoid(xc.astype(jnp.float32) @ params["w_igate"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * xc.astype(jnp.float32))
    return a, gated_x


def rglru_apply(params, x, state=None):
    """x [B,S,d]; returns (y [B,S,d], new_state).  Parallel associative scan
    over S for S > 1; exact single step for S == 1 (decode)."""
    B, S, d = x.shape
    width = params["w_in"].shape[1]
    if state is None:
        state = rglru_state_init(B, width, params["conv_w"].shape[0])
    xn = rms_norm(x, params["norm"])
    xi = xn @ params["w_in"]                          # [B,S,w]
    xg = jax.nn.gelu(xn @ params["w_gate_branch"])    # gate branch
    xc, conv_cache = _causal_conv1d(xi, params["conv_w"], state["conv"])
    a, gx = _gates(params, xc)                        # [B,S,w] f32

    if S == 1:
        h = a[:, 0] * state["h"] + gx[:, 0]
        hs = h[:, None]
    else:
        # fold the carried-in state into the first element, then assoc-scan
        gx = gx.at[:, 0].add(a[:, 0] * state["h"])

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        _, hs = jax.lax.associative_scan(combine, (a, gx), axis=1)
        h = hs[:, -1]

    y = (hs.astype(x.dtype) * xg) @ params["w_out"]
    return x + y, {"h": h, "conv": conv_cache.astype(jnp.bfloat16)}
