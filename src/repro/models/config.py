"""Model configuration for every supported architecture family.

One ``ModelConfig`` describes a decoder-only backbone with per-family
extensions (MoE, xLSTM, RG-LRU hybrid, modality-frontend stubs).  The ten
assigned architectures instantiate these in ``repro.configs.<id>``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

FAMILY_DENSE = "dense"
FAMILY_MOE = "moe"
FAMILY_AUDIO = "audio"     # decoder-only over codec tokens; frontend stub
FAMILY_VLM = "vlm"         # text backbone + patch-embedding stub
FAMILY_SSM = "ssm"         # xLSTM (sLSTM + mLSTM blocks)
FAMILY_HYBRID = "hybrid"   # RG-LRU + local attention (RecurrentGemma)

# per-block kinds (the layer stack is a repeating pattern of these)
BLOCK_ATTN = "attn"            # global causal attention + FFN
BLOCK_LOCAL_ATTN = "local"     # sliding-window attention + FFN
BLOCK_RECURRENT = "rglru"      # RG-LRU recurrent block + FFN
BLOCK_MLSTM = "mlstm"          # xLSTM mLSTM block (self-contained)
BLOCK_SLSTM = "slstm"          # xLSTM sLSTM block (self-contained)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None        # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0                  # per-expert FFN width
    first_dense_layers: int = 0           # leading dense layers (DeepSeek)
    dense_d_ff: int = 0                   # FFN width of those dense layers
    capacity_factor: float = 1.25
    # --- hybrid / recurrent ---
    block_pattern: Tuple[str, ...] = ()   # repeating pattern; () -> all attn
    local_window: int = 2048              # sliding-window size for BLOCK_LOCAL_ATTN
    mlstm_chunk: int = 0                  # 0 = exact sequential scan;
                                          # T>0 = exact chunkwise-parallel (§Perf-A)
    lru_width: int = 0                    # RG-LRU state width (0 -> d_model)
    conv1d_width: int = 4                 # temporal conv in recurrent blocks
    # --- modality frontend stubs ---
    frontend_tokens: int = 0              # image/audio positions provided as
                                          # precomputed embeddings by input_specs()
    d_frontend: int = 0                   # width of precomputed frontend embeds
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def frontend_dim(self) -> int:
        """Width of the precomputed frontend embeddings (stub input)."""
        if self.d_frontend:
            return self.d_frontend
        return {FAMILY_AUDIO: 128, FAMILY_VLM: 1024}.get(self.family, 0)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def block_kind(self, layer: int) -> str:
        if self.block_pattern:
            return self.block_pattern[layer % len(self.block_pattern)]
        return BLOCK_ATTN

    @property
    def attention_free(self) -> bool:
        """True when no block uses global attention (sub-quadratic models)."""
        kinds = {self.block_kind(i) for i in range(self.n_layers)}
        return BLOCK_ATTN not in kinds

    def n_params(self) -> int:
        """Total parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.hd
        H, Hkv = self.n_heads, self.n_kv_heads
        total = self.vocab * d                      # embedding
        if not self.tie_embeddings:
            total += d * self.vocab                 # lm head
        for i in range(self.n_layers):
            kind = self.block_kind(i)
            if kind in (BLOCK_ATTN, BLOCK_LOCAL_ATTN):
                qkv = d * H * hd + 2 * d * Hkv * hd + H * hd * d
                if self.qkv_bias:
                    qkv += (H + 2 * Hkv) * hd
                total += qkv
                total += self._ffn_params(i)
                total += 2 * d                      # norms
            elif kind == BLOCK_RECURRENT:
                w = self.lru_width or d
                total += d * w * 2 + w * d + 2 * w  # in/gate proj, out, lru params
                total += self.conv1d_width * w
                total += self._ffn_params(i) + 2 * d
            elif kind == BLOCK_MLSTM:
                # up-proj x2 (gate), qkv projections in up space, down-proj
                up = 2 * d
                total += d * up * 2 + up * d + 3 * up * up // 4 + 3 * up + d
            elif kind == BLOCK_SLSTM:
                total += 4 * d * d + 4 * d * d + 8 * d + d  # i,f,z,o recurrent
        total += d                                  # final norm
        return total

    def n_active_params(self) -> int:
        """Params touched per token (= total for dense; routed subset for MoE)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        total = self.n_params()
        # subtract inactive routed experts
        per_expert = 3 * d * self.expert_d_ff
        n_moe_layers = self.n_layers - self.first_dense_layers
        inactive = (self.n_experts - self.top_k) * per_expert * n_moe_layers
        return total - inactive

    def _ffn_params(self, layer: int) -> int:
        d = self.d_model
        if self.is_moe and layer >= self.first_dense_layers:
            routed = self.n_experts * 3 * d * self.expert_d_ff
            shared = self.n_shared_experts * 3 * d * self.expert_d_ff
            router = d * self.n_experts
            return routed + shared + router
        ff = self.dense_d_ff if (self.is_moe and self.dense_d_ff) else self.d_ff
        if ff == 0:
            return 0
        return 3 * d * ff  # SwiGLU: gate + up + down
