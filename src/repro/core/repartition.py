"""Workload-aware repartitioning — close the profile -> partitioner loop.

The paper's central claim is that partition characteristics and query
properties must be co-designed: a cut that is optimal for the topology can
still be terrible for the *workload*, because answers that span partitions
force extra loads no heuristic can avoid (Sec. 1, Fig. 4c).  WawPart
(arXiv:2203.14888) closes that gap by repartitioning against observed
traffic; Averbuch & Neumann (arXiv:1301.5121) supply the metric frame —
edge-cut alone vs. query locality — our benchmark table reports.

This module consumes the workload profile a ``GraphSession`` accumulates
(``session.workload_profile()`` / the JSON from ``save_profile()``) and
produces a new vertex assignment by *reweighting* the graph's edges and
re-running the existing multilevel partitioner (``partition_graph``) on
the weighted graph:

  co-traversal pull — the profile's ``answer_spans`` block records how
      often each vertex was bound in a partition-spanning answer
      (``vertex_span_counts``) and, per partition pair, how many answers
      spanned it (``pair_counts``).  A boundary edge whose BOTH endpoints
      were bound in spanning answers gets its weight pulled up
      proportionally, so heavy-edge matching contracts exactly the
      answers' own boundary edges and the new cut routes around them —
      hot spanning structures co-locate while unrelated cut edges stay
      cheap to keep cutting.

  split pressure — partitions with a high share of observed loads and a
      low completion rate (lots of spawning, little finishing) are doing
      spanning work the layout should not preserve.  Their *internal*
      edges keep the minimum weight while calmer partitions' interiors get
      a small cohesion bonus, leaving the partitioner freest to cut
      through exactly the regions the workload says are mis-shaped.

The result is registered under the scheme name ``"waw"`` (knobs below):
``PartitionedGraph.scheme`` / ``RunStats.scheme`` report it, and
``GraphSession.repartition()`` rebuilds a live session against it.

MapReduceMP profiles carry ``partition_counters_observed: false`` (one
compiled program, no host loop): load/completion counters are structurally
zero there, so split pressure is skipped and only the co-traversal term —
which the session observes host-side for every engine — is applied.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Union

import numpy as np

from .graph import Graph, PartitionedGraph, build_partitions
from .partition import PartitionScheme, partition_graph

# The multilevel knobs the reweighted re-run uses (METIS-style kway +
# SHEM, 2 FM rounds — the paper's strongest all-round configuration).
# Deliberately NOT in partition.SCHEMES: "waw" is derived from a profile,
# so sweeping it without weights would just duplicate kway_shem.
WAW_SCHEME = PartitionScheme("waw", "shem", "kway", 2, seed=17)


@dataclasses.dataclass(frozen=True)
class RepartitionConfig:
    """Gains mapping profile observations onto integer edge weights.

    ``boundary_gain`` scales the co-traversal pull: the hottest partition
    pair's boundary edges get weight ``1 + boundary_gain``, colder pairs
    proportionally less.  It must dominate ``cohesion_gain`` (and the unit
    base weight) so heavy-edge matching grabs hot boundary edges first.
    ``cohesion_gain`` scales the stability bonus for interiors of
    partitions the workload is happy with (low split pressure).
    """

    boundary_gain: int = 16
    cohesion_gain: int = 2
    scheme: PartitionScheme = WAW_SCHEME

    def __post_init__(self):
        if self.boundary_gain < 1:
            raise ValueError("boundary_gain must be >= 1")
        if self.cohesion_gain < 0:
            raise ValueError("cohesion_gain must be >= 0")


Profile = Union[str, Dict[str, Any]]


def load_profile(profile: Profile) -> Dict[str, Any]:
    """Accept a ``workload_profile()`` dict or a ``save_profile()`` path."""
    if isinstance(profile, str):
        with open(profile) as f:
            profile = json.load(f)
    if not isinstance(profile, dict) or "partitions" not in profile:
        raise ValueError("not a workload profile (missing 'partitions'); "
                         "expected GraphSession.workload_profile() output")
    return profile


def _profile_assignment(profile: Dict[str, Any], graph: Graph,
                        assignment: Optional[np.ndarray]) -> np.ndarray:
    """The [V] assignment the profile's counters were observed under.

    Saved profiles embed it (``profile["assignment"]``) so a JSON file is
    self-contained; a live caller may pass its own instead.
    """
    if assignment is None:
        emb = profile.get("assignment")
        if emb is None:
            raise ValueError(
                "profile has no embedded 'assignment' and none was passed; "
                "re-save it with GraphSession.save_profile() or supply "
                "assignment= explicitly")
        assignment = np.asarray(emb, dtype=np.int32)
    assignment = np.asarray(assignment, dtype=np.int32)
    if assignment.shape != (graph.n_nodes,):
        raise ValueError(f"assignment shape {assignment.shape} does not "
                         f"match graph ({graph.n_nodes} nodes)")
    return assignment


def reweight_edges(graph: Graph, assignment: np.ndarray,
                   profile: Dict[str, Any],
                   config: RepartitionConfig = RepartitionConfig()
                   ) -> np.ndarray:
    """[E] integer weights encoding the profile's verdict on the layout."""
    k = int(profile["k"])
    if assignment.size and int(assignment.max()) >= k:
        raise ValueError(f"assignment uses partition ids >= profile k={k}")
    E = graph.n_edges
    w = np.ones(E, dtype=np.int64)
    pu = assignment[graph.edge_src]
    pv = assignment[graph.edge_dst]
    cross = pu != pv

    # -- co-traversal pull on boundary edges -------------------------------
    # Primary signal: per-vertex spanning-answer counts.  An edge is pulled
    # up only when BOTH endpoints were bound in partition-spanning answers
    # (min-combine) — that is the answers' own boundary, not every edge
    # that happens to cross a hot partition pair, so unrelated background
    # cut edges keep weight 1 and the new cut is free to go through them.
    spans = profile.get("answer_spans") or {}
    vsc = spans.get("vertex_span_counts")
    if vsc is not None and cross.any():
        vsc = np.asarray(vsc, dtype=np.float64)
        if vsc.shape != (graph.n_nodes,):
            raise ValueError(f"vertex_span_counts length {vsc.shape} != "
                             f"V ({graph.n_nodes})")
        hot = np.minimum(vsc[graph.edge_src], vsc[graph.edge_dst])
        hot[~cross] = 0.0
        peak = hot.max()
        if peak > 0:
            w[cross] += np.round(
                config.boundary_gain * hot[cross] / peak).astype(np.int64)
    else:
        # coarse fallback for pre-vertex-count profiles: pull up every edge
        # crossing a frequently co-spanned partition pair
        pairs = np.asarray(spans.get("pair_counts", np.zeros((k, k))),
                           dtype=np.float64)
        if pairs.shape != (k, k):
            raise ValueError(f"pair_counts shape {pairs.shape} != ({k}, {k})")
        co = pairs.copy()
        np.fill_diagonal(co, 0.0)      # diagonal = within-partition answers
        peak = co.max()
        if peak > 0 and cross.any():
            share = co[pu[cross], pv[cross]] / peak
            w[cross] += np.round(config.boundary_gain * share).astype(np.int64)

    # -- split pressure on partition interiors -----------------------------
    # only meaningful when the engine actually observed per-partition
    # load/yield counters (not MapReduceMP's compiled whole-job run)
    if profile.get("partition_counters_observed", True) and config.cohesion_gain:
        loads = np.zeros(k, dtype=np.float64)
        rates = np.full(k, 0.5, dtype=np.float64)
        for p in profile["partitions"]:
            loads[int(p["pid"])] = float(p.get("loads", 0))
            rates[int(p["pid"])] = float(p.get("completion_rate", 0.5))
        if loads.sum() > 0:
            load_share = loads / loads.sum()
            pressure = load_share * (1.0 - rates)       # in [0, 1]
            top = pressure.max()
            if top > 0:
                calm = 1.0 - pressure / top             # 0 = most pressured
                bonus = np.round(config.cohesion_gain * calm[pu]).astype(np.int64)
                w[~cross] += bonus[~cross]
    return w


def repartition_assignment(graph: Graph, profile: Profile, *,
                           assignment: Optional[np.ndarray] = None,
                           k: Optional[int] = None,
                           seed: Optional[int] = None,
                           config: RepartitionConfig = RepartitionConfig()
                           ) -> np.ndarray:
    """Profile -> reweighted graph -> multilevel re-run -> new [V] assignment.

    Deterministic for a fixed (profile, seed): the reweighting is pure
    arithmetic and ``partition_graph`` seeds its own rng from the scheme.
    """
    prof = load_profile(profile)
    base = _profile_assignment(prof, graph, assignment)
    kk = int(k if k is not None else prof["k"])
    w = reweight_edges(graph, base, prof, config)
    return partition_graph(graph, kk, config.scheme, seed=seed,
                           edge_weights=w)


def repartition(pg: PartitionedGraph, profile: Profile, *,
                seed: Optional[int] = None,
                config: RepartitionConfig = RepartitionConfig()
                ) -> PartitionedGraph:
    """Rebuild a ``PartitionedGraph`` under the workload-aware assignment
    (scheme name ``"waw"``), same k and padding discipline as the input.

    The reweighting runs against the assignment the profile's counters
    were OBSERVED under — the embedded ``profile["assignment"]`` when
    present (its length doubles as the graph-identity check), falling back
    to ``pg.assignment`` only for older profiles without one.  Using the
    current layout for a profile observed under a different one would pull
    up the wrong boundary edges.
    """
    prof = load_profile(profile)
    fallback = None if prof.get("assignment") is not None else pg.assignment
    assign = repartition_assignment(pg.graph, prof,
                                    assignment=fallback, k=pg.k,
                                    seed=seed, config=config)
    return build_partitions(pg.graph, assign, pg.k, scheme=config.scheme.name)


def answer_span_matrix(owner: np.ndarray, rows: np.ndarray, k: int
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Per-answer partition spans from bound vertex ids.

    ``rows`` is [n, q_pad] of global vertex ids (-1 = unbound slot);
    returns ``(pair_counts [k, k], span [n])`` where ``pair_counts[p, q]``
    (p != q) counts answer rows binding vertices in both p and q,
    ``pair_counts[p, p]`` counts rows touching p at all, and ``span[i]`` is
    the number of distinct partitions answer i's bindings live in.  This is
    the co-traversal signal ``reweight_edges`` consumes — observed
    host-side from the answers themselves, so it exists for every engine
    (including MapReduceMP, which has no per-partition load counters).
    """
    n = int(rows.shape[0])
    if n == 0:
        return np.zeros((k, k), dtype=np.int64), np.zeros(0, dtype=np.int64)
    mask = rows >= 0
    pids = owner[np.clip(rows, 0, None)]
    present = np.zeros((n, k), dtype=bool)
    ri = np.broadcast_to(np.arange(n)[:, None], rows.shape)
    present[ri[mask], pids[mask]] = True
    pi = present.astype(np.int64)
    return pi.T @ pi, pi.sum(axis=1)
