"""Quantitative measures for evaluating the heuristics (paper Sec. 5.3).

  load ratio          = L_ideal / AL_h            (<= 1; higher is better)
  h(D)^{query}_{pschemes} = mean load ratio of one query across schemes
  h(D)^{pscheme}_{qbatch} = mean load ratio of a query batch on one scheme

L_ideal is the number of *required* partitions — the paper's Sec. 1
definition: "A required partition is one in which one or more of the query
plan node exists", i.e. partitions containing at least one node matching
ANY query-node predicate (wildcard nodes make every non-empty partition
required).  The paper notes this static count is the usable proxy for the
run-time-only exact bound; the ratio is clipped at 1 ("this value is at
best 1") since no-answer queries can terminate before touching every
required partition.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from .graph import PartitionedGraph
from .plan import Plan


@dataclasses.dataclass
class RunStats:
    """Per-(query, scheme, heuristic) execution record."""

    query: str
    scheme: str
    heuristic: str
    loads: List[int]                  # sequence of partition loads
    l_ideal: int
    n_answers: int
    iterations: int = 0               # MP engines: #parallel iterations
    answers_requested: Optional[int] = None   # K of an answer-budget run
    loads_saved_vs_full: Optional[int] = None # full-run loads minus this
                                              # run's (benchmark-filled)
    # PartitionStore residency accounting for this run (core/store.py):
    # a cold load paid a host->device transfer on the critical path, a warm
    # load reused device-resident buffers, a prefetch hit was a transfer
    # that overlapped the previous partition's evaluation.  None when the
    # engine ran without a store (never, since PR 2 — kept Optional so
    # hand-built RunStats in tests/benchmarks stay valid).
    cold_loads: Optional[int] = None
    warm_loads: Optional[int] = None
    prefetch_hits: Optional[int] = None
    # out-of-core (disk-backed) residency for this run: shard reads the
    # store's host tier issued against disk, and how many host gets were
    # served by a background read-ahead instead of a blocking demand read.
    # Zero for in-RAM sessions; None on hand-built RunStats.
    disk_reads: Optional[int] = None
    read_ahead_hits: Optional[int] = None
    # byte flows for this run (PartitionStore / host tier accounting):
    # bytes_cold moved host->device on the critical path, bytes_prefetched
    # moved off it, bytes_disk came off the disk tier (demand + read-ahead),
    # bytes_host were served out of the host LRU to device staging.  None on
    # hand-built RunStats; engines fill them from the store-stats delta.
    bytes_cold: Optional[int] = None
    bytes_prefetched: Optional[int] = None
    bytes_disk: Optional[int] = None
    bytes_host: Optional[int] = None
    # streaming updates (storage/deltas.py): the graph generation this run
    # was pinned to — every load above resolved against that generation's
    # snapshot, even if a compaction published a newer one mid-run.  None
    # for in-RAM sessions (no generations) and hand-built RunStats.
    generation: Optional[int] = None

    @property
    def n_loads(self) -> int:
        return len(self.loads)

    @property
    def load_ratio(self) -> float:
        if self.n_loads == 0:
            return 1.0
        return min(1.0, self.l_ideal / self.n_loads)


def validate_run_residency(stats: RunStats,
                           per_partition_loads: bool = True
                           ) -> Optional[dict]:
    """Consistency invariant over a run's residency counters: every load
    in ``loads`` was served exactly once by one residency class, so
    ``cold_loads + demand_warm + prefetch_hits == n_loads`` (the store
    counts a prefetch hit as a *kind* of warm load, so ``demand_warm`` is
    ``warm_loads - prefetch_hits``).

    Returns ``None`` when the run carries no residency counters
    (hand-built ``RunStats``); otherwise the disjoint breakdown dict from
    ``obs.metrics.validate_residency``.  Raises ``ValueError`` on
    inconsistent accounting — a store double-count or a load path that
    skipped the counters.

    ``per_partition_loads=False`` skips the ``n_loads`` equality and only
    checks the counters' internal consistency: TraditionalMP's store load
    unit is the stacked top-p bundle (one get per iteration, p entries in
    ``loads``) and MapReduceMP keeps every partition resident
    (``loads == []``), so for those engines the equality doesn't apply.

    When the run also carries byte counters (PR 10 memory accounting),
    they are cross-checked against the load counts: a residency class
    with loads must have moved bytes and vice versa (cold_loads > 0 iff
    bytes_cold > 0, disk_reads > 0 iff bytes_disk > 0, ...) — partitions
    are padded arrays, so a zero-byte load means a counter path was
    skipped.  Byte fields left ``None`` are not checked.
    """
    if stats.cold_loads is None or stats.warm_loads is None \
            or stats.prefetch_hits is None:
        return None
    from ..obs.metrics import validate_residency
    if per_partition_loads:
        out = validate_residency(stats.cold_loads, stats.warm_loads,
                                 stats.prefetch_hits, stats.n_loads)
    else:
        out = validate_residency(stats.cold_loads, stats.warm_loads,
                                 stats.prefetch_hits,
                                 stats.cold_loads + stats.warm_loads)
    byte_checks = (
        ("cold_loads", stats.cold_loads, "bytes_cold", stats.bytes_cold),
        ("disk_reads", stats.disk_reads, "bytes_disk", stats.bytes_disk),
    )
    for cname, count, bname, nbytes in byte_checks:
        if count is None or nbytes is None:
            continue
        if int(nbytes) < 0:
            raise ValueError(f"negative byte counter: {bname}={nbytes}")
        if (int(count) > 0) != (int(nbytes) > 0):
            raise ValueError(
                f"{cname}={count} but {bname}={nbytes}: a residency "
                f"class with loads must have moved bytes (and vice "
                f"versa) — a byte-accounting path was skipped")
        out[bname] = int(nbytes)
    for bname, nbytes in (("bytes_prefetched", stats.bytes_prefetched),
                          ("bytes_host", stats.bytes_host)):
        if nbytes is None:
            continue
        if int(nbytes) < 0:
            raise ValueError(f"negative byte counter: {bname}={nbytes}")
        out[bname] = int(nbytes)
    return out


def l_ideal_for_plan(pg: PartitionedGraph, plan: Plan) -> int:
    """#required partitions: any partition holding a node that matches any
    query-node predicate (paper Sec. 1 / 5.3)."""
    from .query import OP_BY_NAME
    from .graph import WILDCARD
    q = plan.query
    g = pg.graph
    required = np.zeros(pg.k, dtype=bool)
    for qn in q.nodes:
        lid = WILDCARD if qn.label == "?" else g.node_vocab.get(qn.label, -3)
        counts = pg.start_label_counts(lid, OP_BY_NAME[qn.value_op],
                                       float(qn.value))
        required |= counts > 0
    return int(required.sum())


def avg_load_ratio_across_schemes(stats: Sequence[RunStats], query: str,
                                  heuristic: str) -> float:
    """h(D)^{query}_{pschemes} (Table 3)."""
    vals = [s.load_ratio for s in stats
            if s.query == query and s.heuristic == heuristic]
    return float(np.mean(vals)) if vals else float("nan")


def avg_load_ratio_for_batch(stats: Sequence[RunStats], scheme: str,
                             heuristic: str) -> float:
    """h(D)^{pscheme}_{qbatch} (Tables 4, 5)."""
    vals = [s.load_ratio for s in stats
            if s.scheme == scheme and s.heuristic == heuristic]
    return float(np.mean(vals)) if vals else float("nan")


def total_connected_components(pg: PartitionedGraph) -> int:
    return int(pg.connected_components_per_partition().sum())
