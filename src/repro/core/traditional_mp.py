"""TraditionalMP — parallel partition processing with p processors
(paper Sec. 8, Algorithm 1).

Identical bookkeeping to OPAT; the difference is the *set* of partitions
chosen per iteration (top-p under the heuristic) and their parallel
execution.  On real hardware each chosen partition maps to one device; here
the chosen partitions are evaluated with ``jax.vmap`` over stacked partition
arrays — the same compiled program OPAT uses, batched — which is exactly the
semantics of p identical processors executing PGQP independently
(Algorithm 1 lines 6-8).  IMA merging order does not matter (line 9), so the
host merge loop is order-insensitive.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import numpy as np

from .engine import EngineConfig, make_partition_evaluator
from .graph import PartitionedGraph
from .heuristics import MAX_YIELD, choose_top_p
from .metrics import RunStats, l_ideal_for_plan
from .plan import Plan, PlanArrays
from .runner import RunReport, RunRequest, truncate_answers
from .state import BindingBatch, QueryState
from .store import PartitionStore


@dataclasses.dataclass
class TraditionalMPResult:
    answers: np.ndarray
    stats: RunStats
    state: QueryState
    partitions_per_iteration: List[List[int]]


class TraditionalMPEngine:
    """``store`` defaults to a private unbounded ``PartitionStore``; its
    load unit is the *stacked* top-p bundle one iteration ships to the p
    processors, so a recurring top-p set is a warm load."""

    def __init__(self, pg: PartitionedGraph, n_processors: int,
                 cfg: Optional[EngineConfig] = None,
                 store: Optional[PartitionStore] = None,
                 tracer=None,
                 profiler=None):
        assert n_processors >= 1
        self.pg = pg
        self.p = n_processors
        self.cfg = cfg or EngineConfig()
        self._eval = make_partition_evaluator(pg.node_pad, pg.ell_width,
                                              self.cfg)
        # vmapped over (partition arrays, g2l row, inputs); plan broadcast
        self._veval = jax.jit(jax.vmap(
            self._eval, in_axes=(0, 0, None, None, None, 0, 0, 0, 0)))
        self._seval = None       # lazy: the queries x partitions double-vmap
        self.store = store if store is not None else PartitionStore(pg)
        from ..obs.trace import NULL_TRACER
        self.tracer = tracer if tracer is not None else NULL_TRACER
        from ..obs.profile import NULL_PROFILER
        self.profiler = profiler if profiler is not None else NULL_PROFILER
        self._eval_traced = False

    def shared_evaluator(self):
        """The *stacked top-p, multi-query* evaluator: ``vmap`` over the
        query axis wrapped around this engine's per-query partition-vmap —
        one compiled call evaluates B stacked plans against the same p
        stacked partitions (inputs [B, p, ...]; partition arrays and the
        owner map broadcast across queries, each query keeps its own plan,
        n_steps, per-lane IMA rows, and seed flags).  This is how the
        ``QueryScheduler`` shares one top-p load across every waiting
        query (core/scheduler.py): the paper's p processors each advance
        the whole workload, not one query.  Built lazily — per-query
        serving never pays the extra trace."""
        if self._seval is None:
            self._seval = jax.jit(jax.vmap(
                jax.vmap(self._eval,
                         in_axes=(0, 0, None, None, None, 0, 0, 0, 0)),
                in_axes=(None, None, None, 0, 0, 0, 0, 0, 0)))
        return self._seval

    def run(self, plan: Plan, heuristic: str, seed: int = 0,
            max_iterations: Optional[int] = None,
            max_answers: Optional[int] = None) -> TraditionalMPResult:
        cfg = self.cfg
        assert plan.n_slots <= cfg.q_pad and plan.n_steps <= cfg.s_pad
        rng = np.random.default_rng(seed)
        plan_arrays = PlanArrays.from_plan(plan, pad_steps=cfg.s_pad)
        counts = self.pg.start_label_counts(plan.start_label,
                                            plan.start_value_op,
                                            plan.start_value)
        st = QueryState.initial(self.pg.k, cfg.q_pad, counts,
                                track_answer_keys=max_answers is not None)
        limit = max_iterations if max_iterations is not None else 64 * self.pg.k
        per_iter: List[List[int]] = []
        load0 = self.store.stats.copy()

        # budget check after each top-p merge (and before the first load:
        # a K=0 request does no work)
        while not st.budget_met(max_answers):
            eligible = st.eligible()
            if not eligible:
                break
            if st.iterations >= limit:
                raise RuntimeError("TraditionalMP exceeded max iterations")
            sni = {p: st.sni_count(p) for p in eligible}
            rates = (st.completion_rates() if heuristic == MAX_YIELD
                     else None)
            chosen = choose_top_p(heuristic, eligible, sni, self.p, rng,
                                  rates, tracer=self.tracer)
            per_iter.append(list(chosen))
            st.iterations += 1
            # process the set in sorted order: which processor runs which
            # partition is arbitrary (Algorithm 1 lines 6-8), and a
            # canonical order — including the chosen[0] padding below —
            # makes the stacked store key permutation-invariant, so
            # heuristic tie-break order never forces a cold re-stage of
            # the same top-p set
            chosen = sorted(chosen)

            # pad the chosen set to exactly p so the vmapped evaluator keeps a
            # single compiled shape (padding entries are no-ops: empty input,
            # no fresh seeding) — idle processors in the paper's terms.
            exec_set = list(chosen) + [chosen[0]] * (self.p - len(chosen))
            batches: List[BindingBatch] = []
            seeds: List[bool] = []
            is_real: List[bool] = [True] * len(chosen) + [False] * (self.p - len(chosen))
            for pid in chosen:
                st.loads.append(pid)
                b = st.ima[pid]
                st.ima[pid] = BindingBatch.empty(cfg.q_pad)
                if b.n > cfg.cap:
                    # keep the tail for a later iteration of the same partition
                    st.ima[pid] = BindingBatch(rows=b.rows[cfg.cap:],
                                               step=b.step[cfg.cap:])
                    b = BindingBatch(rows=b.rows[: cfg.cap],
                                     step=b.step[: cfg.cap])
                batches.append(b)
                seeds.append(bool(st.fresh_pending[pid]))
                st.fresh_pending[pid] = False
            while len(batches) < self.p:
                batches.append(BindingBatch.empty(cfg.q_pad))
                seeds.append(False)

            # canonicalize lane order: IMA merging is order-insensitive
            # (Algorithm 1 line 9), so which vmap lane runs which partition
            # doesn't matter — sorting collapses permutations of the same
            # top-p set onto one stacked store entry (warm across
            # iterations regardless of heuristic tie-break order)
            lanes = sorted(zip(exec_set, batches, seeds, is_real),
                           key=lambda t: t[0])
            exec_set = [t[0] for t in lanes]
            batches = [t[1] for t in lanes]
            seeds = [t[2] for t in lanes]
            is_real = [t[3] for t in lanes]

            n = self.p
            in_rows = np.full((n, cfg.cap, cfg.q_pad), -1, dtype=np.int32)
            in_step = np.zeros((n, cfg.cap), dtype=np.int32)
            in_valid = np.zeros((n, cfg.cap), dtype=bool)
            for i, b in enumerate(batches):
                if b.n:
                    in_rows[i, : b.n] = b.rows
                    in_step[i, : b.n] = b.step
                    in_valid[i, : b.n] = True

            with self.tracer.span("engine.iteration", engine="traditional",
                                  pids=list(map(int, exec_set)),
                                  iteration=st.iterations):
                entry = self.store.get_stacked(tuple(exec_set))
                with self.tracer.span("kernel.eval", engine="traditional",
                                      pids=list(map(int, exec_set))) as ksp:
                    if not self._eval_traced:
                        self._eval_traced = True
                        ksp.set(first_call=True)
                        self.profiler.attribute_kernel(
                            ("traditional", "veval"), self._veval,
                            entry.part, entry.g2l, self.store.owner,
                            plan_arrays, np.int32(plan.n_steps),
                            in_rows, in_step, in_valid,
                            np.asarray(seeds, dtype=bool))
                        with self.tracer.span("kernel.compile",
                                              engine="traditional"):
                            res = self._veval(entry.part, entry.g2l,
                                              self.store.owner, plan_arrays,
                                              np.int32(plan.n_steps),
                                              in_rows, in_step, in_valid,
                                              np.asarray(seeds, dtype=bool))
                    else:
                        res = self._veval(entry.part, entry.g2l,
                                          self.store.owner, plan_arrays,
                                          np.int32(plan.n_steps),
                                          in_rows, in_step, in_valid,
                                          np.asarray(seeds, dtype=bool))
                    overflow = bool(np.any(np.asarray(res.overflow)))
                    self.profiler.stamp_kernel(ksp, ("traditional", "veval"))
                    self.profiler.sample_device(ksp, self.store)
            if overflow:
                raise RuntimeError("evaluator buffer overflow; raise cap")
            comp_rows = np.asarray(res.comp_rows)
            comp_n = np.asarray(res.comp_n)
            out_rows = np.asarray(res.out_rows)
            out_step = np.asarray(res.out_step)
            out_dest = np.asarray(res.out_dest)
            out_n = np.asarray(res.out_n)
            for i in range(n):  # merge IMA_i -> FAA/IMA (order-insensitive)
                if not is_real[i]:
                    continue
                if comp_n[i]:
                    st.add_answers(comp_rows[i, : comp_n[i]])
                st.observe_yield(exec_set[i], int(comp_n[i]), int(out_n[i]))
                if out_n[i]:
                    orow = out_rows[i, : out_n[i]]
                    ostp = out_step[i, : out_n[i]]
                    odst = out_dest[i, : out_n[i]]
                    for q in range(self.pg.k):
                        sel = odst == q
                        if sel.any():
                            st.ima[q] = st.ima[q].concat(
                                BindingBatch(rows=orow[sel], step=ostp[sel])
                            ).dedup()

        answers = truncate_answers(st.unique_answers(), max_answers)
        delta = self.store.stats - load0
        stats = RunStats(query=plan.query.name, scheme=self.pg.scheme,
                         heuristic=heuristic,
                         loads=list(st.loads),
                         l_ideal=l_ideal_for_plan(self.pg, plan),
                         n_answers=int(answers.shape[0]),
                         iterations=st.iterations,
                         answers_requested=max_answers,
                         cold_loads=delta.cold_loads,
                         warm_loads=delta.warm_loads,
                         prefetch_hits=delta.prefetch_hits,
                         disk_reads=delta.disk_reads,
                         read_ahead_hits=delta.read_ahead_hits,
                         bytes_cold=delta.bytes_cold,
                         bytes_prefetched=delta.bytes_prefetched,
                         bytes_disk=delta.bytes_disk,
                         bytes_host=delta.bytes_host)
        return TraditionalMPResult(answers=answers, stats=stats,
                                   state=st, partitions_per_iteration=per_iter)

    def run_request(self, req: RunRequest) -> RunReport:
        """The shared ``QueryRunner`` protocol (see core/runner.py)."""
        res = self.run(req.plan, req.heuristic, seed=req.seed,
                       max_answers=req.max_answers)
        return RunReport(answers=res.answers, stats=res.stats,
                         engine="traditional",
                         extra={"state": res.state,
                                "partitions_per_iteration":
                                    res.partitions_per_iteration})
