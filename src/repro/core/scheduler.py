"""QueryScheduler — shared-load multi-query OPAT with batched partition
evaluation.

The paper's cost model says response time is dominated by the number and
sequence of partition *loads*, and its heuristics (Sec. 5) optimize that
sequence per query.  A serving deployment has many queries outstanding at
once, and a single device-resident partition can advance all of them —
throughput comes from amortizing data residency across concurrent work
(Fan et al.'s partial evaluation of distributed query fragments; Vaquero
et al.'s near-real-time systems survey), not from optimizing queries in
isolation.  This module is that observation as a subsystem, one layer
between the ``GraphSession`` API and the engines:

  admission    — ``admit()`` expands a (possibly disjunctive) query into
                 per-disjunct *jobs*, each carrying its own plan,
                 ``QueryState`` (SNI/IMA/FAA bookkeeping, identical to the
                 per-query OPAT loop) and ``max_answers`` budget.
  the index    — every round the scheduler derives the partition →
                 waiting-jobs index from the jobs' SNI/IMA eligibility;
                 ``rank_partitions_shared`` (core/heuristics.py) scores
                 each candidate partition by total expected yield summed
                 over every waiting query (MAX-YIELD-SHARED: Σ SNI ×
                 smoothed completion rate), so one cold load services many
                 queries, and the store prefetches the *workload's*
                 runner-up rather than one query's.
  batched eval — the loaded partition evaluates the plans of ALL waiting
                 jobs in one compiled call: stacked ``PlanArrays`` +
                 per-job inputs through ``OPATEngine.batched_evaluator()``
                 (``vmap`` over the query axis, partition broadcast).  The
                 batch is padded up to a power-of-two bucket so the jit
                 cache keeps one trace per bucket, reused across rounds
                 and batch sizes.
  retirement   — a job retires when its budget is met or nothing is
                 eligible; a query retires when all its jobs have.  Retired
                 queries drop out of the index, so their partitions stop
                 being touched and age out of the store's LRU naturally;
                 with ``release_retired=True`` the scheduler additionally
                 ``release()``s partitions no pending job can currently
                 use (observable via ``LoadStats.released``).

Per-query bookkeeping correctness is preserved exactly: each job routes
its evaluator outputs through the same ``absorb_eval_outputs`` as the
one-query-at-a-time loop, so exhaustive answers are bit-identical to
sequential ``GraphSession.submit`` (tests/test_scheduler.py asserts this
for all three engines).  TraditionalMP shares too: each round one stacked
top-p bundle carries EVERY waiting query's inputs through the store and
the engine's double-vmapped ``shared_evaluator()`` — B plans × p
partitions in one compiled call (``_run_shared_tmp``).  MapReduceMP runs
a whole query as one compiled program with no host partition loop to
share, so the scheduler drains its jobs sequentially with unchanged
semantics.

``LoadStats`` attribution is *round-scoped*: ``ScheduleReport.load_stats``
is the store's exact delta over one ``run()`` (what the round cost), and
each ``QueryResult.load_stats`` is that query's participation view — the
sum of the per-load-event deltas for loads its plans took part in (a cold
load shared by three queries appears in each one's view but only once in
the round's).  Interleaved/batched submits therefore never bleed other
queries' store traffic into a result's counters.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, List, Optional, Set, Union

import numpy as np

from .heuristics import MAX_YIELD_SHARED, SHARED_HEURISTICS, \
    rank_partitions_shared
from .metrics import RunStats, l_ideal_for_plan
from .opat import OPATEngine, absorb_eval_outputs
from .plan import Plan, PlanArrays, generate_plan
from .query import DisjunctiveQuery, Query
from .runner import RunReport, RunRequest, truncate_answers
from .session import QueryResult
from .state import BindingBatch, QueryState
from .store import LoadStats
from .traditional_mp import TraditionalMPEngine


def batch_bucket(n: int) -> int:
    """Round a batch size up to the next power of two — the padded batch
    shapes the compiled call sees, so B=5..8 all reuse the B=8 trace."""
    assert n >= 1
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class _Job:
    """One disjunct of one admitted query: a plan plus the same SNI/IMA/FAA
    bookkeeping state the per-query OPAT loop keeps."""

    qid: int
    plan: Plan
    plan_arrays: PlanArrays
    state: QueryState
    max_answers: Optional[int]
    retired: bool = False
    load_stats: LoadStats = dataclasses.field(default_factory=LoadStats)
    report: Optional[RunReport] = None   # sequential fallback: engine-built
    rounds_waiting: int = 0              # consecutive rounds passed over
                                         # (the fairness aging signal)
    urgency: float = 0.0                 # deadline pressure (SLO front end:
                                         # slack-weighted; 0 = no deadline)


@dataclasses.dataclass
class _Admitted:
    """One admitted query: its jobs plus per-query attribution."""

    qid: int
    name: str
    jobs: List[_Job]
    max_answers: Optional[int]
    load_stats: LoadStats = dataclasses.field(default_factory=LoadStats)
    finished_at: Optional[float] = None
    # perf_counter bounds of the query's life in the scheduler — the
    # tracer's timebase, so _collect_results can emit one root "query"
    # span per retired query (admission → retirement) via add_span
    admitted_perf: float = 0.0
    finished_perf: Optional[float] = None


@dataclasses.dataclass
class ScheduleReport:
    """What one ``run()`` round produced: per-query results plus the
    workload-level load sequence and the round-scoped store delta."""

    results: List[QueryResult]   # queries finished this round, admit order
    loads: List[int]             # workload-level partition-load sequence
    batch_sizes: List[int]       # jobs advanced per load (1s when not shared)
    load_stats: LoadStats        # exact store delta over this round
    wall_s: float
    shared: bool                 # True when the shared OPAT path ran

    @property
    def n_loads(self) -> int:
        return len(self.loads)

    @property
    def loads_per_query(self) -> float:
        """Workload loads amortized over the round's queries — the shared
        path's headline metric (one load advancing 4 queries counts once
        here, once per query in each ``QueryResult``)."""
        return self.n_loads / len(self.results) if self.results else 0.0


class QueryScheduler:
    """Admits a batch/stream of queries against one ``GraphSession`` and
    serves them with workload-level load ordering.

    ``heuristic`` is a shared ranking (``SHARED_HEURISTICS``:
    ``max-yield-shared`` default, or ``max-sn`` for the plain summed-SNI
    variant); the per-query heuristic of the session still governs the
    non-OPAT sequential fallback.  ``release_retired`` proactively frees
    store entries no pending job can use when a query retires (off by
    default: a warm entry is only worth dropping under memory pressure).
    ``fairness_gamma`` weights the aging term (rounds-waiting × SNI) in
    the shared ranking — 0 (default) is pure yield; any positive value
    bounds how many rounds a no-overlap query can be passed over under a
    skewed workload (see ``rank_partitions_shared``).
    """

    def __init__(self, session, *, heuristic: str = MAX_YIELD_SHARED,
                 seed: Optional[int] = None,
                 release_retired: bool = False,
                 prefetch: Optional[bool] = None,
                 fairness_gamma: float = 0.0):
        if heuristic not in SHARED_HEURISTICS:
            raise ValueError(f"shared heuristic must be one of "
                             f"{SHARED_HEURISTICS}, got {heuristic!r}")
        if fairness_gamma < 0.0:
            raise ValueError(f"fairness_gamma must be >= 0, "
                             f"got {fairness_gamma}")
        self.session = session
        self.fairness_gamma = float(fairness_gamma)
        self.pg = session.pg
        self.store = session.store
        from ..obs.trace import NULL_TRACER
        self.tracer = getattr(session, "tracer", None) or NULL_TRACER
        from ..obs.profile import NULL_PROFILER
        self.profiler = getattr(session, "profiler", None) or NULL_PROFILER
        # generation pinning (storage/deltas.py): the scheduler takes its
        # OWN pin on the session's current view at construction — every
        # round of every run() resolves loads, SNI counts, and plans
        # against that one generation, even while mutations land and
        # compactions publish newer ones mid-run.  The pin keeps the
        # generation's files out of GC until close().  In-RAM sessions
        # have no view and nothing changes.
        self.view = getattr(session, "current_view", None)
        if self.view is not None:
            self.view.pin()
        self._graph = session.graph
        self._catalog = session.catalog
        self._closed = False
        self.heuristic = heuristic
        self.seed = session.seed if seed is None else seed
        self.release_retired = release_retired
        self.prefetch = (getattr(session.engine, "prefetch", False)
                         if prefetch is None else prefetch)
        # reported queries are pruned after each run(), so a long-lived
        # streaming scheduler holds state proportional to the PENDING set,
        # not to everything it ever served
        self._admitted: Dict[int, _Admitted] = {}
        self._next_qid = 0
        self._jobs: List[_Job] = []
        self._touched: Set[int] = set()   # pids the shared loop ever loaded
        # batch buckets whose vmapped evaluator trace already compiled —
        # the first call per bucket gets a "kernel.compile" child span
        self._traced_buckets: Set[int] = set()
        self.loads: List[int] = []
        self.batch_sizes: List[int] = []

    # -- admission ---------------------------------------------------------

    def admit(self, query: Union[Query, DisjunctiveQuery],
              max_answers: Optional[int] = None,
              urgency: float = 0.0) -> int:
        """Add a query to the pending set; returns its qid.  ``max_answers``
        is the per-disjunct answer budget K, exactly as in ``submit``.
        ``urgency`` is the SLO front end's deadline-pressure weight: every
        partition this query waits on gains ``SNI × urgency`` in the shared
        ranking (0, the default, changes nothing — see
        ``rank_partitions_shared``); update it per round via
        ``set_urgency`` as slack shrinks."""
        self._check_binding()
        session = self.session
        cfg = session.config
        qid = self._next_qid
        self._next_qid += 1
        disjuncts = (query.disjuncts if isinstance(query, DisjunctiveQuery)
                     else [query])
        jobs: List[_Job] = []
        for q in disjuncts:
            # plans and SNI counts come from the scheduler's PINNED
            # binding, not the session's live one — one scheduler, one
            # generation, even for queries admitted after a mutation
            plan = generate_plan(q, self._graph, self._catalog)
            assert plan.n_slots <= cfg.q_pad and plan.n_steps <= cfg.s_pad
            counts = self.pg.start_label_counts(plan.start_label,
                                                plan.start_value_op,
                                                plan.start_value)
            st = QueryState.initial(self.pg.k, cfg.q_pad, counts,
                                    track_answer_keys=max_answers is not None)
            jobs.append(_Job(
                qid=qid, plan=plan,
                plan_arrays=PlanArrays.from_plan(plan, pad_steps=cfg.s_pad),
                state=st, max_answers=max_answers,
                urgency=float(urgency)))
        self._admitted[qid] = _Admitted(qid=qid, name=query.name, jobs=jobs,
                                        max_answers=max_answers,
                                        admitted_perf=time.perf_counter())
        self._jobs.extend(jobs)
        return qid

    def set_urgency(self, qid: int, urgency: float) -> None:
        """Refresh a pending query's deadline pressure (all its jobs); the
        SLO front end calls this each pump as deadlines approach.  Unknown
        (already-reported) qids are ignored — the query no longer ranks."""
        rec = self._admitted.get(qid)
        if rec is not None:
            for j in rec.jobs:
                j.urgency = float(urgency)

    def _check_binding(self) -> None:
        """A scheduler is bound to one session *binding*: its store, layout,
        and SNI counts all name the assignment that existed at construction.
        ``GraphSession.repartition()``/``fold()`` rebind the session (NEW
        store, new pids/paddings), which would silently mix layouts —
        refuse loudly.  Streaming mutations/compactions are fine: they
        keep the store and the scheduler keeps serving its pinned
        generation view (generation-qualified cache keys isolate it from
        newer views sharing the same store)."""
        if self.session.store is not self.store:
            raise RuntimeError(
                "the session was rebound (repartition()/fold()?) after "
                "this scheduler was created; its pending state names the "
                "old layout — create a fresh scheduler via "
                "GraphSession.scheduler()/submit_many()")
        if self._closed:
            raise RuntimeError("this scheduler was close()d — its "
                               "generation pin is gone; create a fresh one")

    def close(self) -> None:
        """Release the scheduler's generation pin (idempotent).  After the
        last pin on a superseded generation goes, the next compaction's GC
        may reclaim that generation's unreferenced files."""
        if not self._closed:
            self._closed = True
            if self.view is not None:
                self.view.release()

    @property
    def n_pending(self) -> int:
        return sum(1 for j in self._jobs if not j.retired)

    def partition_waiters(self) -> Dict[int, List[int]]:
        """The partition → waiting-qids index (observability/tests): which
        pending queries each partition would advance if loaded now."""
        return {p: sorted({j.qid for j in js})
                for p, js in self._waiters().items()}

    # -- the shared-load loop ----------------------------------------------

    def run(self, max_rounds: Optional[int] = None) -> ScheduleReport:
        """Serve every pending job to retirement and return the round's
        report.  Re-entrant: queries admitted after a ``run()`` are served
        (and reported) by the next one.  ``max_rounds`` bounds this call:
        at most that many partition-load rounds on the shared paths (whole
        queries on the sequential fallback), leaving the rest pending —
        the SLO front end pumps with ``max_rounds=1`` so admission and
        urgency updates interleave with serving; None (default) drains
        everything, exactly the pre-existing batch semantics."""
        self._check_binding()
        t0 = time.time()
        stats0 = self.store.stats.copy()
        loads0, batches0 = len(self.loads), len(self.batch_sizes)
        engine = self.session.engine
        shared = isinstance(engine, (OPATEngine, TraditionalMPEngine))
        # every load this call issues resolves against the scheduler's
        # pinned generation, whatever the session's live view is by now
        ctx = (self.store.viewing(self.view) if self.view is not None
               else contextlib.nullcontext())
        with ctx:
            if isinstance(engine, OPATEngine):
                self._run_shared(t0, max_rounds)
            elif isinstance(engine, TraditionalMPEngine):
                self._run_shared_tmp(t0, max_rounds)
            else:
                self._run_sequential(t0, max_rounds)
        report = ScheduleReport(
            results=self._collect_results(t0),
            loads=self.loads[loads0:],
            batch_sizes=self.batch_sizes[batches0:],
            load_stats=self.store.stats - stats0,
            wall_s=time.time() - t0,
            shared=shared)
        return report

    def _run_shared(self, t0: float,
                    max_rounds: Optional[int] = None) -> None:
        engine: OPATEngine = self.session.engine
        beval = engine.batched_evaluator()
        rng = np.random.default_rng(self.seed)
        limit = 64 * self.pg.k * max(1, len(self._jobs))
        rounds = 0
        while True:
            if max_rounds is not None and rounds >= max_rounds:
                break
            self._retire()
            waiters = self._waiters()
            if not waiters:
                break
            if len(self.loads) >= limit:
                raise RuntimeError("scheduler exceeded max partition loads "
                                   f"({limit}); likely a routing bug")
            # score each candidate by every waiter's (SNI, completion
            # rate); a job's rates are partition-indexed but identical
            # across candidates, so compute them once per job per round —
            # and only when the ranking reads them (as in the per-query
            # OPAT loop, which gates rates on MAX-YIELD the same way)
            rates = {}
            if self.heuristic == MAX_YIELD_SHARED:
                for js in waiters.values():
                    for j in js:
                        if id(j) not in rates:
                            rates[id(j)] = j.state.completion_rates()
            scored = {p: [(j.state.sni_count(p),
                           rates[id(j)][p] if rates else 0.0,
                           j.rounds_waiting,
                           j.urgency)
                          for j in js]
                      for p, js in waiters.items()}
            ranked = rank_partitions_shared(
                self.heuristic, scored, rng,
                fairness_gamma=self.fairness_gamma, tracer=self.tracer)
            pid = int(ranked[0])
            batch = waiters[pid]
            with self.tracer.span("scheduler.round", pid=pid, round=rounds,
                                  batch=len(batch),
                                  qids=sorted({j.qid for j in batch})):
                ev0 = self.store.stats.copy()
                entry = self.store.get(pid)
                # the attributable event is the load itself (cold/warm +
                # prefetch hit); snapshot it BEFORE staging the runner-up so
                # a query retiring this round is never charged prefetch
                # traffic for a partition it takes no part in
                event = self.store.stats - ev0
                # double-buffered streaming: pin pid, then stage the
                # WORKLOAD's runner-up while pid evaluates — the shared
                # generalization of OPAT's per-query prefetch; the pin keeps
                # the overlapped H2D copy from evicting the entry the batched
                # evaluator is reading
                with self.store.pinned(pid):
                    if self.prefetch and len(ranked) > 1:
                        self.store.prefetch(int(ranked[1]))
                    self._eval_batch(beval, entry, pid, batch)
            self.loads.append(pid)
            self.batch_sizes.append(len(batch))
            # round-scoped attribution: the event lands once in each
            # participating QUERY's view, and once per participating JOB
            # (a disjunct's RunStats) — never in any bystander's
            for qid in {j.qid for j in batch}:
                rec = self._admitted[qid]
                rec.load_stats = rec.load_stats + event
            self._touched.add(pid)
            in_batch = {id(j) for j in batch}
            for j in batch:
                j.load_stats = j.load_stats + event
                j.state.loads.append(pid)
                j.state.iterations += 1
            # fairness aging: a pending job the chosen partition did NOT
            # advance has waited one more round (core/heuristics.py turns
            # rounds_waiting × SNI into a score bonus when fairness_gamma
            # is set, bounding how long a no-overlap query can starve)
            for j in self._jobs:
                if not j.retired:
                    j.rounds_waiting = 0 if id(j) in in_batch \
                        else j.rounds_waiting + 1
            rounds += 1

    def _run_shared_tmp(self, t0: float,
                        max_rounds: Optional[int] = None) -> None:
        """TraditionalMP shared batching: each round ranks partitions with
        the same workload-level heuristic, takes the TOP-P set (the
        engine's p processors), and ships ONE stacked bundle through the
        store carrying EVERY waiting query's inputs — the double-vmapped
        ``TraditionalMPEngine.shared_evaluator()`` then evaluates B plans ×
        p partitions in one compiled call.  Per-job SNI/IMA/FAA bookkeeping
        is the sequential TMP loop's, verbatim (tail-kept cap chunking, one
        chunk per iteration of the same partition), so exhaustive answers
        stay bit-identical to per-query ``submit``."""
        engine: TraditionalMPEngine = self.session.engine
        seval = engine.shared_evaluator()
        cfg = self.session.config
        k = self.pg.k
        p = engine.p
        rng = np.random.default_rng(self.seed)
        limit = 64 * self.pg.k * max(1, len(self._jobs))
        rounds = 0
        while True:
            if max_rounds is not None and rounds >= max_rounds:
                break
            self._retire()
            waiters = self._waiters()
            if not waiters:
                break
            if len(self.loads) >= limit:
                raise RuntimeError("scheduler exceeded max partition loads "
                                   f"({limit}); likely a routing bug")
            rates = {}
            if self.heuristic == MAX_YIELD_SHARED:
                for js in waiters.values():
                    for j in js:
                        if id(j) not in rates:
                            rates[id(j)] = j.state.completion_rates()
            scored = {pp: [(j.state.sni_count(pp),
                            rates[id(j)][pp] if rates else 0.0,
                            j.rounds_waiting,
                            j.urgency)
                           for j in js]
                      for pp, js in waiters.items()}
            ranked = rank_partitions_shared(
                self.heuristic, scored, rng,
                fairness_gamma=self.fairness_gamma, tracer=self.tracer)
            # canonical sorted order + first-pid padding, exactly as the
            # per-query TMP loop: the stacked store key is then
            # permutation-invariant across rounds (padding lanes are
            # no-ops — idle processors — and sort in with the rest)
            chosen = sorted(int(q) for q in ranked[:p])
            lanes = sorted([(pid, True) for pid in chosen]
                           + [(chosen[0], False)] * (p - len(chosen)))
            exec_set = [t[0] for t in lanes]
            is_real = [t[1] for t in lanes]
            waiter_ids = {pid: {id(j) for j in js}
                          for pid, js in waiters.items()}
            # the round's batch: every job waiting on ANY chosen partition,
            # in stable admit order (deduped — a job waiting on two chosen
            # partitions gets ONE lane row with both its IMAs drained)
            in_round = {id(j) for pid in chosen for j in waiters[pid]}
            batch = [j for j in self._jobs
                     if not j.retired and id(j) in in_round]
            B = len(batch)
            Bpad = batch_bucket(B)
            plans = [j.plan_arrays for j in batch]
            stacked = PlanArrays.stack(plans + [plans[0]] * (Bpad - B))
            n_steps = np.asarray([j.plan.n_steps for j in batch]
                                 + [1] * (Bpad - B), np.int32)
            in_rows = np.full((Bpad, p, cfg.cap, cfg.q_pad), -1, np.int32)
            in_step = np.zeros((Bpad, p, cfg.cap), np.int32)
            in_valid = np.zeros((Bpad, p, cfg.cap), bool)
            seeds = np.zeros((Bpad, p), bool)
            lanes_of: List[List[int]] = []   # per job: real lanes it rode
            for b, j in enumerate(batch):
                mine: List[int] = []
                for i, pid in enumerate(exec_set):
                    if not is_real[i] or id(j) not in waiter_ids[pid]:
                        continue
                    mine.append(i)
                    bb = j.state.ima[pid]
                    j.state.ima[pid] = BindingBatch.empty(cfg.q_pad)
                    if bb.n > cfg.cap:
                        # tail kept for a later round of the same partition
                        j.state.ima[pid] = BindingBatch(
                            rows=bb.rows[cfg.cap:], step=bb.step[cfg.cap:])
                        bb = BindingBatch(rows=bb.rows[: cfg.cap],
                                          step=bb.step[: cfg.cap])
                    if bb.n:
                        in_rows[b, i, : bb.n] = bb.rows
                        in_step[b, i, : bb.n] = bb.step
                        in_valid[b, i, : bb.n] = True
                    seeds[b, i] = bool(j.state.fresh_pending[pid])
                    j.state.fresh_pending[pid] = False
                lanes_of.append(mine)
            ev0 = self.store.stats.copy()
            with self.tracer.span("scheduler.round", pids=chosen,
                                  round=rounds, batch=B,
                                  qids=sorted({j.qid for j in batch})):
                entry = self.store.get_stacked(tuple(exec_set))
                event = self.store.stats - ev0
                with self.tracer.span("kernel.eval", pids=chosen, batch=B,
                                      bucket=Bpad) as ksp:
                    if -Bpad not in self._traced_buckets:
                        # negative keys: the TMP double-vmap's jit cache is
                        # separate from the OPAT batched evaluator's
                        self._traced_buckets.add(-Bpad)
                        ksp.set(first_call=True)
                        self.profiler.attribute_kernel(
                            ("scheduler.tmp", Bpad), seval, entry.part,
                            entry.g2l, self.store.owner, stacked, n_steps,
                            in_rows, in_step, in_valid, seeds)
                        with self.tracer.span("kernel.compile", bucket=Bpad):
                            res = seval(entry.part, entry.g2l,
                                        self.store.owner, stacked, n_steps,
                                        in_rows, in_step, in_valid, seeds)
                    else:
                        res = seval(entry.part, entry.g2l, self.store.owner,
                                    stacked, n_steps, in_rows, in_step,
                                    in_valid, seeds)
                    overflow = np.asarray(res.overflow)
                    self.profiler.stamp_kernel(ksp, ("scheduler.tmp", Bpad))
                    self.profiler.sample_device(ksp, self.store)
            comp_rows, comp_n = np.asarray(res.comp_rows), np.asarray(res.comp_n)
            out_rows, out_n = np.asarray(res.out_rows), np.asarray(res.out_n)
            out_step, out_dest = np.asarray(res.out_step), np.asarray(res.out_dest)
            for b, j in enumerate(batch):
                for i in lanes_of[b]:
                    if bool(overflow[b, i]):
                        raise RuntimeError(
                            f"evaluator buffer overflow on partition "
                            f"{exec_set[i]} (query {j.plan.query.name!r} in "
                            f"a batch of {B}); raise EngineConfig.cap "
                            f"(currently {cfg.cap})")
                    absorb_eval_outputs(j.state, exec_set[i], k,
                                        comp_rows[b, i], int(comp_n[b, i]),
                                        out_rows[b, i], out_step[b, i],
                                        out_dest[b, i], int(out_n[b, i]))
            # attribution: the stacked bundle is ONE store event; each
            # chosen pid counts one workload load, and its batch size is
            # the number of jobs its lane advanced
            self.loads.extend(chosen)
            for pid in chosen:
                self.batch_sizes.append(
                    sum(1 for b, j in enumerate(batch)
                        if any(exec_set[i] == pid for i in lanes_of[b])))
            for qid in {j.qid for j in batch}:
                rec = self._admitted[qid]
                rec.load_stats = rec.load_stats + event
            self._touched.update(chosen)
            in_batch = {id(j) for j in batch}
            for b, j in enumerate(batch):
                j.load_stats = j.load_stats + event
                j.state.loads.extend(exec_set[i] for i in lanes_of[b])
                j.state.iterations += 1
            for j in self._jobs:
                if not j.retired:
                    j.rounds_waiting = 0 if id(j) in in_batch \
                        else j.rounds_waiting + 1
            rounds += 1

    def _eval_batch(self, beval, entry, pid: int, batch: List[_Job]) -> None:
        """One compiled call advances every waiting job's plan against the
        loaded partition (chunked when an IMA exceeds the row capacity;
        later chunks are inert for jobs already drained)."""
        cfg = self.session.config
        k = self.pg.k
        B = len(batch)
        Bpad = batch_bucket(B)
        plans = [j.plan_arrays for j in batch]
        stacked = PlanArrays.stack(plans + [plans[0]] * (Bpad - B))
        n_steps = np.asarray([j.plan.n_steps for j in batch]
                             + [1] * (Bpad - B), np.int32)
        imas: List[BindingBatch] = []
        seed_flags: List[bool] = []
        for j in batch:
            imas.append(j.state.ima[pid])
            j.state.ima[pid] = BindingBatch.empty(cfg.q_pad)
            seed_flags.append(bool(j.state.fresh_pending[pid]))
            j.state.fresh_pending[pid] = False
        n_chunks = max(1, max(-(-bb.n // cfg.cap) for bb in imas))
        for ci in range(n_chunks):
            in_rows = np.full((Bpad, cfg.cap, cfg.q_pad), -1, np.int32)
            in_step = np.zeros((Bpad, cfg.cap), np.int32)
            in_valid = np.zeros((Bpad, cfg.cap), bool)
            for b, bb in enumerate(imas):
                lo = ci * cfg.cap
                n = min(bb.n - lo, cfg.cap)
                if n > 0:
                    in_rows[b, :n] = bb.rows[lo:lo + n]
                    in_step[b, :n] = bb.step[lo:lo + n]
                    in_valid[b, :n] = True
            sf = np.asarray([s and ci == 0 for s in seed_flags]
                            + [False] * (Bpad - B))
            with self.tracer.span("kernel.eval", pid=pid, batch=B,
                                  bucket=Bpad) as ksp:
                if Bpad not in self._traced_buckets:
                    self._traced_buckets.add(Bpad)
                    ksp.set(first_call=True)
                    self.profiler.attribute_kernel(
                        ("scheduler.opat", Bpad), beval, entry.part,
                        entry.g2l, self.store.owner, stacked, n_steps,
                        in_rows, in_step, in_valid, sf)
                    with self.tracer.span("kernel.compile", bucket=Bpad):
                        res = beval(entry.part, entry.g2l, self.store.owner,
                                    stacked, n_steps, in_rows, in_step,
                                    in_valid, sf)
                else:
                    res = beval(entry.part, entry.g2l, self.store.owner,
                                stacked, n_steps, in_rows, in_step,
                                in_valid, sf)
                overflow = np.asarray(res.overflow)
                self.profiler.stamp_kernel(ksp, ("scheduler.opat", Bpad))
                self.profiler.sample_device(ksp, self.store)
            comp_rows, comp_n = np.asarray(res.comp_rows), np.asarray(res.comp_n)
            out_rows, out_n = np.asarray(res.out_rows), np.asarray(res.out_n)
            out_step, out_dest = np.asarray(res.out_step), np.asarray(res.out_dest)
            for b, j in enumerate(batch):
                if bool(overflow[b]):
                    raise RuntimeError(
                        f"evaluator buffer overflow on partition {pid} "
                        f"(query {j.plan.query.name!r} in a batch of {B}); "
                        f"raise EngineConfig.cap (currently {cfg.cap})")
                absorb_eval_outputs(j.state, pid, k,
                                    comp_rows[b], int(comp_n[b]),
                                    out_rows[b], out_step[b], out_dest[b],
                                    int(out_n[b]))

    def _run_sequential(self, t0: float,
                        max_rounds: Optional[int] = None) -> None:
        """Engines with no host partition loop to share (MapReduceMP) run a
        whole query as one (or few) compiled program(s), so the scheduler
        drains their jobs one query at a time — answers, budgets, and
        per-call LoadStats deltas identical to sequential ``submit``.
        ``max_rounds`` bounds the number of QUERIES served this call."""
        session = self.session
        served = 0
        # the engine reads its pg attribute at call time; hold it to the
        # scheduler's pinned binding for the drain so a mutation landing
        # mid-run can't mix generations into the ranking
        engine = session.engine
        prev_pg = engine.pg
        engine.pg = self.pg
        try:
            for rec in self._admitted.values():
                if rec.finished_at is not None:
                    continue
                if max_rounds is not None and served >= max_rounds:
                    break
                served += 1
                ev0 = self.store.stats.copy()
                for j in rec.jobs:
                    jv0 = self.store.stats.copy()
                    rep = engine.run_request(RunRequest(
                        plan=j.plan, heuristic=session.heuristic,
                        max_answers=j.max_answers, seed=self.seed))
                    j.retired = True
                    j.report = rep  # engine-built report reused verbatim
                    j.load_stats = j.load_stats + (self.store.stats - jv0)
                    self.loads.extend(rep.stats.loads)
                    self.batch_sizes.extend([1] * len(rep.stats.loads))
                rec.load_stats = rec.load_stats + (self.store.stats - ev0)
                rec.finished_at = time.time()
                rec.finished_perf = time.perf_counter()
        finally:
            engine.pg = prev_pg

    # -- retirement and the waiter index -----------------------------------

    def _waiters(self) -> Dict[int, List[_Job]]:
        w: Dict[int, List[_Job]] = {}
        for j in self._jobs:
            if j.retired:
                continue
            for p in j.state.eligible():
                w.setdefault(int(p), []).append(j)
        return w

    def _retire(self) -> None:
        """Retire jobs whose budget is met or whose SNI/IMA are exhausted,
        stamp queries whose last job retired, and (optionally) release
        store entries no pending job can currently use."""
        now = time.time()
        newly: List[_Job] = []
        for j in self._jobs:
            if j.retired:
                continue
            if j.state.budget_met(j.max_answers) or not j.state.eligible():
                j.retired = True
                newly.append(j)
        for rec in self._admitted.values():
            if rec.finished_at is None and all(j.retired for j in rec.jobs):
                rec.finished_at = now
                rec.finished_perf = time.perf_counter()
        if newly and self.release_retired:
            # any partition the workload loaded that no pending job can
            # currently use is releasable — cumulative, so an early
            # retiree's partitions go as soon as the last query needing
            # them retires (prefetched-but-never-loaded entries are left
            # to the LRU)
            needed: Set[int] = set()
            for j in self._jobs:
                if not j.retired:
                    needed.update(int(p) for p in j.state.eligible())
            for pid in sorted(self._touched - needed):
                if self.store.contains(pid):
                    self.store.release(pid)

    # -- results -----------------------------------------------------------

    def _collect_results(self, t0: float) -> List[QueryResult]:
        """Build the finished queries' results (admit order) and prune
        their state — a streaming scheduler's footprint stays proportional
        to the pending set, not to its serving history."""
        gen = int(self.view.generation) if self.view is not None else None
        results: List[QueryResult] = []
        done: List[int] = []
        for rec in self._admitted.values():
            if rec.finished_at is None:
                continue
            done.append(rec.qid)
            reports: List[RunReport] = []
            answers: Optional[np.ndarray] = None
            for j in rec.jobs:
                rep = j.report
                if rep is None:          # shared path: build from job state
                    a = truncate_answers(j.state.unique_answers(),
                                         j.max_answers)
                    delta = j.load_stats
                    rep = RunReport(
                        answers=a,
                        stats=RunStats(
                            query=j.plan.query.name, scheme=self.pg.scheme,
                            heuristic=self.heuristic,
                            loads=list(j.state.loads),
                            l_ideal=l_ideal_for_plan(self.pg, j.plan),
                            n_answers=int(a.shape[0]),
                            iterations=j.state.iterations,
                            answers_requested=j.max_answers,
                            cold_loads=delta.cold_loads,
                            warm_loads=delta.warm_loads,
                            prefetch_hits=delta.prefetch_hits,
                            disk_reads=delta.disk_reads,
                            read_ahead_hits=delta.read_ahead_hits,
                            bytes_cold=delta.bytes_cold,
                            bytes_prefetched=delta.bytes_prefetched,
                            bytes_disk=delta.bytes_disk,
                            bytes_host=delta.bytes_host),
                        engine=self.session.engine_name,
                        extra={"state": j.state})
                rep.stats.generation = gen
                reports.append(rep)
                a = rep.answers
                answers = a if answers is None else np.unique(
                    np.concatenate([answers, a]), axis=0)
            results.append(QueryResult(
                name=rec.name, answers=answers, reports=reports,
                latency_s=max(0.0, rec.finished_at - t0),
                load_stats=rec.load_stats, qid=rec.qid, generation=gen))
            if self.tracer.enabled and rec.finished_perf is not None:
                # one root span per retired query, admission → retirement
                # (externally-timed: the lifetime crosses many rounds, so
                # no single call frame could carry it)
                self.tracer.add_span(
                    "query", rec.admitted_perf, rec.finished_perf,
                    qid=rec.qid, query=rec.name, generation=gen,
                    n_answers=int(answers.shape[0]),
                    n_loads=sum(len(r.stats.loads) for r in reports))
        for qid in done:
            del self._admitted[qid]
        self._jobs = [j for j in self._jobs if not j.retired]
        return results
