"""The jitted within-partition evaluator shared by OPAT / TraditionalMP /
MapReduceMP.

One compiled function evaluates *any* partition of a given padded geometry:
it seeds fresh start-node bindings (when the partition is processed for the
first time), expands all local partial answers breadth-first following the
plan, and classifies every produced row as

  completed  -> appended to the FAA buffer,
  local      -> next frontier vertex owned here; kept in the work buffer,
  outgoing   -> next frontier vertex owned elsewhere; emitted with its
                destination partition id (the paper's PCA/IMA continuation).

All buffers are fixed capacity; saturation sets an ``overflow`` flag the
host checks (the host then re-runs with a bigger capacity — never silent).

TPU adaptation: the per-step expansion evaluates an [EB, W] tile (EB active
bindings x ELLPACK width W) of candidate edges *densely* — predicates are
branchless masks, a perfect VPU shape — instead of the pointer-chasing loop
a CPU implementation would use.  The tile-match inner block is exactly what
``kernels/frontier_expand.py`` implements as a Pallas kernel; ``use_pallas``
routes through it (interpret mode on CPU).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import DIR_BACKWARD, DIR_FORWARD, DIR_UNDIRECTED, PartitionArrays, WILDCARD
from .plan import PlanArrays
from .query import QDIR_ANY, QDIR_IN, QDIR_OUT
from .state import apply_value_op


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static geometry for the compiled evaluator."""

    q_pad: int = 8            # binding row width (max query nodes)
    s_pad: int = 12           # padded plan length
    cap: int = 4096           # in/out/completed buffer capacity
    expand_block: int = 512   # active rows expanded per loop iteration (EB)
    max_inner_iters: int = 10_000
    use_pallas: bool = False


class EvalResult(NamedTuple):
    comp_rows: jax.Array      # [cap, Q]
    comp_n: jax.Array         # []
    out_rows: jax.Array       # [cap, Q]
    out_step: jax.Array       # [cap]
    out_dest: jax.Array       # [cap]
    out_n: jax.Array          # []
    overflow: jax.Array       # [] bool
    n_iters: jax.Array        # []
    n_expanded: jax.Array     # [] total candidate rows expanded


def _match_tile_jnp(rows_b, step_b, lidx_b, m,
                    ell_dst, ell_label, ell_dir,
                    node_label, node_value, node_gid,
                    plan, n_steps):
    """Dense [EB, W] candidate-edge match.  Returns (ok, dg, ns, nr)."""
    EB = rows_b.shape[0]
    Q = rows_b.shape[1]
    s = jnp.clip(step_b, 0, plan.src_slot.shape[0] - 1)
    p_el = plan.edge_label[s]          # [EB]
    p_dir = plan.direction[s]
    p_dlab = plan.dst_label[s]
    p_dop = plan.dst_value_op[s]
    p_dval = plan.dst_value[s]
    p_dst = plan.dst_slot[s]
    p_closes = plan.closes_cycle[s]

    lsafe = jnp.clip(lidx_b, 0, ell_dst.shape[0] - 1)
    ed = jnp.take(ell_dst, lsafe, axis=0)      # [EB, W] local dst
    el = jnp.take(ell_label, lsafe, axis=0)
    edir = jnp.take(ell_dir, lsafe, axis=0)

    edge_exists = ed >= 0
    elabel_ok = (p_el[:, None] == WILDCARD) | (el == p_el[:, None])
    dir_ok = ((p_dir[:, None] == QDIR_ANY)
              | (edir == DIR_UNDIRECTED)
              | ((p_dir[:, None] == QDIR_OUT) & (edir == DIR_FORWARD))
              | ((p_dir[:, None] == QDIR_IN) & (edir == DIR_BACKWARD)))

    dsafe = jnp.clip(ed, 0, node_label.shape[0] - 1)
    dl = jnp.take(node_label, dsafe)
    dv = jnp.take(node_value, dsafe)
    dg = jnp.take(node_gid, dsafe)            # global id of candidate dst

    dlabel_ok = (p_dlab[:, None] == WILDCARD) | (dl == p_dlab[:, None])
    dval_ok = apply_value_op(p_dop[:, None], dv, p_dval[:, None])
    # injectivity: candidate must not already be bound to another slot
    inj_ok = ~jnp.any(rows_b[:, None, :] == dg[:, :, None], axis=-1)

    bound_dst = jnp.take_along_axis(rows_b, p_dst[:, None], axis=1)  # [EB,1]
    cyc_ok = (p_closes[:, None] == 1) & (bound_dst == dg)
    new_ok = (p_closes[:, None] == 0) & dlabel_ok & dval_ok & inj_ok

    ok = (m[:, None] & (step_b[:, None] < n_steps)
          & edge_exists & elabel_ok & dir_ok & (cyc_ok | new_ok))

    # new rows: bind dst slot (unless cycle closure keeps bindings unchanged)
    col = jnp.arange(Q, dtype=jnp.int32)
    setcol = (col[None, None, :] == p_dst[:, None, None]) & (p_closes[:, None, None] == 0)
    nr = jnp.where(setcol, dg[:, :, None], rows_b[:, None, :])      # [EB, W, Q]
    ns = jnp.broadcast_to(step_b[:, None] + 1, ok.shape)            # [EB, W]
    return ok, dg, ns, nr


def _next_rows(rows_b, step_b, dg, ok_shape, plan):
    """New binding rows + steps (scatter-shaped; stays in jnp either way)."""
    Q = rows_b.shape[1]
    s = jnp.clip(step_b, 0, plan.src_slot.shape[0] - 1)
    p_dst = plan.dst_slot[s]
    p_closes = plan.closes_cycle[s]
    col = jnp.arange(Q, dtype=jnp.int32)
    setcol = (col[None, None, :] == p_dst[:, None, None]) & (p_closes[:, None, None] == 0)
    nr = jnp.where(setcol, dg[:, :, None], rows_b[:, None, :])
    ns = jnp.broadcast_to(step_b[:, None] + 1, ok_shape)
    return nr, ns


def _expand_classify(rows_b, step_b, lidx_b, m, part, g2l_row, owner, aux,
                     plan, n_steps, use_pallas):
    """Fused inner step: match an [EB, W] candidate tile AND classify every
    produced row as done / keep / outgoing (with destination pid).

    ``aux`` is the (ell_dlidx, ell_downer) pair from kops.denorm_locality
    when use_pallas (hoisted out of the while loop), else None.
    Returns ([EB, W]-shaped) ok, dg, ns, nr, done, keep, outm, dest.
    """
    n_core = part["n_core"]
    if use_pallas:
        from ..kernels import ops as kops
        ell_dlidx, ell_downer = aux
        ok, dg, done, keep, outm, dest = kops.fused_frontier(
            rows_b, step_b, lidx_b, m,
            part["ell_dst"], part["ell_label"], part["ell_dir"],
            part["ell_dlab"], part["ell_dval"], part["ell_dgid"],
            ell_dlidx, ell_downer, g2l_row, owner, n_core,
            plan, n_steps)
        nr, ns = _next_rows(rows_b, step_b, dg, ok.shape, plan)
        return ok, dg, ns, nr, done, keep, outm, dest

    ok, dg, ns, nr = _match_tile_jnp(
        rows_b, step_b, lidx_b, m,
        part["ell_dst"], part["ell_label"], part["ell_dir"],
        part["node_label"], part["node_value"], part["node_gid"],
        plan, n_steps)
    done = ok & (ns >= n_steps)
    s2 = jnp.clip(ns, 0, plan.src_slot.shape[0] - 1)
    nsrc = plan.src_slot[s2]                                   # [EB, W]
    fg = jnp.take_along_axis(nr, nsrc[:, :, None], axis=2)[:, :, 0]
    fg_safe = jnp.clip(fg, 0, g2l_row.shape[0] - 1)
    l2 = jnp.take(g2l_row, fg_safe)
    local = (l2 >= 0) & (l2 < n_core) & (fg >= 0)
    keep = ok & ~done & local
    outm = ok & ~done & ~local
    dest = jnp.take(owner, fg_safe)
    return ok, dg, ns, nr, done, keep, outm, dest


def make_partition_evaluator(node_pad: int, ell_width: int, cfg: EngineConfig):
    """Build the jitted evaluator.

    Geometry-agnostic: the padded node count ``Np`` and ELLPACK width ``W``
    are read off the *input array shapes* at trace time (``node_pad`` /
    ``ell_width`` are advisory — kept in the signature for callers that
    size buffers up front), so one returned callable serves partitions of
    any geometry; jit retraces per distinct shape.  This is what lets a
    pinned old generation and a freshly compacted generation with grown
    padding share one evaluator (storage/deltas.py).
    """

    Q, S = cfg.q_pad, cfg.s_pad
    CAP = cfg.cap

    def _frontier_local(rows, step, valid, plan, n_steps, g2l_row, n_core):
        """active mask + local index of each row's next frontier vertex."""
        s = jnp.clip(step, 0, S - 1)
        src_slot = plan.src_slot[s]
        fg = jnp.take_along_axis(rows, src_slot[:, None], axis=1)[:, 0]
        fg_safe = jnp.clip(fg, 0, g2l_row.shape[0] - 1)
        lidx = jnp.take(g2l_row, fg_safe)
        lidx = jnp.where(fg >= 0, lidx, -1)
        local = (lidx >= 0) & (lidx < n_core)
        act = valid & (step < n_steps) & local
        return act, lidx, fg

    def _append(buf_rows, buf_aux, buf_n, rows_flat, aux_flat, mask_flat, overflow):
        """Masked append into a fixed buffer via out-of-bounds-drop scatter."""
        cnt = jnp.cumsum(mask_flat.astype(jnp.int32)) - 1
        tgt = jnp.where(mask_flat, buf_n + cnt, buf_rows.shape[0])
        buf_rows = buf_rows.at[tgt].set(rows_flat, mode="drop")
        new_aux = []
        for b, a in zip(buf_aux, aux_flat):
            new_aux.append(b.at[tgt].set(a, mode="drop"))
        total = buf_n + mask_flat.sum(dtype=jnp.int32)
        overflow = overflow | (total > buf_rows.shape[0])
        return buf_rows, tuple(new_aux), jnp.minimum(total, buf_rows.shape[0]), overflow

    def evaluate(part: Dict[str, jax.Array], g2l_row: jax.Array,
                 owner: jax.Array, plan: PlanArrays, n_steps: jax.Array,
                 in_rows: jax.Array, in_step: jax.Array, in_valid: jax.Array,
                 seed_fresh: jax.Array) -> EvalResult:
        n_core = part["n_core"]
        pid = part["pid"]
        Np = part["node_label"].shape[0]   # static at trace time
        W = part["ell_dst"].shape[1]
        WT = CAP + Np  # work buffer: incoming rows + fresh seeds
        EB = min(cfg.expand_block, WT)  # can't select more rows than exist

        if cfg.use_pallas:
            # locality tables for the fused kernel: computed once per call,
            # hoisted out of the while loop (static python branch — cfg is
            # a closure constant, so the jnp path pays nothing)
            from ..kernels import ops as kops
            aux = kops.denorm_locality(part["ell_dgid"], g2l_row, owner)
        else:
            aux = None

        # ---- seed fresh start-node bindings (SNI entries with NULL vid) ----
        node_idx = jnp.arange(Np, dtype=jnp.int32)
        start_ok = ((node_idx < n_core)
                    & ((plan.start_label == WILDCARD)
                       | (part["node_label"] == plan.start_label))
                    & apply_value_op(plan.start_value_op, part["node_value"],
                                     plan.start_value)
                    & seed_fresh)
        col = jnp.arange(Q, dtype=jnp.int32)
        fresh_rows = jnp.where((col[None, :] == plan.start_slot) & start_ok[:, None],
                               part["node_gid"][:, None],
                               jnp.int32(-1))
        work_rows = jnp.concatenate([in_rows, fresh_rows], axis=0)          # [WT, Q]
        work_step = jnp.concatenate([in_step, jnp.zeros(Np, jnp.int32)])
        work_valid = jnp.concatenate([in_valid, start_ok])

        comp_rows = jnp.full((CAP, Q), -1, jnp.int32)
        comp_n = jnp.int32(0)
        out_rows = jnp.full((CAP, Q), -1, jnp.int32)
        out_step = jnp.zeros(CAP, jnp.int32)
        out_dest = jnp.full(CAP, -1, jnp.int32)
        out_n = jnp.int32(0)
        overflow = jnp.bool_(False)

        # ---- pre-classify: rows already complete, or frontier not local ----
        done0 = work_valid & (work_step >= n_steps)
        act0, _, fg0 = _frontier_local(work_rows, work_step, work_valid, plan,
                                       n_steps, g2l_row, n_core)
        outm0 = work_valid & ~done0 & ~act0
        dest0 = jnp.take(owner, jnp.clip(fg0, 0, owner.shape[0] - 1))
        comp_rows, _, comp_n, overflow = _append(
            comp_rows, (), comp_n, work_rows, (), done0, overflow)
        out_rows, (out_step, out_dest), out_n, overflow = _append(
            out_rows, (out_step, out_dest), out_n, work_rows,
            (work_step, dest0), outm0, overflow)
        work_valid = work_valid & act0

        state = (work_rows, work_step, work_valid, comp_rows, comp_n,
                 out_rows, out_step, out_dest, out_n, overflow,
                 jnp.int32(0), jnp.int32(0))

        def cond(st):
            wr, ws, wv, *_, it, _nx = st
            act, _, _ = _frontier_local(wr, ws, wv, plan, n_steps, g2l_row, n_core)
            return jnp.any(act) & (it < cfg.max_inner_iters)

        def body(st):
            (wr, ws, wv, cr, cn, orr, os_, od, on, ovf, it, nx) = st
            act, lidx, _ = _frontier_local(wr, ws, wv, plan, n_steps, g2l_row, n_core)
            # pick up to EB active rows: top_k on the mask is O(WT log EB)
            # vs the original full argsort's O(WT log WT) (§Perf-D2)
            _, sel = jax.lax.top_k(act.astype(jnp.int32), EB)
            m = jnp.take(act, sel)
            rows_b = jnp.take(wr, sel, axis=0)
            step_b = jnp.take(ws, sel)
            lidx_b = jnp.take(lidx, sel)
            # consume them
            wv = wv.at[sel].set(jnp.take(wv, sel) & ~m)

            (ok, dg, ns, nr, done_t, keep_t, outm_t, dest_t) = _expand_classify(
                rows_b, step_b, lidx_b, m, part, g2l_row, owner, aux,
                plan, n_steps, cfg.use_pallas)

            EBW = EB * W
            ok_f = ok.reshape(EBW)
            nr_f = nr.reshape(EBW, Q)
            ns_f = ns.reshape(EBW)
            done = done_t.reshape(EBW)
            keep = keep_t.reshape(EBW)
            outm = outm_t.reshape(EBW)
            dest = dest_t.reshape(EBW)

            cr, _, cn, ovf = _append(cr, (), cn, nr_f, (), done, ovf)
            orr, (os_, od), on, ovf = _append(orr, (os_, od), on, nr_f,
                                              (ns_f, dest), outm, ovf)
            # keep-rows go into free work slots; at most EBW are needed, so
            # top_k over the free mask replaces the full argsort (§Perf-D3)
            kfree = min(EBW, WT)
            _, free = jax.lax.top_k((~wv).astype(jnp.int32), kfree)
            n_free_needed = keep.sum(dtype=jnp.int32)
            n_free_have = (~wv).sum(dtype=jnp.int32)
            ovf = ovf | (n_free_needed > n_free_have)
            pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
            tgt = jnp.where(keep & (pos < kfree), free[jnp.clip(pos, 0, kfree - 1)], WT)
            wr = wr.at[tgt].set(nr_f, mode="drop")
            ws = ws.at[tgt].set(ns_f, mode="drop")
            wv = wv.at[tgt].set(True, mode="drop")

            return (wr, ws, wv, cr, cn, orr, os_, od, on, ovf,
                    it + 1, nx + m.sum(dtype=jnp.int32))

        state = jax.lax.while_loop(cond, body, state)
        (_, _, _, cr, cn, orr, os_, od, on, ovf, it, nx) = state
        return EvalResult(cr, cn, orr, os_, od, on, ovf, it, nx)

    return jax.jit(evaluate)


# ---------------------------------------------------------------------------
# Host-side helpers shared by the OPAT / TraditionalMP orchestrators
# ---------------------------------------------------------------------------

def part_to_device_dict(p: PartitionArrays) -> Dict[str, np.ndarray]:
    assert p.ell_dst is not None, "call PartitionArrays.to_ell() first"
    return dict(
        pid=np.int32(p.pid),
        n_core=np.int32(p.n_core),
        node_gid=p.node_gid,
        node_label=p.node_label,
        node_value=p.node_value,
        ell_dst=p.ell_dst,
        ell_label=p.ell_label,
        ell_dir=p.ell_dir,
        ell_dlab=p.ell_dlab,
        ell_dval=p.ell_dval,
        ell_dgid=p.ell_dgid,
    )


def plan_to_device(pa: PlanArrays) -> PlanArrays:
    return pa  # numpy arrays are fine as jit inputs; kept for symmetry


jax.tree_util.register_pytree_node(
    PlanArrays,
    lambda p: ((p.start_slot, p.start_label, p.start_value_op, p.start_value,
                p.src_slot, p.dst_slot, p.edge_label, p.direction, p.dst_label,
                p.dst_value_op, p.dst_value, p.closes_cycle),
               (p.n_slots, p.n_steps)),
    lambda aux, ch: PlanArrays(
        n_slots=aux[0], n_steps=aux[1], start_slot=ch[0], start_label=ch[1],
        start_value_op=ch[2], start_value=ch[3], src_slot=ch[4], dst_slot=ch[5],
        edge_label=ch[6], direction=ch[7], dst_label=ch[8], dst_value_op=ch[9],
        dst_value=ch[10], closes_cycle=ch[11]),
)
