"""GraphSession — the stateful serving API over one partitioned graph.

The paper's workload is *query serving*: many queries, one partitioned
graph, response time dominated by the partition-load sequence.  The seed
code had no object for that shape — every caller re-built engines and
re-shipped partitions per query.  A ``GraphSession`` is constructed once
from (graph, scheme, k, engine, EngineConfig) and then serves repeated
``submit`` calls against the same residency state:

  * it owns the ``PartitionStore`` (core/store.py), so the second query
    finds the first query's partitions device-resident — warm loads — and
    OPAT's runner-up prefetch overlaps transfers with evaluation;
  * it owns the catalog and the engine (one compile of the partition
    evaluator per session, reused across queries);
  * it accumulates a per-partition *workload profile* — loads, completed
    vs spawned rows, completion rates, answers — that persists to JSON.
    This is the observability hook WawPart-style workload-aware
    repartitioning (ROADMAP item #2) consumes: hot query paths show up as
    partitions with many loads and low completion rates, i.e. spanning
    work the partitioner should co-locate.

``submit(query, max_answers=K)`` accepts a conjunctive ``Query`` or a
``DisjunctiveQuery`` (per-disjunct plans, unioned answers; a budget K
applies per disjunct, matching ``launch/serve.py`` semantics) and returns a
``QueryResult`` carrying the merged answers, per-disjunct ``RunReport``s,
wall latency, and this call's cold/warm/prefetch ``LoadStats`` delta.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Union

import numpy as np

from .catalog import Catalog, build_catalog
from .engine import EngineConfig
from .graph import Graph, PartitionedGraph, build_partitions
from .heuristics import MAX_SN
from .metrics import RunStats
from .partition import partition_graph
from .plan import generate_plan
from .query import DisjunctiveQuery, Query
from .runner import QueryRunner, RunReport, RunRequest
from .store import LoadStats, PartitionStore

ENGINES = ("opat", "traditional", "mapreduce")


@dataclasses.dataclass
class QueryResult:
    """What ``GraphSession.submit`` returns for one (possibly disjunctive)
    query: merged unique answers plus everything observability needs."""

    name: str
    answers: np.ndarray            # [n, q_pad] unique rows (union of disjuncts)
    reports: List[RunReport]       # one per disjunct, in disjunct order
    latency_s: float
    load_stats: LoadStats          # this call's store delta (cold/warm/prefetch)

    @property
    def n_answers(self) -> int:
        return int(self.answers.shape[0])

    @property
    def stats(self) -> List[RunStats]:
        return [r.stats for r in self.reports]

    @property
    def n_loads(self) -> int:
        return sum(s.n_loads for s in self.stats)


class GraphSession:
    """One partitioned graph, one engine compile, many queries.

    Parameters mirror the serving CLI: ``engine`` is one of ``"opat"``,
    ``"traditional"``, ``"mapreduce"``; ``cache_parts`` / ``cache_bytes``
    size the store's LRU device cache (None = unbounded); ``prefetch``
    enables OPAT's runner-up staging.  Pass ``pg`` to reuse an existing
    ``PartitionedGraph`` (then ``graph``/``k``/``scheme`` are taken from
    it); ``mesh`` is required context for MapReduceMP on >1 device
    (defaults to a 1-D mesh over all local devices).
    """

    def __init__(self, graph: Optional[Graph] = None, *,
                 k: int = 4,
                 scheme: str = "kway_shem",
                 engine: str = "opat",
                 heuristic: str = MAX_SN,
                 config: Optional[EngineConfig] = None,
                 cache_parts: Optional[int] = None,
                 cache_bytes: Optional[int] = None,
                 processors: int = 2,
                 prefetch: bool = True,
                 seed: int = 0,
                 pg: Optional[PartitionedGraph] = None,
                 mesh: Optional[Any] = None,
                 catalog: Optional[Catalog] = None):
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
        if pg is None:
            if graph is None:
                raise ValueError("need a graph (or a pre-built pg)")
            assign = partition_graph(graph, k, scheme, seed=seed)
            pg = build_partitions(graph, assign, k, scheme=scheme)
        self.pg = pg
        self.graph = pg.graph
        self.scheme = pg.scheme
        self.k = pg.k
        self.engine_name = engine
        self.heuristic = heuristic
        self.seed = seed
        self.config = config or EngineConfig()
        self.catalog = catalog if catalog is not None else build_catalog(self.graph)
        self.store = PartitionStore(pg, capacity_parts=cache_parts,
                                    capacity_bytes=cache_bytes)

        if engine == "opat":
            from .opat import OPATEngine
            self.engine: QueryRunner = OPATEngine(
                pg, self.config, store=self.store, prefetch=prefetch)
        elif engine == "traditional":
            from .traditional_mp import TraditionalMPEngine
            self.engine = TraditionalMPEngine(
                pg, processors, self.config, store=self.store)
        else:
            from ..compat import make_part_mesh
            from .mapreduce_mp import MapReduceMPEngine
            if mesh is None:
                mesh = make_part_mesh(pg.k)
            self.engine = MapReduceMPEngine(
                pg, mesh, self.config, heuristic=heuristic, store=self.store)

        # per-partition workload profile, accumulated across submits.
        # MapReduceMP runs as one compiled program with no host loop, so it
        # surfaces no per-partition load/yield counters — the profile flags
        # that rather than passing off all-zeros as observations.
        self.observes_partition_counters = engine != "mapreduce"
        self._loads = np.zeros(self.k, dtype=np.int64)
        self._completed = np.zeros(self.k, dtype=np.int64)
        self._spawned = np.zeros(self.k, dtype=np.int64)
        self._queries_served = 0
        self._answers_served = 0

    # -- serving -----------------------------------------------------------

    def submit(self, query: Union[Query, DisjunctiveQuery],
               max_answers: Optional[int] = None,
               heuristic: Optional[str] = None,
               seed: Optional[int] = None) -> QueryResult:
        """Serve one query against the session's resident partitions.

        ``max_answers`` is the paper's "specified number of answers" K
        (per disjunct); ``heuristic``/``seed`` default to the session's.
        """
        disjuncts = (query.disjuncts if isinstance(query, DisjunctiveQuery)
                     else [query])
        h = heuristic if heuristic is not None else self.heuristic
        s = seed if seed is not None else self.seed
        stats0 = self.store.stats.copy()
        t0 = time.time()
        reports: List[RunReport] = []
        answers: Optional[np.ndarray] = None
        for q in disjuncts:
            plan = generate_plan(q, self.graph, self.catalog)
            rep = self.engine.run_request(RunRequest(
                plan=plan, heuristic=h, max_answers=max_answers, seed=s))
            reports.append(rep)
            a = rep.answers
            answers = a if answers is None else np.unique(
                np.concatenate([answers, a]), axis=0)
        latency = time.time() - t0
        self._absorb(reports, int(answers.shape[0]))
        return QueryResult(name=query.name, answers=answers, reports=reports,
                           latency_s=latency,
                           load_stats=self.store.stats - stats0)

    def _absorb(self, reports: List[RunReport], n_answers: int) -> None:
        for rep in reports:
            for pid in rep.stats.loads:
                self._loads[pid] += 1
            st = rep.extra.get("state")
            if st is not None:     # OPAT / TraditionalMP expose QueryState
                self._completed += st.completed_from
                self._spawned += st.spawned_from
        self._queries_served += 1
        self._answers_served += n_answers

    # -- observability -----------------------------------------------------

    @property
    def load_stats(self) -> LoadStats:
        """Lifetime store counters (cold/warm/evictions/prefetch)."""
        return self.store.stats

    def workload_profile(self) -> Dict[str, Any]:
        """Per-partition load/yield/completion-rate profile of everything
        this session served — the input a workload-aware repartitioner
        (WawPart, arXiv:2203.14888) feeds on.

        ``partition_counters_observed`` is False for MapReduceMP (no host
        loop, so per-partition counters are structurally zero and a
        repartitioner must not treat them as measurements).
        """
        partitions = []
        for p in range(self.k):
            comp = int(self._completed[p])
            spawn = int(self._spawned[p])
            partitions.append({
                "pid": p,
                "loads": int(self._loads[p]),
                "completed": comp,
                "spawned": spawn,
                # Laplace-smoothed, matching heuristics.MAX_YIELD
                "completion_rate": (comp + 1.0) / (comp + spawn + 2.0),
            })
        return {
            "engine": self.engine_name,
            "scheme": self.scheme,
            "k": self.k,
            "heuristic": self.heuristic,
            "partition_counters_observed": self.observes_partition_counters,
            "queries_served": self._queries_served,
            "answers_served": self._answers_served,
            "partitions": partitions,
            "cache": self.store.stats.to_dict(),
        }

    def save_profile(self, path: str) -> None:
        """Persist ``workload_profile()`` as JSON (the repartitioner/CI
        artifact format)."""
        with open(path, "w") as f:
            json.dump(self.workload_profile(), f, indent=2)
