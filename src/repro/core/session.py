"""GraphSession — the stateful serving API over one partitioned graph.

The paper's workload is *query serving*: many queries, one partitioned
graph, response time dominated by the partition-load sequence.  The seed
code had no object for that shape — every caller re-built engines and
re-shipped partitions per query.  A ``GraphSession`` is constructed once
from (graph, scheme, k, engine, EngineConfig) and then serves repeated
``submit`` calls against the same residency state:

  * it owns the ``PartitionStore`` (core/store.py), so the second query
    finds the first query's partitions device-resident — warm loads — and
    OPAT's runner-up prefetch overlaps transfers with evaluation;
  * it owns the catalog and the engine (one compile of the partition
    evaluator per session, reused across queries);
  * it accumulates a per-partition *workload profile* — loads, completed
    vs spawned rows, completion rates, and the per-answer partition-span
    matrix — that persists to JSON.  ``core/repartition.py`` consumes it:
    hot query paths show up as partitions with many loads, low completion
    rates, and heavy co-span pairs, i.e. spanning work the partitioner
    should co-locate — and ``repartition()`` (below) closes that loop in
    place, rebuilding the session against the workload-aware layout.

``submit(query, max_answers=K)`` accepts a conjunctive ``Query`` or a
``DisjunctiveQuery`` (per-disjunct plans, unioned answers; a budget K
applies per disjunct, matching ``launch/serve.py`` semantics) and returns a
``QueryResult`` carrying the merged answers, per-disjunct ``RunReport``s,
wall latency, and this call's cold/warm/prefetch ``LoadStats`` delta.

``submit_many(queries, max_answers=K)`` serves a whole batch through the
``QueryScheduler`` (core/scheduler.py): pending queries share partition
loads (workload-level MAX-YIELD-SHARED ordering, batched partition
evaluation on the OPAT path), each retires independently on its own
budget, and the workload profile absorbs every result exactly as single
submits do.

``save(path)`` / ``open(path)`` round the partitioned graph through disk
(src/repro/storage/): a saved *graph directory* reopens as an
out-of-core session whose partitions stream through the store's
disk → pinned-host → device cache tiers with identical answers.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np

from .catalog import Catalog, build_catalog
from .engine import EngineConfig
from .graph import Graph, PartitionedGraph, build_partitions
from .heuristics import MAX_SN
from .metrics import RunStats
from .partition import partition_graph
from .plan import generate_plan
from .query import DisjunctiveQuery, Query
from .runner import QueryRunner, RunReport, RunRequest
from .store import LoadStats, PartitionStore

ENGINES = ("opat", "traditional", "mapreduce")


@dataclasses.dataclass
class QueryResult:
    """What ``GraphSession.submit`` returns for one (possibly disjunctive)
    query: merged unique answers plus everything observability needs."""

    name: str
    answers: np.ndarray            # [n, q_pad] unique rows (union of disjuncts)
    reports: List[RunReport]       # one per disjunct, in disjunct order
    latency_s: float
    load_stats: LoadStats          # this call's store delta (cold/warm/prefetch)
    qid: Optional[int] = None      # scheduler admission id (None on submit);
                                   # the SLO front end matches results back
                                   # to requests with it
    generation: Optional[int] = None   # the graph generation this result was
                                       # pinned to (storage/deltas.py); None
                                       # for in-RAM sessions — no generations

    @property
    def n_answers(self) -> int:
        return int(self.answers.shape[0])

    @property
    def stats(self) -> List[RunStats]:
        return [r.stats for r in self.reports]

    @property
    def n_loads(self) -> int:
        return sum(s.n_loads for s in self.stats)


class GraphSession:
    """One partitioned graph, one engine compile, many queries.

    Parameters mirror the serving CLI: ``engine`` is one of ``"opat"``,
    ``"traditional"``, ``"mapreduce"``; ``cache_parts`` / ``cache_bytes``
    size the store's LRU device cache (None = unbounded); ``prefetch``
    enables OPAT's runner-up staging.  Pass ``pg`` to reuse an existing
    ``PartitionedGraph`` (then ``graph``/``k``/``scheme`` are taken from
    it); ``mesh`` is required context for MapReduceMP on >1 device
    (defaults to a 1-D mesh over all local devices).

    Out of core: ``GraphSession.open(path)`` builds a session over a
    ``save``d graph directory — partitions stay disk-resident behind a
    three-tier cache, with ``host_cache_parts`` / ``host_cache_bytes``
    sizing the pinned-host LRU and ``read_ahead`` enabling the
    background-thread disk staging of the heuristic's runner-up (both
    are ignored for in-RAM sessions, whose host tier is the whole graph).
    See docs/storage.md.
    """

    def __init__(self, graph: Optional[Graph] = None, *,
                 k: int = 4,
                 scheme: str = "kway_shem",
                 engine: str = "opat",
                 heuristic: str = MAX_SN,
                 config: Optional[EngineConfig] = None,
                 cache_parts: Optional[int] = None,
                 cache_bytes: Optional[int] = None,
                 host_cache_parts: Optional[int] = None,
                 host_cache_bytes: Optional[int] = None,
                 read_ahead: bool = True,
                 processors: int = 2,
                 prefetch: bool = True,
                 seed: int = 0,
                 pg: Optional[PartitionedGraph] = None,
                 mesh: Optional[Any] = None,
                 catalog: Optional[Catalog] = None,
                 tracer: Optional[Any] = None,
                 profiler: Optional[Any] = None):
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
        if pg is None:
            if graph is None:
                raise ValueError("need a graph (or a pre-built pg)")
            assign = partition_graph(graph, k, scheme, seed=seed)
            pg = build_partitions(graph, assign, k, scheme=scheme)
        self.graph = pg.graph
        self.engine_name = engine
        self.heuristic = heuristic
        self.seed = seed
        self.config = config or EngineConfig()
        self.catalog = catalog if catalog is not None else build_catalog(self.graph)
        # remembered so repartition() can rebuild the stack identically
        self._cache_parts = cache_parts
        self._cache_bytes = cache_bytes
        # the disk tier (out-of-core sessions, GraphSession.open): a
        # DiskCatalog the store's host LRU reads shards from, plus that
        # LRU's sizing and read-ahead switch (storage/host_cache.py)
        self._backing = getattr(pg, "backing", None)
        self._host_cache_parts = host_cache_parts
        self._host_cache_bytes = host_cache_bytes
        self._read_ahead = read_ahead
        self._processors = processors
        self._prefetch = prefetch
        self._mesh = mesh
        self.repartitions = 0
        # observability (obs/trace.py): one tracer serves the whole stack
        # threaded under this session — store, host tier, engines,
        # scheduler, front end, delta layer.  The no-op default keeps
        # untraced serving at pre-obs cost.
        from ..obs.trace import NULL_TRACER
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # resource profiling (obs/profile.py): defaults ON whenever a real
        # tracer is attached — traced spans then carry memory/cost
        # attributes — and to the no-op singleton otherwise; pass an
        # explicit profiler (or NULL_PROFILER) to decouple the two
        from ..obs.profile import NULL_PROFILER, ResourceProfiler
        if profiler is not None:
            self.profiler = profiler
        elif self.tracer.enabled:
            self.profiler = ResourceProfiler(self.tracer)
        else:
            self.profiler = NULL_PROFILER
        self.store: Optional[PartitionStore] = None
        # streaming updates (storage/deltas.py): a session built by
        # ``open`` owns the directory's writer handle and keeps one pinned
        # generation view current; in-RAM sessions have neither and
        # ``mutate``/``compact``/``snapshot`` raise
        self._mdir: Optional[Any] = None
        self._view: Optional[Any] = None
        self._bind(pg)

    def _bind(self, pg: PartitionedGraph) -> None:
        """(Re)build everything that depends on the vertex assignment: the
        store (so no stale single-partition entry or stacked bundle from an
        older layout can ever be served), the engine (its compiled
        evaluator is shaped by the new padding geometry and it must point
        at the new store), and the per-partition profile counters (old pids
        name different vertex sets, so old counts are not observations of
        the new layout)."""
        if self.store is not None:
            # join in-flight read-aheads and drop every cache tier: no
            # stale host/device entry of an old layout can ever be served
            self.store.close()
        self.pg = pg
        self.scheme = pg.scheme
        self.k = pg.k
        self.store = PartitionStore(pg, capacity_parts=self._cache_parts,
                                    capacity_bytes=self._cache_bytes,
                                    backing=self._backing,
                                    host_cache_parts=self._host_cache_parts,
                                    host_cache_bytes=self._host_cache_bytes,
                                    read_ahead=self._read_ahead,
                                    tracer=self.tracer,
                                    profiler=self.profiler)
        engine = self.engine_name
        if engine == "opat":
            from .opat import OPATEngine
            self.engine: QueryRunner = OPATEngine(
                pg, self.config, store=self.store, prefetch=self._prefetch,
                tracer=self.tracer, profiler=self.profiler)
        elif engine == "traditional":
            from .traditional_mp import TraditionalMPEngine
            self.engine = TraditionalMPEngine(
                pg, self._processors, self.config, store=self.store,
                tracer=self.tracer, profiler=self.profiler)
        else:
            from ..compat import make_part_mesh
            from .mapreduce_mp import MapReduceMPEngine
            mesh = self._mesh
            if mesh is None:
                mesh = make_part_mesh(pg.k)
            self.engine = MapReduceMPEngine(
                pg, mesh, self.config, heuristic=self.heuristic,
                store=self.store, tracer=self.tracer,
                profiler=self.profiler)

        # per-partition workload profile, accumulated across submits.
        # MapReduceMP runs as one compiled program with no host loop: it
        # now surfaces per-partition YIELD counters (carried through the
        # while_loop state), but still no per-partition LOAD sequence —
        # the profile flags that rather than passing off all-zeros as
        # load observations.
        self.observes_partition_counters = engine != "mapreduce"
        self._loads = np.zeros(self.k, dtype=np.int64)
        self._completed = np.zeros(self.k, dtype=np.int64)
        self._spawned = np.zeros(self.k, dtype=np.int64)
        # answer-span observations (host-side, engine-independent): how many
        # answer rows bound vertices in both p and q, and how often each
        # vertex was bound in a partition-spanning answer — the co-traversal
        # signals core/repartition.py reweights boundary edges with
        self._cospan = np.zeros((self.k, self.k), dtype=np.int64)
        self._vertex_span = np.zeros(self.graph.n_nodes, dtype=np.int64)
        self._span_sum = 0
        self._span_rows = 0
        self._queries_served = 0
        self._answers_served = 0
        # SLO serving accumulators (serving/frontend.py feeds these via
        # record_serving; empty for plain submit/submit_many sessions, and
        # workload_profile() only emits a "serving" block when non-empty —
        # keeping non-SLO profiles byte-identical)
        self._slo_counters: Dict[str, int] = {}
        self._slo_shed_reasons: Dict[str, int] = {}
        self._slo_latencies: Dict[str, List[float]] = {}
        self._slo_deadline: Dict[str, List[int]] = {}
        # latest per-class burn-rate snapshot (obs/profile.SloBurnMonitor
        # via record_serving): {cls: {window, misses, miss_fraction,
        # burn_rate, error_budget}}
        self._slo_burn: Dict[str, Dict[str, Any]] = {}

    # -- serving -----------------------------------------------------------

    def submit(self, query: Union[Query, DisjunctiveQuery],
               max_answers: Optional[int] = None,
               heuristic: Optional[str] = None,
               seed: Optional[int] = None) -> QueryResult:
        """Serve one query against the session's resident partitions.

        ``max_answers`` is the paper's "specified number of answers" K
        (per disjunct); ``heuristic``/``seed`` default to the session's.
        """
        disjuncts = (query.disjuncts if isinstance(query, DisjunctiveQuery)
                     else [query])
        h = heuristic if heuristic is not None else self.heuristic
        s = seed if seed is not None else self.seed
        stats0 = self.store.stats.copy()
        t0 = time.time()
        reports: List[RunReport] = []
        answers: Optional[np.ndarray] = None
        # the whole call runs against ONE pinned generation view: a
        # mutation or compaction landing mid-query never changes what this
        # query's loads resolve to (new submits pick up the latest view)
        view = self._view
        ctx = (self.store.viewing(view) if view is not None
               else contextlib.nullcontext())
        gen = int(view.generation) if view is not None else None
        with self.tracer.span("query", query=query.name, heuristic=h,
                              engine=self.engine_name,
                              generation=gen) as qsp, ctx:
            for q in disjuncts:
                plan = generate_plan(q, self.graph, self.catalog)
                rep = self.engine.run_request(RunRequest(
                    plan=plan, heuristic=h, max_answers=max_answers, seed=s))
                reports.append(rep)
                a = rep.answers
                answers = a if answers is None else np.unique(
                    np.concatenate([answers, a]), axis=0)
            qsp.set(n_answers=int(answers.shape[0]),
                    n_loads=sum(len(r.stats.loads) for r in reports))
        latency = time.time() - t0
        for rep in reports:
            rep.stats.generation = gen
        self._absorb(reports, answers)
        return QueryResult(name=query.name, answers=answers, reports=reports,
                           latency_s=latency,
                           load_stats=self.store.stats - stats0,
                           generation=gen)

    def scheduler(self, heuristic: Optional[str] = None,
                  seed: Optional[int] = None,
                  release_retired: bool = False,
                  fairness_gamma: float = 0.0) -> "Any":
        """A ``QueryScheduler`` bound to this session's store, engine, and
        catalog (core/scheduler.py) — the multi-query serving loop.
        ``heuristic`` is a *shared* ranking (default MAX-YIELD-SHARED);
        ``fairness_gamma`` weights the anti-starvation aging term
        (rounds-waiting × SNI) in that ranking.  Prefer ``submit_many``
        unless you need streaming admission, since only ``submit_many``
        feeds results into the workload profile."""
        from .heuristics import MAX_YIELD_SHARED
        from .scheduler import QueryScheduler
        return QueryScheduler(
            self,
            heuristic=heuristic if heuristic is not None else MAX_YIELD_SHARED,
            seed=seed, release_retired=release_retired,
            fairness_gamma=fairness_gamma)

    def frontend(self, **kwargs) -> "Any":
        """A ``ServingFrontend`` bound to this session
        (serving/frontend.py): continuous-arrival serving with admission
        control, cost prediction, deadline scheduling, and load shedding.
        Keyword arguments pass through (``slo_classes``, ``cost_model``,
        ``shed_policy``, ``replay_speed``, ...).  With no SLO classes the
        front end delegates to ``submit_many`` byte-identically."""
        from ..serving.frontend import ServingFrontend
        return ServingFrontend(self, **kwargs)

    def record_serving(self, *, counters: Dict[str, int],
                       shed_by_reason: Dict[str, int],
                       latencies: Dict[str, List[float]],
                       deadline_met: Dict[str, List[bool]],
                       slo_burn: Optional[Dict[str, Dict[str, Any]]] = None
                       ) -> None:
        """Fold one ``ServingFrontend.serve`` run's admission/shed counters
        and per-SLO-class latencies into the session's workload profile
        (the ``"serving"`` block of ``workload_profile()``).  ``slo_burn``
        is the front end's rolling error-budget burn snapshot (kept as
        latest-wins: the window is the monitor's, not the session's)."""
        for key, n in counters.items():
            self._slo_counters[key] = self._slo_counters.get(key, 0) + int(n)
        for reason, n in shed_by_reason.items():
            self._slo_shed_reasons[reason] = \
                self._slo_shed_reasons.get(reason, 0) + int(n)
        for cls, vals in latencies.items():
            self._slo_latencies.setdefault(cls, []).extend(
                float(v) for v in vals)
        for cls, oks in deadline_met.items():
            met = self._slo_deadline.setdefault(cls, [0, 0])
            for ok in oks:
                met[0] += int(bool(ok))
                met[1] += 1
        if slo_burn:
            for cls, snap in slo_burn.items():
                self._slo_burn[cls] = dict(snap)

    def submit_many(self, queries: Sequence[Union[Query, DisjunctiveQuery]],
                    max_answers: Union[None, int,
                                       Sequence[Optional[int]]] = None,
                    heuristic: Optional[str] = None,
                    seed: Optional[int] = None,
                    release_retired: bool = False,
                    fairness_gamma: float = 0.0) -> "Any":
        """Serve a batch of queries through the shared-load scheduler and
        return its ``ScheduleReport`` (``.results`` holds one
        ``QueryResult`` per query, in input order).  ``max_answers`` is
        one per-disjunct budget K for the whole batch, or a per-query
        sequence of budgets (None entries = exhaustive).

        Semantics match a loop of ``submit`` calls — same per-query answer
        sets when exhaustive, same per-disjunct budget K, and every result
        is absorbed into the workload profile exactly as single submits
        are — but on the OPAT path the partition-load sequence is chosen
        at the *workload* level, so overlapping queries share cold loads
        and each ``QueryResult.load_stats`` reports the loads that query
        participated in (round-scoped, never other queries' traffic).
        """
        if isinstance(max_answers, (list, tuple)):
            budgets = list(max_answers)
            if len(budgets) != len(queries):
                raise ValueError(f"got {len(budgets)} budgets for "
                                 f"{len(queries)} queries")
        else:
            budgets = [max_answers] * len(queries)
        sched = self.scheduler(heuristic=heuristic, seed=seed,
                               release_retired=release_retired,
                               fairness_gamma=fairness_gamma)
        try:
            for q, b in zip(queries, budgets):
                sched.admit(q, max_answers=b)
            report = sched.run()
        finally:
            sched.close()   # drop the scheduler's generation pin
        for res in report.results:
            self._absorb(res.reports, res.answers)
        return report

    def _absorb(self, reports: List[RunReport], answers: np.ndarray) -> None:
        from .repartition import answer_span_matrix
        for rep in reports:
            for pid in rep.stats.loads:
                self._loads[pid] += 1
            st = rep.extra.get("state")
            if st is not None:     # OPAT / TraditionalMP expose QueryState
                self._completed += st.completed_from
                self._spawned += st.spawned_from
            elif rep.extra.get("completed_from") is not None:
                # MapReduceMP: yield counters carried through the device
                # while_loop and surfaced as plain [k] arrays
                self._completed += rep.extra["completed_from"]
                self._spawned += rep.extra["spawned_from"]
        pairs, span = answer_span_matrix(self.pg.owner, answers, self.k)
        self._cospan += pairs
        spanning = answers[span >= 2]
        if spanning.size:
            ids = spanning[spanning >= 0]
            np.add.at(self._vertex_span, ids, 1)
        self._span_sum += int(span.sum())
        self._span_rows += int(span.shape[0])
        self._queries_served += 1
        self._answers_served += int(answers.shape[0])

    # -- observability -----------------------------------------------------

    @property
    def load_stats(self) -> LoadStats:
        """Lifetime store counters (cold/warm/evictions/prefetch)."""
        return self.store.stats

    def workload_profile(self) -> Dict[str, Any]:
        """Per-partition load/yield/completion-rate profile of everything
        this session served, plus the answer-span (co-traversal) matrix and
        the assignment it was observed under — exactly what
        ``core/repartition.py`` consumes to produce the ``"waw"`` layout
        (WawPart, arXiv:2203.14888), and what ``launch/serve.py --json``
        embeds for CI.

        ``partition_counters_observed`` is False for MapReduceMP: yield
        counters (completed/spawned) ARE carried through the device
        while_loop and absorbed, but there is no host loop and hence no
        per-partition LOAD sequence, so the repartitioner skips its
        load-share split-pressure term; the ``answer_spans`` block is
        observed host-side from the answers and is valid for every engine.

        Sessions served through the SLO front end additionally carry a
        ``"serving"`` block: admission/degrade/shed counters, shed reasons,
        and per-SLO-class p50/p95/p99 latency + deadline attainment.  Plain
        sessions emit no such block, so their profiles stay byte-identical
        to pre-SLO builds.
        """
        pending = (self._mdir.pending_counts()
                   if self._mdir is not None else None)
        partitions = []
        for p in range(self.k):
            comp = int(self._completed[p])
            spawn = int(self._spawned[p])
            entry = {
                "pid": p,
                "loads": int(self._loads[p]),
                "completed": comp,
                "spawned": spawn,
                # Laplace-smoothed, matching heuristics.MAX_YIELD
                "completion_rate": (comp + 1.0) / (comp + spawn + 2.0),
            }
            if pending is not None:
                # per-partition pending delta volume: the hot-update
                # signal continuous repartitioning (fold) keys off
                entry["delta_count"] = int(pending[p])
            partitions.append(entry)
        profile: Dict[str, Any] = {
            "engine": self.engine_name,
            "scheme": self.scheme,
            "k": self.k,
            "heuristic": self.heuristic,
            "partition_counters_observed": self.observes_partition_counters,
            "queries_served": self._queries_served,
            "answers_served": self._answers_served,
            "partitions": partitions,
            "answer_spans": {
                "answers_observed": self._span_rows,
                "mean_span": (self._span_sum / self._span_rows
                              if self._span_rows else 0.0),
                "pair_counts": self._cospan.tolist(),
                # per-vertex: #spanning answers (span >= 2) binding it; the
                # edge-level co-traversal signal for reweight_edges
                "vertex_span_counts": self._vertex_span.tolist(),
            },
            # the [V] assignment the counters refer to, so a saved profile
            # is self-contained for repartition_assignment()
            "assignment": self.pg.assignment.astype(int).tolist(),
            # out-of-core sessions: disk_reads / read_ahead_* land here too
            # (the LoadStats dict is field-complete by construction)
            "out_of_core": self.out_of_core,
            "cache": self.store.stats.to_dict(),
        }
        if self._mdir is not None:
            profile["generation"] = int(self._view.generation)
            profile["pending_deltas"] = int(sum(pending))
            profile["compactions"] = int(self._mdir.compactions)
        if self._slo_counters or self._slo_latencies:
            def _pct(vals: List[float], q: float) -> float:
                return float(np.percentile(np.asarray(vals), q * 100.0)) \
                    if vals else 0.0
            profile["serving"] = {
                "counters": dict(sorted(self._slo_counters.items())),
                "shed_by_reason": dict(sorted(
                    self._slo_shed_reasons.items())),
                "classes": {
                    cls: {
                        "served": len(vals),
                        "p50_latency_s": _pct(vals, 0.5),
                        "p95_latency_s": _pct(vals, 0.95),
                        "p99_latency_s": _pct(vals, 0.99),
                        "deadline_met": self._slo_deadline.get(
                            cls, [0, 0])[0],
                        "deadline_total": self._slo_deadline.get(
                            cls, [0, 0])[1],
                    }
                    for cls, vals in sorted(self._slo_latencies.items())
                },
            }
        return profile

    def save_profile(self, path: str) -> None:
        """Persist ``workload_profile()`` as JSON — the self-contained
        input of ``core/repartition.py`` (and the CI serve artifact)."""
        with open(path, "w") as f:
            json.dump(self.workload_profile(), f, indent=2)

    # -- out-of-core storage (src/repro/storage/) --------------------------

    @property
    def out_of_core(self) -> bool:
        """True when partitions are disk-resident (session built by
        ``open``; a later ``repartition()`` moves back in-RAM until the
        new layout is ``save``d)."""
        return self._backing is not None

    def save(self, path: str) -> Dict[str, Any]:
        """Write this session's partitioned graph as a *graph directory*
        (storage/format.py: ``manifest.json`` + one ``part-<pid>.npz``
        shard per partition + ``graph.npz``); returns the manifest.
        Works for in-RAM and disk-opened sessions alike (the latter
        streams shards one at a time, never holding the graph's partition
        bytes in memory); the manifest is written last, so an interrupted
        save never yields an openable directory and re-saving over a live
        one leaves the old shards intact until the fresh manifest lands.
        """
        from ..storage.format import save_partitioned_graph
        return save_partitioned_graph(self.pg, path)

    @classmethod
    def open(cls, path: str, *,
             engine: str = "opat",
             heuristic: str = MAX_SN,
             config: Optional[EngineConfig] = None,
             cache_parts: Optional[int] = None,
             cache_bytes: Optional[int] = None,
             host_cache_parts: Optional[int] = None,
             host_cache_bytes: Optional[int] = None,
             read_ahead: bool = True,
             processors: int = 2,
             prefetch: bool = True,
             seed: int = 0,
             mesh: Optional[Any] = None,
             verify_checksums: bool = True,
             tracer: Optional[Any] = None,
             profiler: Optional[Any] = None) -> "GraphSession":
        """Open a ``save``d graph directory as an *out-of-core* session.

        Partition shards stay on disk; the store serves them through a
        three-tier cache — device LRU (``cache_parts``/``cache_bytes``)
        over a pinned-host LRU (``host_cache_parts``/``host_cache_bytes``,
        None = unbounded) over disk — and ``read_ahead`` pulls the
        heuristic's runner-up off disk on a background thread while the
        current partition evaluates.  Heuristic ranking and scheduler
        admission read the manifest catalog, so they never touch a shard.
        Answers are identical to a session over the in-RAM graph; only
        residency (and ``LoadStats.disk_reads`` / ``read_ahead_hits``)
        differs.

        The directory opens *mutable* (storage/deltas.py): the session
        binds a pinned generation view, ``mutate``/``add_edge``/... append
        durable delta records, and ``compact``/``fold`` publish new
        generations — in-flight queries keep their pinned view, new
        submits pick up the latest.
        """
        from ..storage.deltas import open_mutable
        mdir = open_mutable(path, verify_checksums=verify_checksums)
        view = mdir.snapshot()
        pg = view.as_partitioned_graph()
        sess = cls(pg=pg, engine=engine, heuristic=heuristic, config=config,
                   cache_parts=cache_parts, cache_bytes=cache_bytes,
                   host_cache_parts=host_cache_parts,
                   host_cache_bytes=host_cache_bytes, read_ahead=read_ahead,
                   processors=processors, prefetch=prefetch, seed=seed,
                   mesh=mesh, tracer=tracer, profiler=profiler)
        sess._mdir = mdir
        sess._view = view
        # the directory's writes (append/compact/overlay rebuild) trace
        # into the same stream as the session that owns it
        mdir.tracer = sess.tracer
        return sess

    # -- streaming updates (storage/deltas.py) -----------------------------

    @property
    def mutable(self) -> bool:
        """True when the session owns a writable graph directory."""
        return self._mdir is not None

    @property
    def current_view(self):
        """The session's pinned GenerationView (None: in-RAM session)."""
        return self._view

    @property
    def generation(self) -> Optional[int]:
        """Generation new submits run against (None: in-RAM session)."""
        return int(self._view.generation) if self._view is not None else None

    def _require_mutable(self) -> "Any":
        if self._mdir is None:
            raise RuntimeError(
                "streaming updates need a disk-backed session — build one "
                "with GraphSession.open(path) over a save()d directory")
        return self._mdir

    def snapshot(self):
        """A fresh pinned GenerationView of the latest generation + deltas
        (caller releases).  While any snapshot stays pinned, the files its
        generation needs survive every later compaction's GC."""
        return self._require_mutable().snapshot()

    def _refresh_view(self) -> None:
        """Re-pin the latest generation and rebind the pg-level state on
        top of the UNCHANGED store — generation-qualified cache keys keep
        old-view entries valid for their pins while new submits resolve
        against the new view; nothing is invalidated."""
        mdir = self._mdir
        old = self._view
        self._view = mdir.snapshot()
        if old is not None:
            old.release()
        pg = self._view.as_partitioned_graph()
        self.pg = pg
        self.graph = pg.graph
        self.catalog = build_catalog(self.graph)
        self.engine.pg = pg
        self.store.pg = pg
        self.store.backing = mdir.catalog
        self.store.host_tier.catalog = mdir.catalog
        self._backing = mdir.catalog
        if self._vertex_span.shape[0] < self.graph.n_nodes:
            self._vertex_span = np.concatenate([
                self._vertex_span,
                np.zeros(self.graph.n_nodes - self._vertex_span.shape[0],
                         dtype=np.int64)])

    def mutate(self, ops: Sequence[Dict[str, Any]]) -> List[Any]:
        """Apply a batch of update operations durably (each a dict:
        ``{"op": "edge_add"|"edge_del"|"vertex_add"|"vertex_del", ...}``,
        see ``MutableGraphDirectory.apply_op``) and advance the session's
        view once.  Returns the appended ``DeltaRecord``s."""
        mdir = self._require_mutable()
        recs = [mdir.apply_op(d) for d in ops]
        self._refresh_view()
        return recs

    def add_edge(self, u: int, v: int, label: str,
                 directed: bool = False) -> "Any":
        rec = self._require_mutable().add_edge(u, v, label, directed=directed)
        self._refresh_view()
        return rec

    def del_edge(self, u: int, v: int, label: str) -> "Any":
        rec = self._require_mutable().del_edge(u, v, label)
        self._refresh_view()
        return rec

    def add_vertex(self, label: str, value: float = float("nan"),
                   pid: Optional[int] = None) -> "Any":
        rec = self._require_mutable().add_vertex(label, value=value, pid=pid)
        self._refresh_view()
        return rec

    def del_vertex(self, gid: int) -> "Any":
        rec = self._require_mutable().del_vertex(gid)
        self._refresh_view()
        return rec

    def compact(self, pid: int) -> int:
        """Fold one partition's pending deltas into a fresh shard
        generation (manifest commit is the publish point) and advance the
        session's view; returns the published generation.  Queries pinned
        to older views keep serving them until released."""
        mdir = self._require_mutable()
        gen = mdir.compact(int(pid))
        self._refresh_view()
        return gen

    def compact_all(self) -> int:
        mdir = self._require_mutable()
        gen = mdir.compact_all()
        self._refresh_view()
        return gen

    def compact_hot(self, min_pending: int = 1) -> List[int]:
        """Compact every partition with at least ``min_pending`` pending
        delta records — the background maintenance policy the mutation
        soak (launch/serve.py --mutate-workload) runs between queries.
        Returns the pids compacted."""
        mdir = self._require_mutable()
        pending = mdir.pending_counts()
        hot = [p for p in range(self.k) if int(pending[p]) >= min_pending]
        for p in hot:
            mdir.compact(p)
        if hot:
            self._refresh_view()
        return hot

    def fold(self, repartition: bool = False, *,
             seed: Optional[int] = None,
             config: Optional[Any] = None) -> Dict[str, Any]:
        """Fold the overlay into a brand-new full layout on disk and
        rebind the session to it — the heavyweight maintenance step
        ``compact`` amortizes away, and (with ``repartition=True``) the
        continuous-repartitioning trigger: hot-update partitions observed
        by ``workload_profile()`` reshape the layout, the new generation
        is re-``save``d in the background of pinned readers, and the
        session ``open``s it live.  Returns the published manifest."""
        mdir = self._require_mutable()
        if repartition:
            from .repartition import RepartitionConfig, repartition as _repart
            cfg = config if config is not None else RepartitionConfig()
            new_pg = _repart(self.pg, self.workload_profile(),
                             seed=seed, config=cfg)
            self.repartitions += 1
        else:
            new_pg = build_partitions(
                self.graph,
                np.asarray(self._view.assignment, dtype=np.int64),
                self.k, scheme=self.scheme)
        manifest = mdir.resave(new_pg)
        old = self._view
        self._view = mdir.snapshot()
        if old is not None:
            old.release()
        self._backing = mdir.catalog
        # a full re-layout invalidates pid meanings — rebind the whole
        # stack (store, engine, profile counters), exactly as
        # ``repartition()`` does for in-RAM sessions
        self._bind(self._view.as_partitioned_graph())
        self.graph = self.pg.graph
        self.catalog = build_catalog(self.graph)
        return manifest

    # -- the WawPart loop --------------------------------------------------

    def repartition(self, profile: Optional[Any] = None, *,
                    seed: Optional[int] = None,
                    config: Optional[Any] = None) -> Dict[str, Any]:
        """Re-layout the graph from observed traffic and rebind the session.

        ``profile`` is a ``workload_profile()`` dict or a
        ``save_profile()`` JSON path; None uses everything this session has
        served so far.  The store, compiled evaluators, and engine are
        rebuilt against the new assignment — cached single-partition
        entries and stacked bundles of the old layout are all invalidated
        (their pids/paddings no longer mean the same thing) — and the
        profile counters restart from zero for the new layout.  The graph,
        catalog, engine choice, cache capacities, and k are unchanged.

        Returns a summary dict: scheme/cut before and after, k, and which
        repartition round this is (``GraphSession.repartitions``).
        """
        from .partition import partition_quality
        from .repartition import RepartitionConfig, repartition as _repart
        prof = profile if profile is not None else self.workload_profile()
        cfg = config if config is not None else RepartitionConfig()
        before = partition_quality(self.graph, self.pg.assignment, self.k)
        new_pg = _repart(self.pg, prof, seed=seed, config=cfg)
        # a disk-opened session's backing names the OLD layout's shards —
        # drop it before rebinding so the fresh store pins the new in-RAM
        # partitions instead (and _bind closes the old store, joining any
        # in-flight read-ahead and invalidating its host-cache entries).
        # The graph directory on disk is untouched until save() writes
        # the new layout back (fresh manifest last).  A mutable session
        # moves in-RAM too: its view pin is released and further mutate()
        # calls raise (use fold(repartition=True) to re-layout in place).
        if self._view is not None:
            self._view.release()
            self._view = None
            self._mdir = None
        self._backing = None
        self._bind(new_pg)
        self.repartitions += 1
        after = partition_quality(self.graph, new_pg.assignment, self.k)
        return {"round": self.repartitions, "k": self.k,
                "scheme": self.scheme,
                "cut_before": before["cut"], "cut_after": after["cut"],
                "imbalance_after": after["imbalance"]}
