"""Multilevel graph partitioning (METIS / KaHIP stand-ins).

The paper partitions with two external systems (METIS, KaHIP) in six named
configurations.  Those binaries are not available offline, so we implement a
faithful multilevel scheme — coarsen / initial-partition / uncoarsen+refine —
with the same knobs the paper varies:

  coarsening  : 'shem' (sorted heavy-edge matching, METIS-style) or
                'lp'   (label-propagation clustering, KaHIP *social-variant*)
  initial     : 'kway' (greedy k-region growing) or
                'rb'   (recursive bisection)
  refinement  : #boundary-FM rounds ('fast'=1, default=2, 'eco'=3)

The six paper schemes map onto these knobs in SCHEMES below.  The partitioner
is deliberately host-side numpy — partitioning is offline preprocessing in
the paper's pipeline too (Fig. 3's unshaded modules).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .graph import Graph


@dataclasses.dataclass(frozen=True)
class PartitionScheme:
    name: str
    coarsening: str          # 'shem' | 'lp'
    initial: str             # 'kway' | 'rb'
    refine_rounds: int
    imbalance: float = 0.06  # allowed deviation from perfect balance
    seed: int = 0


SCHEMES: Dict[str, PartitionScheme] = {
    # METIS configurations used in the paper (Sec. 3)
    "kway_shem": PartitionScheme("kway_shem", "shem", "kway", 2, seed=11),
    "rb_shem": PartitionScheme("rb_shem", "shem", "rb", 2, seed=12),
    # KaHIP configurations used in the paper
    "fast": PartitionScheme("fast", "shem", "kway", 1, seed=13),
    "eco": PartitionScheme("eco", "shem", "kway", 3, seed=14),
    "fastsocial": PartitionScheme("fastsocial", "lp", "kway", 1, seed=15),
    "ecosocial": PartitionScheme("ecosocial", "lp", "kway", 3, seed=16),
}


# ---------------------------------------------------------------------------
# CSR helpers on (possibly weighted) host graphs
# ---------------------------------------------------------------------------

def _sym_csr(n: int, src: np.ndarray, dst: np.ndarray,
             w: Optional[np.ndarray] = None
             ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    if w is None:
        w = np.ones(src.shape[0], dtype=np.int64)
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    ww = np.concatenate([w, w])
    order = np.argsort(s, kind="stable")
    s, d, ww = s[order], d[order], ww[order]
    ptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(ptr, s + 1, 1)
    return np.cumsum(ptr), d.astype(np.int64), ww.astype(np.int64)


def _edge_cut(assign: np.ndarray, src: np.ndarray, dst: np.ndarray,
              w: Optional[np.ndarray] = None) -> int:
    cut = assign[src] != assign[dst]
    if w is None:
        return int(cut.sum())
    return int(w[cut].sum())


# ---------------------------------------------------------------------------
# Coarsening
# ---------------------------------------------------------------------------

def _match_shem(n: int, ptr, adj, w, vwgt, rng) -> np.ndarray:
    """Sorted heavy-edge matching: visit vertices in ascending-degree order,
    match each unmatched vertex with its heaviest-edge unmatched neighbour."""
    deg = np.diff(ptr)
    order = np.argsort(deg, kind="stable")
    match = np.full(n, -1, dtype=np.int64)
    for v in order:
        if match[v] != -1:
            continue
        best, best_w = -1, -1
        for idx in range(ptr[v], ptr[v + 1]):
            u = adj[idx]
            if u != v and match[u] == -1 and w[idx] > best_w:
                best, best_w = u, w[idx]
        if best == -1:
            match[v] = v
        else:
            match[v] = best
            match[best] = v
    return match


def _match_lp(n: int, ptr, adj, w, vwgt, rng, rounds: int = 2) -> np.ndarray:
    """Label-propagation clustering (size-constrained) — the coarsening used
    by KaHIP's *social* configurations for social-network-like graphs."""
    label = np.arange(n, dtype=np.int64)
    max_cluster = max(2, int(np.ceil(vwgt.sum() / max(1, n // 16))))
    csize = vwgt.astype(np.int64).copy()
    for _ in range(rounds):
        order = rng.permutation(n)
        for v in order:
            s, e = ptr[v], ptr[v + 1]
            if s == e:
                continue
            neigh = label[adj[s:e]]
            # accumulate edge weight toward each neighbouring label
            uniq, inv = np.unique(neigh, return_inverse=True)
            score = np.zeros(uniq.shape[0], dtype=np.int64)
            np.add.at(score, inv, w[s:e])
            # respect the size constraint so coarsening stays balanced
            ok = csize[uniq] + vwgt[v] <= max_cluster
            ok |= uniq == label[v]
            if not ok.any():
                continue
            score = np.where(ok, score, -1)
            tgt = int(uniq[int(np.argmax(score))])
            if tgt != label[v]:
                csize[label[v]] -= vwgt[v]
                csize[tgt] += vwgt[v]
                label[v] = tgt
    return label


def _contract(n: int, src, dst, w, vwgt, cluster_of) -> Tuple[int, np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    uniq, new_of = np.unique(cluster_of, return_inverse=True)
    cn = uniq.shape[0]
    cvw = np.zeros(cn, dtype=np.int64)
    np.add.at(cvw, new_of, vwgt)
    cs, cd = new_of[src], new_of[dst]
    keep = cs != cd
    cs, cd, cw = cs[keep], cd[keep], w[keep]
    # merge parallel edges
    lo, hi = np.minimum(cs, cd), np.maximum(cs, cd)
    key = lo * cn + hi
    uk, inv = np.unique(key, return_inverse=True)
    mw = np.zeros(uk.shape[0], dtype=np.int64)
    np.add.at(mw, inv, cw)
    return cn, (uk // cn).astype(np.int64), (uk % cn).astype(np.int64), mw, cvw, new_of


# ---------------------------------------------------------------------------
# Initial partitioning
# ---------------------------------------------------------------------------

def _greedy_grow_kway(n, ptr, adj, w, vwgt, k, rng, imbalance) -> np.ndarray:
    """Greedy k-region growing from spread-out seeds (METIS kway flavor)."""
    target = vwgt.sum() / k
    cap = target * (1.0 + imbalance)
    assign = np.full(n, -1, dtype=np.int64)
    sizes = np.zeros(k, dtype=np.int64)
    deg = np.diff(ptr)
    seeds = list(np.argsort(-deg)[: 4 * k])
    rng.shuffle(seeds)
    frontiers: List[List[int]] = [[] for _ in range(k)]
    si = 0
    for p in range(k):
        while si < len(seeds) and assign[seeds[si]] != -1:
            si += 1
        s = seeds[si] if si < len(seeds) else int(np.argmax(assign == -1))
        assign[s] = p
        sizes[p] += vwgt[s]
        frontiers[p].append(int(s))
    active = True
    while active:
        active = False
        for p in np.argsort(sizes):  # grow smallest region first
            f = frontiers[p]
            grew = False
            while f and not grew:
                v = f.pop()
                for idx in range(ptr[v], ptr[v + 1]):
                    u = int(adj[idx])
                    if assign[u] == -1 and sizes[p] + vwgt[u] <= cap:
                        assign[u] = p
                        sizes[p] += vwgt[u]
                        f.append(u)
                        grew = True
                        active = True
        if not active:
            break
    # orphans (disconnected leftovers) -> smallest partition
    for v in np.where(assign == -1)[0]:
        p = int(np.argmin(sizes))
        assign[v] = p
        sizes[p] += vwgt[v]
    return assign


def _bisect(n, ptr, adj, w, vwgt, rng, imbalance) -> np.ndarray:
    """Greedy BFS bisection + one FM sweep (building block of 'rb')."""
    total = vwgt.sum()
    half = total / 2.0
    deg = np.diff(ptr)
    seed = int(np.argmax(deg)) if n else 0
    side = np.ones(n, dtype=np.int64)
    size0 = 0
    queue = [seed]
    seen = np.zeros(n, dtype=bool)
    seen[seed] = True
    while queue and size0 < half:
        v = queue.pop(0)
        side[v] = 0
        size0 += vwgt[v]
        for idx in range(ptr[v], ptr[v + 1]):
            u = int(adj[idx])
            if not seen[u]:
                seen[u] = True
                queue.append(u)
    return side


def _initial_rb(n, ptr, adj, w, vwgt, k, rng, imbalance, src, dst) -> np.ndarray:
    """Recursive bisection down to k parts (requires k power-of-two-ish;
    uneven k splits proportionally)."""
    assign = np.zeros(n, dtype=np.int64)

    def rec(nodes: np.ndarray, lo: int, hi: int) -> None:
        if hi - lo <= 1 or nodes.size == 0:
            assign[nodes] = lo
            return
        mid = (lo + hi) // 2
        # build the induced subgraph
        remap = np.full(n, -1, dtype=np.int64)
        remap[nodes] = np.arange(nodes.size)
        mask = (remap[src] >= 0) & (remap[dst] >= 0)
        ssrc, sdst, sw = remap[src[mask]], remap[dst[mask]], w[mask]
        sptr, sadj, sww = _sym_csr(nodes.size, ssrc, sdst, sw)
        side = _bisect(nodes.size, sptr, sadj, sww, vwgt[nodes], rng, imbalance)
        rec(nodes[side == 0], lo, mid)
        rec(nodes[side == 1], mid, hi)

    rec(np.arange(n, dtype=np.int64), 0, k)
    return assign


# ---------------------------------------------------------------------------
# Refinement: boundary FM (gain-based moves under a balance constraint)
# ---------------------------------------------------------------------------

def _refine_fm(n, ptr, adj, w, vwgt, assign, k, rounds, imbalance) -> np.ndarray:
    target = vwgt.sum() / k
    cap = target * (1.0 + imbalance)
    sizes = np.zeros(k, dtype=np.int64)
    np.add.at(sizes, assign, vwgt)
    for _ in range(rounds):
        moved = 0
        for v in range(n):
            s, e = ptr[v], ptr[v + 1]
            if s == e:
                continue
            me = assign[v]
            neigh = assign[adj[s:e]]
            if (neigh == me).all():
                continue  # interior vertex
            uniq, inv = np.unique(neigh, return_inverse=True)
            gain_to = np.zeros(uniq.shape[0], dtype=np.int64)
            np.add.at(gain_to, inv, w[s:e])
            internal = gain_to[uniq == me].sum() if (uniq == me).any() else 0
            best_gain, best_p = 0, -1
            for ui, p in enumerate(uniq):
                if p == me:
                    continue
                if sizes[p] + vwgt[v] > cap:
                    continue
                g = gain_to[ui] - internal
                if g > best_gain:
                    best_gain, best_p = g, int(p)
            if best_p >= 0:
                sizes[me] -= vwgt[v]
                sizes[best_p] += vwgt[v]
                assign[v] = best_p
                moved += 1
        if moved == 0:
            break
    return assign


# ---------------------------------------------------------------------------
# Multilevel driver
# ---------------------------------------------------------------------------

def partition_graph(graph: Graph, k: int, scheme: str | PartitionScheme,
                    seed: Optional[int] = None,
                    edge_weights: Optional[np.ndarray] = None) -> np.ndarray:
    """Partition ``graph`` into ``k`` parts; returns [V] assignment array.

    ``edge_weights`` (optional, [E] ints >= 1 aligned with ``graph.edge_src``)
    biases every phase — heavy edges are matched first during coarsening,
    resist the cut during initial partitioning, and dominate FM gains — which
    is how workload-aware repartitioning (core/repartition.py) steers the
    same multilevel machinery with observed traffic instead of topology
    alone.  ``None`` keeps the paper's unweighted behaviour bit-for-bit.
    """
    sch = SCHEMES[scheme] if isinstance(scheme, str) else scheme
    rng = np.random.default_rng(sch.seed if seed is None else seed)
    n = graph.n_nodes
    if k <= 1 or n <= k:
        return np.minimum(np.arange(n, dtype=np.int64), k - 1).astype(np.int32)

    src = graph.edge_src.astype(np.int64)
    dst = graph.edge_dst.astype(np.int64)
    if edge_weights is None:
        w = np.ones(src.shape[0], dtype=np.int64)
    else:
        w = np.asarray(edge_weights, dtype=np.int64)
        if w.shape != src.shape:
            raise ValueError(f"edge_weights shape {w.shape} != E {src.shape}")
        if w.size and w.min() < 1:
            raise ValueError("edge_weights must be >= 1 (0 would make the "
                             "coarsener blind to the edge)")
    vwgt = np.ones(n, dtype=np.int64)

    # --- coarsening phase ---------------------------------------------------
    levels: List[np.ndarray] = []   # new_of maps at each level
    cn, cs, cd, cw, cvw = n, src, dst, w, vwgt
    coarsen_target = max(30 * k, 64)
    while cn > coarsen_target:
        ptr, adj, ww = _sym_csr(cn, cs, cd, cw)
        if sch.coarsening == "lp":
            cluster = _match_lp(cn, ptr, adj, ww, cvw, rng)
        else:
            match = _match_shem(cn, ptr, adj, ww, cvw, rng)
            cluster = np.minimum(np.arange(cn, dtype=np.int64), match)
        nn, ns, nd, nw, nvw, new_of = _contract(cn, cs, cd, cw, cvw, cluster)
        if nn >= cn * 0.95:  # matching stalled; stop coarsening
            break
        levels.append(new_of)
        cn, cs, cd, cw, cvw = nn, ns, nd, nw, nvw

    # --- initial partitioning -------------------------------------------------
    ptr, adj, ww = _sym_csr(cn, cs, cd, cw)
    if sch.initial == "rb":
        # NB: pass cw (edge-aligned weights), not ww (symmetrized CSR order)
        assign = _initial_rb(cn, ptr, adj, cw, cvw, k, rng, sch.imbalance, cs, cd)
    else:
        assign = _greedy_grow_kway(cn, ptr, adj, ww, cvw, k, rng, sch.imbalance)
    assign = _refine_fm(cn, ptr, adj, ww, cvw, assign, k, sch.refine_rounds, sch.imbalance)

    # --- uncoarsen + refine ---------------------------------------------------
    for li in range(len(levels) - 1, -1, -1):
        assign = assign[levels[li]]          # project onto the finer level
        # rebuild the level-li graph by re-contracting from the finest level
        ls, ld, lw, lvw = src, dst, w, vwgt
        for m in levels[:li]:
            _, ls, ld, lw, lvw, _ = _contract(lvw.shape[0], ls, ld, lw, lvw, m)
        lvl_n = lvw.shape[0]
        ptr, adj, ww = _sym_csr(lvl_n, ls, ld, lw)
        assign = _refine_fm(lvl_n, ptr, adj, ww, lvw, assign, k,
                            sch.refine_rounds, sch.imbalance)

    return assign.astype(np.int32)


def partition_quality(graph: Graph, assign: np.ndarray, k: int) -> dict:
    sizes = np.bincount(assign, minlength=k)
    cut = _edge_cut(assign, graph.edge_src, graph.edge_dst)
    return {
        "cut": cut,
        "cut_frac": cut / max(1, graph.n_edges),
        "sizes": sizes.tolist(),
        "imbalance": float(sizes.max() / max(1.0, graph.n_nodes / k) - 1.0),
    }
