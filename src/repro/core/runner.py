"""Unified host-orchestration layer: one API over all three engines.

The paper evaluates three strategies for processing a partitioned graph
query — OPAT, one partition at a time (Sec. 5-7); TraditionalMP, p
partitions in parallel per iteration (Sec. 8, Algorithm 1); and
MapReduceMP, map/reduce-style one-edge expansion with a shuffle (Sec. 9).
Its stated goal is to "obtain all or *specified number of* answers": the
load-ordering heuristics (Sec. 5) exist precisely so a K-answer request
touches as few partitions as possible.  This module is that contract as
code:

  ``RunRequest``   — a plan + heuristic + optional ``max_answers`` (the
                     paper's "specified number of answers", None = all)
  ``RunReport``    — answers (exactly ``min(K, total)`` unique rows when a
                     budget is set), the paper's ``RunStats`` metrics, and
                     engine-specific extras
  ``QueryRunner``  — the protocol all three engines implement via
                     ``run_request``; benchmarks and the serving driver
                     depend only on it

Budget semantics (identical across engines, asserted by
``tests/test_answer_budget.py``):

  * the run stops as soon as K unique answers exist — OPAT checks the FAA
    between partition loads, TraditionalMP after each top-p merge, and
    MapReduceMP folds a global ``psum`` of per-device answer counts into
    its on-device ``lax.while_loop`` stop condition (no host round-trip);
  * the returned rows are a deterministic subset of the exhaustive run's
    answer set (unique rows in lexicographic order, truncated to K);
  * ``RunStats.answers_requested`` records K, and
    ``RunStats.loads_saved_vs_full`` (filled by the benchmark harness)
    records how many partition loads the budget avoided — the paper's
    response-time-vs-scalability trade-off made measurable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Protocol, runtime_checkable

import numpy as np

from .heuristics import MAX_SN
from .metrics import RunStats
from .plan import Plan


@dataclasses.dataclass(frozen=True)
class RunRequest:
    """One query execution request, engine-agnostic."""

    plan: Plan
    heuristic: str = MAX_SN
    max_answers: Optional[int] = None   # None = run to exhaustion
    seed: int = 0

    def __post_init__(self):
        if self.max_answers is not None and self.max_answers < 0:
            raise ValueError(f"max_answers must be >= 0 or None, "
                             f"got {self.max_answers}")


@dataclasses.dataclass
class RunReport:
    """Engine-agnostic result: what serving and benchmarks consume."""

    answers: np.ndarray        # [n, q_pad] unique rows; n == min(K, total)
    stats: RunStats
    engine: str                # "opat" | "traditional" | "mapreduce"
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def n_answers(self) -> int:
        return int(self.answers.shape[0])


@runtime_checkable
class QueryRunner(Protocol):
    """What every evaluation engine exposes to callers."""

    def run_request(self, req: RunRequest) -> RunReport: ...


def truncate_answers(answers: np.ndarray,
                     max_answers: Optional[int]) -> np.ndarray:
    """Deterministic K-truncation: unique rows are already in lexicographic
    order (np.unique), so given the same found-answer set the same K rows
    are returned; every returned row is an answer the exhaustive run also
    finds."""
    if max_answers is None:
        return answers
    return answers[:max_answers]
