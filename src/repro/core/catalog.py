"""Graph catalog (QP-Subdue style metadata, paper Sec. 3).

Built in a single pass over the graph database; contains the statistics the
cost-based planner consumes:

  * type cardinality            — #nodes per node label
  * average instance cardinality — #nodes / #distinct labels
  * connection cardinality      — #edges per (src_label, edge_label, dst_label)
  * min / max numeric value per node label
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from .graph import Graph, WILDCARD


@dataclasses.dataclass
class Catalog:
    n_nodes: int
    n_edges: int
    type_card: np.ndarray                         # [n_node_labels] int64
    avg_instance_card: float
    # connection cardinality keyed by (src_label, edge_label, dst_label);
    # symmetrized (both orientations present).
    conn_card: Dict[Tuple[int, int, int], int]
    # per-(edge_label) totals for wildcard estimates
    edge_label_card: np.ndarray                   # [n_edge_labels] int64
    value_min: np.ndarray                         # [n_node_labels] float32
    value_max: np.ndarray                         # [n_node_labels] float32

    def label_cardinality(self, label_id: int) -> float:
        if label_id == WILDCARD:
            return float(self.n_nodes)
        if label_id < 0 or label_id >= self.type_card.shape[0]:
            return 0.0
        return float(self.type_card[label_id])

    def connection_cardinality(self, src_label: int, edge_label: int,
                               dst_label: int) -> float:
        """Estimated #edges matching (src_label)-[edge_label]-(dst_label),
        falling back to independence assumptions for wildcards."""
        if src_label != WILDCARD and edge_label != WILDCARD and dst_label != WILDCARD:
            return float(self.conn_card.get((src_label, edge_label, dst_label), 0))
        # wildcard fallbacks: scale the closest known aggregate
        if edge_label == WILDCARD:
            total = float(self.n_edges)
        elif 0 <= edge_label < self.edge_label_card.shape[0]:
            total = float(self.edge_label_card[edge_label])
        else:
            total = 0.0   # NO_MATCH edge label
        frac_src = self.label_cardinality(src_label) / max(1.0, self.n_nodes)
        frac_dst = self.label_cardinality(dst_label) / max(1.0, self.n_nodes)
        if src_label != WILDCARD:
            total *= frac_src * self._label_edge_bias(src_label)
        if dst_label != WILDCARD:
            total *= frac_dst * self._label_edge_bias(dst_label)
        return max(total, 0.0)

    def _label_edge_bias(self, label_id: int) -> float:
        # crude degree-bias correction; 1.0 keeps the independence estimate
        return 1.0

    def value_selectivity(self, label_id: int, op: int, value: float) -> float:
        """Fraction of label_id nodes surviving a value predicate (uniformity
        assumption over [min, max], as in relational optimizers)."""
        from .query import OP_EQ, OP_NE, OP_LT, OP_LE, OP_GT, OP_GE, OP_NONE
        if op == OP_NONE:
            return 1.0
        if label_id == WILDCARD:
            return 0.5 if op not in (OP_EQ,) else 0.1
        if label_id < 0 or label_id >= self.value_min.shape[0]:
            return 0.0   # NO_MATCH / unknown label: nothing survives
        lo = float(self.value_min[label_id])
        hi = float(self.value_max[label_id])
        if not np.isfinite(lo) or not np.isfinite(hi) or hi <= lo:
            return {OP_EQ: 0.1, OP_NE: 0.9}.get(op, 0.5)
        span = hi - lo
        if op == OP_EQ:
            return max(1.0 / max(2.0, self.label_cardinality(label_id)), 1e-6)
        if op == OP_NE:
            return 1.0 - max(1.0 / max(2.0, self.label_cardinality(label_id)), 1e-6)
        if op in (OP_LT, OP_LE):
            return float(np.clip((value - lo) / span, 0.0, 1.0))
        if op in (OP_GT, OP_GE):
            return float(np.clip((hi - value) / span, 0.0, 1.0))
        return 0.5


def build_catalog(graph: Graph) -> Catalog:
    n_nl = max(1, len(graph.node_vocab))
    n_el = max(1, len(graph.edge_vocab))
    type_card = np.bincount(graph.node_label, minlength=n_nl).astype(np.int64)
    edge_label_card = np.bincount(graph.edge_label, minlength=n_el).astype(np.int64)

    conn: Dict[Tuple[int, int, int], int] = {}
    sl = graph.node_label[graph.edge_src]
    dl = graph.node_label[graph.edge_dst]
    el = graph.edge_label
    # symmetrize: count both orientations (plans may expand either way)
    for a, e, b in zip(np.concatenate([sl, dl]), np.concatenate([el, el]),
                       np.concatenate([dl, sl])):
        key = (int(a), int(e), int(b))
        conn[key] = conn.get(key, 0) + 1

    vmin = np.full(n_nl, np.inf, dtype=np.float64)
    vmax = np.full(n_nl, -np.inf, dtype=np.float64)
    finite = np.isfinite(graph.node_value)
    if finite.any():
        np.minimum.at(vmin, graph.node_label[finite], graph.node_value[finite].astype(np.float64))
        np.maximum.at(vmax, graph.node_label[finite], graph.node_value[finite].astype(np.float64))
    vmin[~np.isfinite(vmin)] = np.nan
    vmax[~np.isfinite(vmax)] = np.nan

    return Catalog(
        n_nodes=graph.n_nodes,
        n_edges=graph.n_edges,
        type_card=type_card,
        avg_instance_card=graph.n_nodes / max(1, len(graph.node_vocab)),
        conn_card=conn,
        edge_label_card=edge_label_card,
        value_min=vmin.astype(np.float32),
        value_max=vmax.astype(np.float32),
    )
