"""OPAT — One Partition At a Time query evaluation (paper Sec. 5-7).

The host orchestrator mirrors the paper's PGQP loop exactly:

  1. build the initial SNI from start-label counts per partition,
  2. choose the next partition with the configured heuristic,
  3. run the jitted within-partition evaluator (= "load" the partition),
  4. route outgoing continuations into destination IMA files, append
     completed answers to the FAA, update the SNI,
  5. repeat until no partition is eligible.

Partition *loads* (including re-loads of the same partition, Fig. 4c) are
recorded for the load-ratio metrics.

Partition residency goes through a ``PartitionStore`` (core/store.py): a
load is *cold* when the store must ``device_put`` the partition and *warm*
when device buffers are reused — a re-load of an already-resident partition
(Fig. 4c) costs bookkeeping, not a transfer.  While one partition
evaluates, the engine prefetches the heuristic's runner-up so the next
pick's transfer overlaps the current evaluation (ROADMAP item #1);
``RunStats.cold_loads`` / ``warm_loads`` / ``prefetch_hits`` record the
split.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import numpy as np

from .engine import EngineConfig, make_partition_evaluator
from .graph import PartitionedGraph
from .heuristics import MAX_YIELD, rank_partitions
from .metrics import RunStats, l_ideal_for_plan
from .plan import Plan, PlanArrays
from .runner import RunReport, RunRequest, truncate_answers
from .state import BindingBatch, QueryState
from .store import PartitionStore, StoreEntry


@dataclasses.dataclass
class OPATResult:
    answers: np.ndarray          # [n, q_pad] global-vertex-id rows
    stats: RunStats
    state: QueryState


def absorb_eval_outputs(st: QueryState, pid: int, k: int,
                        comp_rows: np.ndarray, comp_n: int,
                        out_rows: np.ndarray, out_step: np.ndarray,
                        out_dest: np.ndarray, out_n: int) -> None:
    """Route one evaluator call's outputs into a query's bookkeeping state:
    completed rows append to the FAA, outgoing continuations land in their
    destination partitions' IMA files (deduped, paper Fig. 4c), and the
    partition's yield counters update.  Shared by the per-query OPAT loop
    and the scheduler's batched evaluation (core/scheduler.py), so the
    paper's bookkeeping cannot diverge between the two paths."""
    if comp_n:
        st.add_answers(np.asarray(comp_rows)[:comp_n])
    st.observe_yield(pid, comp_n, out_n)
    if out_n:
        rows = np.asarray(out_rows)[:out_n]
        step = np.asarray(out_step)[:out_n]
        dest = np.asarray(out_dest)[:out_n]
        for q in range(k):
            sel = dest == q
            if sel.any():
                st.ima[q] = st.ima[q].concat(
                    BindingBatch(rows=rows[sel], step=step[sel])).dedup()


class OPATEngine:
    """Reusable engine bound to one partitioned graph (one compile).

    ``store`` defaults to a private unbounded ``PartitionStore``; a
    ``GraphSession`` passes its own so residency (and its hit/miss
    accounting) is shared across queries.  ``prefetch`` stages the
    heuristic's runner-up partition while the chosen one evaluates.
    """

    def __init__(self, pg: PartitionedGraph, cfg: Optional[EngineConfig] = None,
                 store: Optional[PartitionStore] = None,
                 prefetch: bool = True,
                 tracer: Optional[Any] = None,
                 profiler: Optional[Any] = None):
        self.pg = pg
        self.cfg = cfg or EngineConfig()
        assert pg.node_pad > 0, "build_partitions(uniform_pad=True) required"
        self._eval = make_partition_evaluator(pg.node_pad, pg.ell_width,
                                              self.cfg)
        self._beval = None
        self.store = store if store is not None else PartitionStore(pg)
        self.prefetch = prefetch
        from ..obs.trace import NULL_TRACER
        self.tracer = tracer if tracer is not None else NULL_TRACER
        from ..obs.profile import NULL_PROFILER
        self.profiler = profiler if profiler is not None else NULL_PROFILER
        # flips after the first kernel call so the jit compile shows up as
        # a one-off "kernel.compile" child span, not steady-state eval time
        self._eval_traced = False

    def batched_evaluator(self):
        """The *plan-batched* partition evaluator: ``vmap`` of the compiled
        evaluator over the query axis with the partition inputs broadcast
        — the mirror image of TraditionalMP's partition-vmapped call.  One
        loaded partition advances B pending queries' plans in a single
        compiled call: inputs gain a leading [B] axis (stacked
        ``PlanArrays``, per-query n_steps / IMA rows / seed flags) while
        ``part``/``g2l``/``owner`` stay un-batched.  The scheduler
        (core/scheduler.py) pads B up to a bucket size so the jit cache
        holds one trace per bucket, reused across rounds.  Built lazily:
        per-query serving never pays for it."""
        if self._beval is None:
            self._beval = jax.jit(jax.vmap(
                self._eval, in_axes=(None, None, None, 0, 0, 0, 0, 0, 0)))
        return self._beval

    def _run_partition(self, entry: StoreEntry, plan_arrays: PlanArrays,
                       n_steps: int, batch: BindingBatch, seed_fresh: bool,
                       st: QueryState) -> None:
        cfg = self.cfg
        pid = int(entry.key)
        chunks: List[BindingBatch] = []
        if batch.n == 0:
            chunks.append(BindingBatch.empty(cfg.q_pad))
        else:
            for i in range(0, batch.n, cfg.cap):
                chunks.append(BindingBatch(rows=batch.rows[i : i + cfg.cap],
                                           step=batch.step[i : i + cfg.cap]))
        for ci, chunk in enumerate(chunks):
            in_rows = np.full((cfg.cap, cfg.q_pad), -1, dtype=np.int32)
            in_step = np.zeros(cfg.cap, dtype=np.int32)
            in_valid = np.zeros(cfg.cap, dtype=bool)
            if chunk.n:
                in_rows[: chunk.n] = chunk.rows
                in_step[: chunk.n] = chunk.step
                in_valid[: chunk.n] = True
            with self.tracer.span("kernel.eval", pid=pid, engine="opat",
                                  rows=int(chunk.n)) as ksp:
                if not self._eval_traced:
                    # the first call traces+compiles the jitted evaluator;
                    # nest that one-off under its own child span so
                    # steady-state eval time reads clean
                    self._eval_traced = True
                    ksp.set(first_call=True)
                    self.profiler.attribute_kernel(
                        ("opat", "eval"), self._eval, entry.part, entry.g2l,
                        self.store.owner, plan_arrays, np.int32(n_steps),
                        in_rows, in_step, in_valid,
                        np.bool_(seed_fresh and ci == 0))
                    with self.tracer.span("kernel.compile", engine="opat"):
                        res = self._eval(entry.part, entry.g2l,
                                         self.store.owner,
                                         plan_arrays, np.int32(n_steps),
                                         in_rows, in_step, in_valid,
                                         np.bool_(seed_fresh and ci == 0))
                else:
                    res = self._eval(entry.part, entry.g2l, self.store.owner,
                                     plan_arrays, np.int32(n_steps),
                                     in_rows, in_step, in_valid,
                                     np.bool_(seed_fresh and ci == 0))
                overflow = bool(res.overflow)   # device sync inside the span
                self.profiler.stamp_kernel(ksp, ("opat", "eval"))
                self.profiler.sample_device(ksp, self.store)
            if overflow:
                raise RuntimeError(
                    f"evaluator buffer overflow on partition {pid}; raise "
                    f"EngineConfig.cap (currently {cfg.cap})")
            absorb_eval_outputs(st, pid, self.pg.k,
                                res.comp_rows, int(res.comp_n),
                                res.out_rows, res.out_step, res.out_dest,
                                int(res.out_n))

    def run(self, plan: Plan, heuristic: str, seed: int = 0,
            max_loads: Optional[int] = None,
            max_answers: Optional[int] = None) -> OPATResult:
        cfg = self.cfg
        assert plan.n_slots <= cfg.q_pad and plan.n_steps <= cfg.s_pad
        rng = np.random.default_rng(seed)
        plan_arrays = PlanArrays.from_plan(plan, pad_steps=cfg.s_pad)
        counts = self.pg.start_label_counts(plan.start_label,
                                            plan.start_value_op,
                                            plan.start_value)
        st = QueryState.initial(self.pg.k, cfg.q_pad, counts,
                                track_answer_keys=max_answers is not None)
        limit = max_loads if max_loads is not None else 64 * self.pg.k
        load0 = self.store.stats.copy()

        while not st.budget_met(max_answers):
            eligible = st.eligible()
            if not eligible:
                break
            if len(st.loads) >= limit:
                raise RuntimeError("OPAT exceeded max partition loads "
                                   f"({limit}); likely a routing bug")
            sni = {p: st.sni_count(p) for p in eligible}
            rates = (st.completion_rates() if heuristic == MAX_YIELD
                     else None)
            ranked = rank_partitions(heuristic, eligible, sni, rng, rates,
                                     tracer=self.tracer)
            pid = ranked[0]
            with self.tracer.span("opat.round", pid=pid,
                                  iteration=st.iterations,
                                  pending_rows=int(st.ima[pid].n)):
                st.loads.append(pid)
                st.iterations += 1
                batch = st.ima[pid]
                st.ima[pid] = BindingBatch.empty(cfg.q_pad)
                seed_fresh = bool(st.fresh_pending[pid])
                st.fresh_pending[pid] = False
                entry = self.store.get(pid)
                # double-buffered streaming: pin pid, then stage the
                # heuristic's runner-up while pid evaluates — device_put
                # dispatch returns immediately, so the H2D copy overlaps the
                # evaluator work (ROADMAP item #1); the pin guarantees the
                # in-flight staging can evict anything BUT the partition the
                # running kernel reads (store may exceed capacity by one slot)
                with self.store.pinned(pid):
                    if self.prefetch and len(ranked) > 1:
                        self.store.prefetch(ranked[1])
                    self._run_partition(entry, plan_arrays, plan.n_steps,
                                        batch, seed_fresh, st)

        answers = truncate_answers(st.unique_answers(), max_answers)
        delta = self.store.stats - load0
        stats = RunStats(query=plan.query.name, scheme=self.pg.scheme,
                         heuristic=heuristic,
                         loads=list(st.loads),
                         l_ideal=l_ideal_for_plan(self.pg, plan),
                         n_answers=int(answers.shape[0]),
                         iterations=st.iterations,
                         answers_requested=max_answers,
                         cold_loads=delta.cold_loads,
                         warm_loads=delta.warm_loads,
                         prefetch_hits=delta.prefetch_hits,
                         disk_reads=delta.disk_reads,
                         read_ahead_hits=delta.read_ahead_hits,
                         bytes_cold=delta.bytes_cold,
                         bytes_prefetched=delta.bytes_prefetched,
                         bytes_disk=delta.bytes_disk,
                         bytes_host=delta.bytes_host)
        return OPATResult(answers=answers, stats=stats, state=st)

    def run_request(self, req: RunRequest) -> RunReport:
        """The shared ``QueryRunner`` protocol (see core/runner.py)."""
        res = self.run(req.plan, req.heuristic, seed=req.seed,
                       max_answers=req.max_answers)
        return RunReport(answers=res.answers, stats=res.stats, engine="opat",
                         extra={"state": res.state})
