"""Bookkeeping state for partitioned query evaluation (paper Sec. 6).

The paper keeps three kinds of files:

  SNI — Starting Node Information: start labels (vertex id NULL) and
        continuation nodes (vertex id bound) per partition,
  IMA — Intermediate Answers, one per partition: partial bindings whose next
        expansion must happen in that partition,
  FAA — Final All Answers, appended incrementally.

Here those become fixed-capacity array buffers so every engine step is
jittable.  A *binding row* is ``[Q_pad]`` of global vertex ids (-1 unbound)
plus a ``step`` cursor into the plan; a row is an answer when
``step == n_steps`` (the paper demarcates complete answers by size — same
criterion).  Host-side dataclasses wrap the arrays for the OPAT /
TraditionalMP orchestrators; MapReduceMP keeps them device-resident.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .query import OP_EQ, OP_GE, OP_GT, OP_LE, OP_LT, OP_NE, OP_NONE


def apply_value_op(op, values, v):
    """Predicate evaluation; works for numpy and jax arrays (operator
    overloading only).  Nodes without a numeric value (NaN) fail every
    predicate, including !=, matching QP-Subdue semantics."""
    finite = values == values  # NaN-safe isfinite for both backends
    if isinstance(op, (int, np.integer)):
        if op == OP_NONE:
            return finite | True
        if op == OP_EQ:
            return finite & (values == v)
        if op == OP_NE:
            return finite & (values != v)
        if op == OP_LT:
            return finite & (values < v)
        if op == OP_LE:
            return finite & (values <= v)
        if op == OP_GT:
            return finite & (values > v)
        if op == OP_GE:
            return finite & (values >= v)
        raise ValueError(f"bad op {op}")
    # traced op (jax): branchless select over all comparisons
    eq = values == v
    res = (
        (op == OP_NONE)
        | ((op == OP_EQ) & eq)
        | ((op == OP_NE) & (values != v))
        | ((op == OP_LT) & (values < v))
        | ((op == OP_LE) & (values <= v))
        | ((op == OP_GT) & (values > v))
        | ((op == OP_GE) & (values >= v))
    )
    return (finite | (op == OP_NONE)) & res


@dataclasses.dataclass
class BindingBatch:
    """Host-side bag of binding rows (the content of one IMA file)."""

    rows: np.ndarray   # [n, Q_pad] int32
    step: np.ndarray   # [n] int32

    @staticmethod
    def empty(q_pad: int) -> "BindingBatch":
        return BindingBatch(rows=np.zeros((0, q_pad), dtype=np.int32),
                            step=np.zeros((0,), dtype=np.int32))

    @property
    def n(self) -> int:
        return int(self.rows.shape[0])

    def concat(self, other: "BindingBatch") -> "BindingBatch":
        if self.n == 0:
            return other
        if other.n == 0:
            return self
        return BindingBatch(rows=np.concatenate([self.rows, other.rows]),
                            step=np.concatenate([self.step, other.step]))

    def dedup(self) -> "BindingBatch":
        """Drop duplicate (rows, step) entries — an answer prefix re-entering a
        partition along two cut edges must not double-count (paper Fig. 4c)."""
        if self.n == 0:
            return self
        key = np.concatenate([self.rows, self.step[:, None]], axis=1)
        _, idx = np.unique(key, axis=0, return_index=True)
        idx.sort()
        return BindingBatch(rows=self.rows[idx], step=self.step[idx])


@dataclasses.dataclass
class SNIEntry:
    """One SNI record: either a start-label entry (vertex NULL) or a
    continuation count for a partition."""

    pid: int
    fresh_starts: int      # #unconsumed start-label nodes (vertex id NULL)
    continuations: int     # #rows pending in this partition's IMA


@dataclasses.dataclass
class QueryState:
    """SNI + IMA + FAA for one conjunctive plan over k partitions."""

    k: int
    q_pad: int
    ima: List[BindingBatch]            # per-partition intermediate answers
    fresh_pending: np.ndarray          # [k] bool: start nodes not yet seeded
    fresh_counts: np.ndarray           # [k] int64: #start nodes per partition
    faa_rows: List[np.ndarray]         # accumulated answers
    loads: List[int]                   # sequence of partition loads (metric)
    iterations: int = 0
    # per-partition yield observations (MAX-YIELD heuristic): when partition
    # p was processed, how many rows completed an answer vs spawned a
    # continuation into another partition's IMA
    completed_from: np.ndarray = None  # [k] int64
    spawned_from: np.ndarray = None    # [k] int64
    # incrementally-maintained unique answer keys, so the per-load budget
    # check is O(new rows), not a full-FAA np.unique; engines must append
    # answers via add_answers().  None (the default) skips the bookkeeping
    # entirely — exhaustive runs never consult budget_met, so they should
    # not pay the tuple-hashing/memory cost.
    answer_keys: Optional[set] = None

    @staticmethod
    def initial(k: int, q_pad: int, fresh_counts: np.ndarray,
                track_answer_keys: bool = False) -> "QueryState":
        return QueryState(
            k=k, q_pad=q_pad,
            ima=[BindingBatch.empty(q_pad) for _ in range(k)],
            fresh_pending=fresh_counts > 0,
            fresh_counts=fresh_counts.astype(np.int64).copy(),
            faa_rows=[], loads=[], iterations=0,
            completed_from=np.zeros(k, dtype=np.int64),
            spawned_from=np.zeros(k, dtype=np.int64),
            answer_keys=set() if track_answer_keys else None)

    def add_answers(self, rows: np.ndarray) -> None:
        """Append completed rows to the FAA (and the unique-key index when
        an answer budget is being tracked)."""
        self.faa_rows.append(rows)
        if self.answer_keys is not None:
            self.answer_keys.update(map(tuple, rows.tolist()))

    def observe_yield(self, pid: int, completed: int, spawned: int) -> None:
        self.completed_from[pid] += completed
        self.spawned_from[pid] += spawned

    def completion_rates(self) -> dict:
        """Laplace-smoothed completed/(completed+spawned) per partition —
        the MAX-YIELD signal (0.5 prior when nothing was observed yet)."""
        return {p: (float(self.completed_from[p]) + 1.0)
                   / (float(self.completed_from[p] + self.spawned_from[p]) + 2.0)
                for p in range(self.k)}

    def sni_count(self, pid: int) -> int:
        """The SNI-derived score used by the SN heuristics: fresh start nodes
        (if unconsumed) + pending continuation rows."""
        fresh = int(self.fresh_counts[pid]) if self.fresh_pending[pid] else 0
        return fresh + self.ima[pid].n

    def eligible(self) -> List[int]:
        return [p for p in range(self.k)
                if (self.fresh_pending[p] and self.fresh_counts[p] > 0)
                or self.ima[p].n > 0]

    def answers(self) -> np.ndarray:
        if not self.faa_rows:
            return np.zeros((0, self.q_pad), dtype=np.int32)
        return np.concatenate(self.faa_rows, axis=0)

    def unique_answers(self) -> np.ndarray:
        a = self.answers()
        if a.shape[0] == 0:
            return a
        return np.unique(a, axis=0)

    def unique_answer_count(self) -> int:
        if self.answer_keys is not None:
            return len(self.answer_keys)
        return int(self.unique_answers().shape[0])

    def budget_met(self, max_answers) -> bool:
        """True when an answer budget is set and the FAA already holds that
        many unique answers (the engines' early-termination test)."""
        return (max_answers is not None
                and self.unique_answer_count() >= max_answers)
