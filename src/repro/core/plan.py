"""Cost-based query plan generation (QP-Subdue style, paper Sec. 3).

A plan linearizes the query pattern into a sequence of one-edge expansion
steps starting from a chosen *start node*.  Candidate plans are generated
for every query node as a potential start, costed with catalog statistics
(estimated intermediate-result cardinality after each step, summed), and the
minimum-cost plan is executed — the same strategy QP-Subdue uses.

The emitted ``PlanArrays`` is the fixed-shape array form every engine (OPAT,
TraditionalMP, MapReduceMP) and the Pallas kernel consume.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .catalog import Catalog
from .graph import Graph
from .query import OP_BY_NAME, QDIR_ANY, QDIR_IN, QDIR_OUT, Query


@dataclasses.dataclass
class PlanStep:
    src_slot: int          # already-bound query-node slot we expand from
    dst_slot: int          # slot being bound (or checked, if closes_cycle)
    edge_label: int        # interned id or WILDCARD
    direction: int         # QDIR_* seen from src_slot
    dst_label: int         # interned id or WILDCARD
    dst_value_op: int      # OP_*
    dst_value: float
    closes_cycle: bool     # dst_slot already bound -> edge-existence check


@dataclasses.dataclass
class Plan:
    query: Query
    start_slot: int        # query-node index bound first
    start_label: int
    start_value_op: int
    start_value: float
    steps: List[PlanStep]
    est_cost: float

    @property
    def n_slots(self) -> int:
        return self.query.n_nodes

    @property
    def n_steps(self) -> int:
        return len(self.steps)

    def max_path_len(self) -> int:
        """Longest root-to-leaf path (in steps) of the plan tree — the paper's
        upper bound on TraditionalMP / MapReduceMP iterations (Sec. 8.2, 9)."""
        depth = {self.start_slot: 0}
        best = 0
        for s in self.steps:
            d = depth.get(s.src_slot, 0) + 1
            if not s.closes_cycle:
                depth[s.dst_slot] = d
            best = max(best, d)
        return best


@dataclasses.dataclass
class PlanArrays:
    """jnp-friendly plan encoding (all int32/float32, fixed length S)."""

    n_slots: int
    n_steps: int
    start_slot: np.ndarray      # [] int32
    start_label: np.ndarray     # [] int32
    start_value_op: np.ndarray  # [] int32
    start_value: np.ndarray     # [] float32
    src_slot: np.ndarray        # [S] int32
    dst_slot: np.ndarray        # [S] int32
    edge_label: np.ndarray      # [S] int32
    direction: np.ndarray       # [S] int32
    dst_label: np.ndarray       # [S] int32
    dst_value_op: np.ndarray    # [S] int32
    dst_value: np.ndarray       # [S] float32
    closes_cycle: np.ndarray    # [S] int32 (0/1)

    @staticmethod
    def from_plan(plan: Plan, pad_steps: Optional[int] = None) -> "PlanArrays":
        S = plan.n_steps if pad_steps is None else pad_steps
        assert S >= plan.n_steps
        def arr(fn, dtype):
            a = np.zeros(S, dtype=dtype)
            for i, s in enumerate(plan.steps):
                a[i] = fn(s)
            return a
        return PlanArrays(
            n_slots=plan.n_slots,
            n_steps=plan.n_steps,
            start_slot=np.int32(plan.start_slot),
            start_label=np.int32(plan.start_label),
            start_value_op=np.int32(plan.start_value_op),
            start_value=np.float32(plan.start_value),
            src_slot=arr(lambda s: s.src_slot, np.int32),
            dst_slot=arr(lambda s: s.dst_slot, np.int32),
            edge_label=arr(lambda s: s.edge_label, np.int32),
            direction=arr(lambda s: s.direction, np.int32),
            dst_label=arr(lambda s: s.dst_label, np.int32),
            dst_value_op=arr(lambda s: s.dst_value_op, np.int32),
            dst_value=arr(lambda s: s.dst_value, np.float32),
            closes_cycle=arr(lambda s: int(s.closes_cycle), np.int32),
        )

    @staticmethod
    def stack(plans: Sequence["PlanArrays"]) -> "PlanArrays":
        """Stack B same-padding plans into one [B, ...] ``PlanArrays`` — the
        unit the scheduler's batched partition evaluator consumes (each
        leaf gains a leading batch axis; ``jax.vmap`` maps over it while
        the partition inputs broadcast).  The scalar ``n_slots`` /
        ``n_steps`` metadata is not meaningful for a stacked bundle (each
        plan keeps its own runtime ``n_steps`` argument), so it is pinned
        to (0, S): a *constant* aux for the jit cache, ensuring one trace
        per batch-size bucket regardless of which plans are stacked."""
        assert plans, "need at least one plan to stack"
        S = plans[0].src_slot.shape[0]
        assert all(p.src_slot.shape[0] == S for p in plans), \
            "stacked plans must share one padded step count"
        fields = ("start_slot", "start_label", "start_value_op", "start_value",
                  "src_slot", "dst_slot", "edge_label", "direction",
                  "dst_label", "dst_value_op", "dst_value", "closes_cycle")
        stacked = {f: np.stack([np.asarray(getattr(p, f)) for p in plans])
                   for f in fields}
        return PlanArrays(n_slots=0, n_steps=S, **stacked)


def _enumerate_orders(query: Query, start: int) -> List[List[Tuple[int, bool]]]:
    """All BFS-ish edge orders are exponential; we use the greedy order only
    (chosen per-step by estimated fanout) — matching QP-Subdue's practical
    planner.  Returns a single greedy order as [(edge_idx, forward_from_a)]."""
    return []  # greedy order is computed inline in generate_plan


def _greedy_plan(query: Query, graph: Graph, catalog: Catalog,
                 start: int) -> Optional[Plan]:
    nl = query.node_label_ids(graph)
    el = query.edge_label_ids(graph)
    bound = {start}
    remaining = set(range(len(query.edges)))
    steps: List[PlanStep] = []
    start_op = OP_BY_NAME[query.nodes[start].value_op]
    start_sel = catalog.value_selectivity(nl[start], start_op, query.nodes[start].value)
    card = catalog.label_cardinality(nl[start]) * start_sel
    if card == 0.0:
        card = 1e-3  # unknown label: still a valid (cheap) plan
    cost = card

    while remaining:
        best = None  # (est_new_card, edge_idx, src_slot, dst_slot, closes)
        for ei in list(remaining):
            e = query.edges[ei]
            a_in, b_in = e.a in bound, e.b in bound
            if not (a_in or b_in):
                continue
            closes = a_in and b_in
            src, dst = (e.a, e.b) if a_in else (e.b, e.a)
            conn = catalog.connection_cardinality(nl[src], el[ei], nl[dst])
            src_card = max(1.0, catalog.label_cardinality(nl[src]))
            fanout = conn / src_card
            dst_op = OP_BY_NAME[query.nodes[dst].value_op]
            sel = catalog.value_selectivity(nl[dst], dst_op, query.nodes[dst].value)
            if closes:
                # cycle closure filters; estimate survival prob ~ fanout / |dst label|
                est = card * min(1.0, fanout / max(1.0, catalog.label_cardinality(nl[dst])))
            else:
                est = card * fanout * sel
            key = (est, ei, src, dst, closes)
            if best is None or est < best[0]:
                best = key
        if best is None:
            return None  # disconnected pattern (validate() prevents this)
        est, ei, src, dst, closes = best
        e = query.edges[ei]
        # direction seen from src
        if e.direction == QDIR_ANY:
            direction = QDIR_ANY
        elif src == e.a:
            direction = e.direction
        else:
            direction = QDIR_IN if e.direction == QDIR_OUT else QDIR_OUT
        dst_op = OP_BY_NAME[query.nodes[dst].value_op]
        steps.append(PlanStep(
            src_slot=src, dst_slot=dst, edge_label=el[ei], direction=direction,
            dst_label=nl[dst], dst_value_op=dst_op,
            dst_value=float(query.nodes[dst].value), closes_cycle=closes))
        remaining.discard(ei)
        bound.add(dst)
        card = max(est, 1e-6)
        cost += card

    return Plan(query=query, start_slot=start, start_label=nl[start],
                start_value_op=start_op,
                start_value=float(query.nodes[start].value),
                steps=steps, est_cost=cost)


def generate_plan(query: Query, graph: Graph, catalog: Catalog,
                  start_slot: Optional[int] = None) -> Plan:
    """Generate the minimum-estimated-cost plan over all start-node choices
    (or for a forced ``start_slot``)."""
    query.validate()
    candidates = range(query.n_nodes) if start_slot is None else [start_slot]
    best: Optional[Plan] = None
    for s in candidates:
        # prefer concrete-label starts: wildcard starts scan every node
        p = _greedy_plan(query, graph, catalog, s)
        if p is None:
            continue
        if best is None or p.est_cost < best.est_cost:
            best = p
    assert best is not None, "no valid plan (pattern disconnected?)"
    return best
