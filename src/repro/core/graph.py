"""Graph representation for PGQP-JAX.

The paper (Das et al., 2019) uses the Subdue representation: vertices are
<vID, vLabel> pairs, edges are <dir, s_vID, d_vID, eLabel> tuples, and the
partitioned representation adds a partition id (pID) per vertex plus the
one-edge cut-set extension replicated into each partition (Fig. 1b/1c).

Host side we keep a numpy ``Graph``; each partition is converted into a
fixed-shape, padded ``PartitionArrays`` bundle (CSR + ELLPACK adjacency)
that a single jitted evaluator can consume for *any* partition of the same
padded geometry — this is what lets OPAT / TraditionalMP / MapReduceMP share
one compiled program.

TPU adaptation note (see DESIGN.md): the adjacency is carried both as CSR
(reference/jnp path) and as ELLPACK (dense [n_nodes_padded, ell_width] edge
tiles).  ELLPACK trades padding for perfectly regular, vectorizable access —
the classic vector-machine sparse format — and is what the Pallas
``frontier_expand`` kernel tiles into VMEM.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

WILDCARD = -1  # label id for "?" wildcards in queries
NO_VALUE = np.float32(np.nan)

# edge direction encoding (paper supports directed + undirected edges)
DIR_UNDIRECTED = 0
DIR_FORWARD = 1   # stored edge goes src -> dst
DIR_BACKWARD = 2  # stored edge is the reverse view of a directed edge


class LabelVocab:
    """Interns string labels to dense int32 ids (separate node/edge spaces)."""

    def __init__(self) -> None:
        self._to_id: Dict[str, int] = {}
        self._to_str: List[str] = []

    def intern(self, label: str) -> int:
        got = self._to_id.get(label)
        if got is not None:
            return got
        new_id = len(self._to_str)
        self._to_id[label] = new_id
        self._to_str.append(label)
        return new_id

    def id_of(self, label: str) -> int:
        if label == "?":
            return WILDCARD
        return self._to_id[label]

    def get(self, label: str, default: int = WILDCARD) -> int:
        return self._to_id.get(label, default)

    def str_of(self, label_id: int) -> str:
        return "?" if label_id == WILDCARD else self._to_str[label_id]

    def __len__(self) -> int:
        return len(self._to_str)

    def __contains__(self, label: str) -> bool:
        return label in self._to_id


@dataclasses.dataclass
class Graph:
    """Whole-graph host representation (Subdue-style)."""

    n_nodes: int
    node_label: np.ndarray        # [V] int32
    node_value: np.ndarray        # [V] float32 (NaN when the node has no numeric value)
    edge_src: np.ndarray          # [E] int32
    edge_dst: np.ndarray          # [E] int32
    edge_label: np.ndarray        # [E] int32
    edge_directed: np.ndarray     # [E] bool
    node_vocab: LabelVocab
    edge_vocab: LabelVocab

    @property
    def n_edges(self) -> int:
        return int(self.edge_src.shape[0])

    def degree_view(self) -> np.ndarray:
        """Out-degree in the symmetrized adjacency (each undirected edge counts
        from both endpoints; each directed edge contributes a forward and a
        backward slot so that plans may traverse either direction)."""
        deg = np.zeros(self.n_nodes, dtype=np.int64)
        np.add.at(deg, self.edge_src, 1)
        np.add.at(deg, self.edge_dst, 1)
        return deg

    def validate(self) -> None:
        assert self.node_label.shape == (self.n_nodes,)
        assert self.node_value.shape == (self.n_nodes,)
        e = self.n_edges
        for arr in (self.edge_dst, self.edge_label, self.edge_directed):
            assert arr.shape == (e,)
        if e:
            assert self.edge_src.min() >= 0 and self.edge_src.max() < self.n_nodes
            assert self.edge_dst.min() >= 0 and self.edge_dst.max() < self.n_nodes


class GraphBuilder:
    """Convenience builder used by data generators and tests."""

    def __init__(self) -> None:
        self.node_vocab = LabelVocab()
        self.edge_vocab = LabelVocab()
        self._labels: List[int] = []
        self._values: List[float] = []
        self._src: List[int] = []
        self._dst: List[int] = []
        self._elabel: List[int] = []
        self._edir: List[bool] = []

    def add_node(self, label: str, value: Optional[float] = None) -> int:
        vid = len(self._labels)
        self._labels.append(self.node_vocab.intern(label))
        self._values.append(float("nan") if value is None else float(value))
        return vid

    def add_edge(self, src: int, dst: int, label: str, directed: bool = False) -> int:
        eid = len(self._src)
        self._src.append(src)
        self._dst.append(dst)
        self._elabel.append(self.edge_vocab.intern(label))
        self._edir.append(directed)
        return eid

    def build(self) -> Graph:
        g = Graph(
            n_nodes=len(self._labels),
            node_label=np.asarray(self._labels, dtype=np.int32),
            node_value=np.asarray(self._values, dtype=np.float32),
            edge_src=np.asarray(self._src, dtype=np.int32),
            edge_dst=np.asarray(self._dst, dtype=np.int32),
            edge_label=np.asarray(self._elabel, dtype=np.int32),
            edge_directed=np.asarray(self._edir, dtype=bool),
            node_vocab=self.node_vocab,
            edge_vocab=self.edge_vocab,
        )
        g.validate()
        return g


# ---------------------------------------------------------------------------
# Partitioned representation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PartitionArrays:
    """One partition, padded to a uniform geometry shared by all partitions.

    Node order: the ``n_core`` owned nodes first, then ghost (cut-set) nodes,
    then padding.  Ghost nodes carry label/value/owner so predicates on a
    continuation node evaluate locally — exactly the paper's "one edge cut
    set information ... added to each partition" (Sec. 4.2).
    """

    pid: int
    n_core: int
    n_nodes: int                  # core + ghosts (<= padded size)
    node_gid: np.ndarray          # [Np] int32 global vertex id (-1 padding)
    node_label: np.ndarray        # [Np] int32 (-2 padding)
    node_value: np.ndarray        # [Np] float32
    node_owner: np.ndarray        # [Np] int32 owning partition id (-1 padding)
    # CSR over local node ids; only core nodes have adjacency.
    row_ptr: np.ndarray           # [Np + 1] int32
    edge_dst: np.ndarray          # [Ep] int32 local dst (-1 padding)
    edge_label: np.ndarray        # [Ep] int32
    edge_dir: np.ndarray          # [Ep] int32 (DIR_* from the traversal's view)
    # ELLPACK view (built lazily by to_ell) for the Pallas kernel path.
    # Destination-node attributes are DENORMALIZED into the edge tables
    # (ell_dlab/ell_dval/ell_dgid) so the frontier_expand kernel is fully
    # elementwise after one scalar-prefetch row gather — no data-dependent
    # gathers inside the kernel (TPU adaptation; see DESIGN.md).
    ell_width: int = 0
    ell_dst: Optional[np.ndarray] = None      # [Np, W] int32 local dst (-1 pad)
    ell_label: Optional[np.ndarray] = None    # [Np, W] int32
    ell_dir: Optional[np.ndarray] = None      # [Np, W] int32
    ell_dlab: Optional[np.ndarray] = None     # [Np, W] int32 dst node label
    ell_dval: Optional[np.ndarray] = None     # [Np, W] float32 dst node value
    ell_dgid: Optional[np.ndarray] = None     # [Np, W] int32 dst global id

    @property
    def n_ghost(self) -> int:
        return self.n_nodes - self.n_core

    def max_degree(self) -> int:
        deg = np.diff(self.row_ptr[: self.n_nodes + 1])
        return int(deg.max()) if deg.size else 0

    def to_ell(self, width: Optional[int] = None) -> None:
        """Build the ELLPACK adjacency (dense [Np, W] tiles; see module doc)."""
        w = int(width if width is not None else max(1, self.max_degree()))
        npad = self.node_gid.shape[0]
        dst = np.full((npad, w), -1, dtype=np.int32)
        lab = np.full((npad, w), -2, dtype=np.int32)
        dire = np.zeros((npad, w), dtype=np.int32)
        for v in range(self.n_nodes):
            s, e = int(self.row_ptr[v]), int(self.row_ptr[v + 1])
            d = min(e - s, w)
            dst[v, :d] = self.edge_dst[s : s + d]
            lab[v, :d] = self.edge_label[s : s + d]
            dire[v, :d] = self.edge_dir[s : s + d]
        self.ell_width = w
        self.ell_dst, self.ell_label, self.ell_dir = dst, lab, dire
        # denormalized destination-node attributes (see field comment)
        dsafe = np.clip(dst, 0, npad - 1)
        self.ell_dlab = np.where(dst >= 0, self.node_label[dsafe], -2).astype(np.int32)
        self.ell_dval = np.where(dst >= 0, self.node_value[dsafe],
                                 np.float32(np.nan)).astype(np.float32)
        self.ell_dgid = np.where(dst >= 0, self.node_gid[dsafe], -1).astype(np.int32)


@dataclasses.dataclass
class PartitionedGraph:
    """k partitions + global ownership/lookup tables.

    ``owner``   : [V] partition owning each global vertex.
    ``g2l``     : [k, V] local index of a global vertex inside a partition
                  (core or ghost), or -1.  For laptop-scale graphs this dense
                  table is cheap; at cluster scale it is sharded over the
                  "part" mesh axis exactly like the partitions themselves
                  (each device needs only its own row).
    """

    graph: Graph
    k: int
    assignment: np.ndarray            # [V] int32 partition of each vertex
    parts: List[PartitionArrays]
    owner: np.ndarray                 # [V] int32 (== assignment; kept for clarity)
    g2l: np.ndarray                   # [k, V] int32
    cut_edges: int
    node_pad: int
    edge_pad: int
    scheme: str = "?"                 # partitioning-scheme name (for RunStats)

    @property
    def ell_width(self) -> int:
        """The uniform ELLPACK width shared by every partition (the jitted
        evaluator's W dimension).  Out-of-core variants override this with
        the manifest value, so engines must read it here, not via
        ``parts[0]``."""
        w = self.parts[0].ell_width
        assert all(p.ell_width == w for p in self.parts), \
            "uniform ELL width required"
        return w

    def start_label_counts(self, label_id: int, value_op: int = 0,
                           value: float = 0.0) -> np.ndarray:
        """#core nodes matching (label, value predicate) per partition — the
        paper's one-pass start-node metric used to seed the SNI file.
        Computed from the whole-graph arrays + the assignment (a core node
        of p is exactly a vertex assigned to p), so it never touches
        ``parts`` — out-of-core graphs rank partitions without any shard
        resident."""
        return start_label_counts_from_arrays(
            self.graph.node_label, self.graph.node_value, self.assignment,
            self.k, label_id, value_op, value)

    def connected_components_per_partition(self) -> np.ndarray:
        """#connected components among each partition's *core* nodes using only
        intra-partition edges (paper Sec. 5.2 metric, computed in the same
        pass as partition construction)."""
        out = np.zeros(self.k, dtype=np.int64)
        for p in self.parts:
            out[p.pid] = _count_components(p)
        return out


def start_label_counts_from_arrays(node_label: np.ndarray,
                                   node_value: np.ndarray,
                                   assignment: np.ndarray, k: int,
                                   label_id: int, value_op: int = 0,
                                   value: float = 0.0) -> np.ndarray:
    """The SNI seed computed from whole-graph arrays alone — one
    implementation shared by ``PartitionedGraph.start_label_counts`` and
    the disk catalog (storage/format.py), so predicate semantics can
    never diverge between the in-RAM and out-of-core ranking paths."""
    from .state import apply_value_op  # local import to avoid cycle
    ok = (np.ones(node_label.shape[0], dtype=bool) if label_id == WILDCARD
          else node_label == label_id)
    if value_op:
        ok = ok & apply_value_op(int(value_op), node_value, float(value))
    return np.bincount(assignment[ok], minlength=k).astype(np.int64)


def _count_components(p: PartitionArrays) -> int:
    n = p.n_core
    if n == 0:
        return 0
    parent = np.arange(n, dtype=np.int64)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    for v in range(n):
        s, e = int(p.row_ptr[v]), int(p.row_ptr[v + 1])
        for idx in range(s, e):
            d = int(p.edge_dst[idx])
            if 0 <= d < n:  # core-to-core edge
                ra, rb = find(v), find(d)
                if ra != rb:
                    parent[ra] = rb
    return int(sum(1 for v in range(n) if find(v) == v))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def build_partitions(graph: Graph, assignment: np.ndarray, k: int,
                     node_pad_multiple: int = 8,
                     edge_pad_multiple: int = 8,
                     uniform_pad: bool = True,
                     ell: bool = True,
                     ell_width: Optional[int] = None,
                     scheme: str = "?") -> PartitionedGraph:
    """Materialize ``PartitionArrays`` for every partition from a vertex
    assignment, replicating the one-edge cut set (ghost nodes) per Fig. 1.

    All partitions are padded to a shared (node_pad, edge_pad) geometry when
    ``uniform_pad`` so a single jitted evaluator handles every partition.
    ``scheme`` records the partitioning-scheme name that produced
    ``assignment`` so every engine's ``RunStats`` can report it.
    """
    V = graph.n_nodes
    assignment = assignment.astype(np.int32)
    assert assignment.shape == (V,)
    # Symmetrized adjacency with direction flags, CSR over global ids.
    src = np.concatenate([graph.edge_src, graph.edge_dst])
    dst = np.concatenate([graph.edge_dst, graph.edge_src])
    lab = np.concatenate([graph.edge_label, graph.edge_label])
    dire = np.concatenate([
        np.where(graph.edge_directed, DIR_FORWARD, DIR_UNDIRECTED),
        np.where(graph.edge_directed, DIR_BACKWARD, DIR_UNDIRECTED),
    ]).astype(np.int32)
    order = np.argsort(src, kind="stable")
    src, dst, lab, dire = src[order], dst[order], lab[order], dire[order]
    gptr = np.zeros(V + 1, dtype=np.int64)
    np.add.at(gptr, src + 1, 1)
    gptr = np.cumsum(gptr)

    cut = int(np.sum(assignment[graph.edge_src] != assignment[graph.edge_dst]))

    per_core: List[np.ndarray] = [np.where(assignment == p)[0] for p in range(k)]
    raw_parts: List[dict] = []
    for p in range(k):
        core = per_core[p]
        core_set_local = {int(g): i for i, g in enumerate(core)}
        ghosts: List[int] = []
        ghost_idx: Dict[int, int] = {}
        e_dst: List[int] = []
        e_lab: List[int] = []
        e_dir: List[int] = []
        rptr = [0]
        for g in core:
            s, e = int(gptr[g]), int(gptr[g + 1])
            for idx in range(s, e):
                d = int(dst[idx])
                li = core_set_local.get(d)
                if li is None:  # cut edge -> ghost node
                    gi = ghost_idx.get(d)
                    if gi is None:
                        gi = len(ghosts)
                        ghost_idx[d] = gi
                        ghosts.append(d)
                    li = len(core) + gi
                e_dst.append(li)
                e_lab.append(int(lab[idx]))
                e_dir.append(int(dire[idx]))
            rptr.append(len(e_dst))
        raw_parts.append(dict(core=core, ghosts=np.asarray(ghosts, dtype=np.int64),
                              rptr=np.asarray(rptr, dtype=np.int64),
                              e_dst=np.asarray(e_dst, dtype=np.int32),
                              e_lab=np.asarray(e_lab, dtype=np.int32),
                              e_dir=np.asarray(e_dir, dtype=np.int32)))

    if uniform_pad:
        node_pad = _round_up(max(1, max(len(r["core"]) + len(r["ghosts"]) for r in raw_parts)),
                             node_pad_multiple)
        edge_pad = _round_up(max(1, max(len(r["e_dst"]) for r in raw_parts)),
                             edge_pad_multiple)
    else:
        node_pad = edge_pad = 0  # per-partition sizes below

    parts: List[PartitionArrays] = []
    g2l = np.full((k, V), -1, dtype=np.int32)
    for p in range(k):
        r = raw_parts[p]
        n_core, n_ghost = len(r["core"]), len(r["ghosts"])
        n_nodes = n_core + n_ghost
        npad = node_pad if uniform_pad else _round_up(max(1, n_nodes), node_pad_multiple)
        epad = edge_pad if uniform_pad else _round_up(max(1, len(r["e_dst"])), edge_pad_multiple)
        gids = np.full(npad, -1, dtype=np.int32)
        labels = np.full(npad, -2, dtype=np.int32)
        values = np.full(npad, np.nan, dtype=np.float32)
        owners = np.full(npad, -1, dtype=np.int32)
        all_g = np.concatenate([r["core"], r["ghosts"]]).astype(np.int64) if n_nodes else np.zeros(0, np.int64)
        gids[:n_nodes] = all_g
        labels[:n_nodes] = graph.node_label[all_g]
        values[:n_nodes] = graph.node_value[all_g]
        owners[:n_nodes] = assignment[all_g]
        g2l[p, all_g] = np.arange(n_nodes, dtype=np.int32)

        rptr = np.full(npad + 1, r["rptr"][-1], dtype=np.int32)
        rptr[: n_core + 1] = r["rptr"]
        # ghosts + padding rows all get empty adjacency (== last value)
        edst = np.full(epad, -1, dtype=np.int32)
        elab = np.full(epad, -2, dtype=np.int32)
        edir = np.zeros(epad, dtype=np.int32)
        ne = len(r["e_dst"])
        edst[:ne], elab[:ne], edir[:ne] = r["e_dst"], r["e_lab"], r["e_dir"]

        pa = PartitionArrays(pid=p, n_core=n_core, n_nodes=n_nodes,
                             node_gid=gids, node_label=labels, node_value=values,
                             node_owner=owners, row_ptr=rptr, edge_dst=edst,
                             edge_label=elab, edge_dir=edir)
        parts.append(pa)

    if ell:
        w = ell_width if ell_width is not None else max(1, max(pa.max_degree() for pa in parts))
        for pa in parts:
            pa.to_ell(w)

    return PartitionedGraph(graph=graph, k=k, assignment=assignment, parts=parts,
                            owner=assignment.copy(), g2l=g2l, cut_edges=cut,
                            node_pad=node_pad if uniform_pad else -1,
                            edge_pad=edge_pad if uniform_pad else -1,
                            scheme=scheme)
