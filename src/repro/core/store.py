"""PartitionStore — explicit partition residency for all three engines.

The paper's central cost model is the partition *load* sequence: OPAT pays
one load per heuristic pick, TraditionalMP one stacked load of its top-p
set per iteration, MapReduceMP one all-partitions load at job start.  The
seed code made those loads implicit — every engine call re-shipped host
numpy dicts through ``jit``, so a "load" was always a cold host->device
transfer and nothing could be reused across queries.  This module makes
residency a first-class object, the transfer layer that near-real-time
graph serving (Vaquero et al., arXiv:1410.1903) and workload-aware
repartitioning (WawPart, arXiv:2203.14888) both observe and steer.

Cold vs warm semantics (shared vocabulary for ``RunStats`` / ``LoadStats``):

  cold load  — the requested entry was not device-resident; the store pays
               a ``jax.device_put`` transfer on the caller's critical path
               (a cache *miss*).
  warm load  — the entry was already device-resident (from an earlier get
               or a prefetch); the caller reuses the committed buffers and
               pays no transfer (a cache *hit*).
  prefetch   — ``prefetch(pid)`` stages an entry *off* the critical path:
               ``device_put`` dispatches asynchronously, so staging the
               heuristic's next-ranked partition overlaps with the current
               partition's evaluation.  A later ``get`` of a prefetched
               entry is a warm load and additionally counts as a
               ``prefetch_hit`` — the transfer happened, but nobody waited
               for it.

Eviction is LRU with a configurable capacity, in partitions
(``capacity_parts``; a stacked entry of n partitions costs n) or bytes
(``capacity_bytes``).  With no capacity the store holds every
single-partition entry it has ever staged (fine at laptop scale; serving
deployments size it to HBM); *stacked* entries are always additionally
capped at ``max_stacked_entries`` distinct tuples (LRU), since each one
duplicates its partitions' buffers.

Entries come in two shapes, matching how the engines consume partitions:

  ``get(pid)``            — one partition: the evaluator input dict plus
                            that partition's g2l row (OPAT).
  ``get_stacked(pids)``   — ``np.stack`` of the dicts over a pid tuple plus
                            the stacked g2l rows, optionally ``device_put``
                            with a target sharding (TraditionalMP's top-p
                            set; MapReduceMP's one-per-device full stack).

Both return committed jax Arrays, so repeated jit calls reuse the same
device buffers instead of re-transferring host memory.

Out-of-core backing (PR 5): pass ``backing=DiskCatalog`` (storage/) and
the store becomes the top of a THREE-tier cache — a device miss falls
through to a pinned-host LRU (``host_cache_parts`` / ``host_cache_bytes``,
storage/host_cache.py), a host miss to a disk shard read (``disk_reads``
counter); ``prefetch(pid)`` of a partition that is not host-resident
issues a background-thread *read-ahead* instead of blocking on disk, so
the heuristic's runner-up overlaps the current partition's evaluation at
the disk tier exactly as it already does at the device tier
(``read_ahead_issued`` / ``read_ahead_hits``).  Without a backing the
host tier is the whole graph pinned in RAM — the pre-PR behaviour,
bit-for-bit.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from .graph import PartitionedGraph

# a cache key: one partition id, or an ordered tuple of them (stacked entry)
StoreKey = Union[int, Tuple[int, ...]]


@dataclasses.dataclass
class LoadStats:
    """Residency counters; deltas of two snapshots describe one run."""

    hits: int = 0                # warm loads (entry already device-resident)
    misses: int = 0              # cold loads (device_put on the critical path)
    evictions: int = 0           # LRU entries dropped to fit capacity
    prefetch_issued: int = 0     # prefetch() calls that actually staged
    prefetch_hits: int = 0       # gets served by a previously prefetched entry
    bytes_cold: int = 0          # bytes transferred by cold (demand) loads
    bytes_prefetched: int = 0    # bytes transferred off the critical path
    released: int = 0            # entries release()d by a caller (scheduler
                                 # retirement: no pending query needs them)
    # out-of-core (disk-backed) tier counters — structurally zero when the
    # store has no backing (the whole graph is pinned in host RAM):
    disk_reads: int = 0          # shard reads issued against the disk tier
                                 # (demand + read-ahead: total disk traffic)
    read_ahead_issued: int = 0   # background-thread shard reads started
    read_ahead_hits: int = 0     # host gets served by a completed/in-flight
                                 # read-ahead (the disk latency overlapped
                                 # evaluation instead of blocking a get)
    bytes_disk: int = 0          # bytes read off disk (demand + read-ahead)
    bytes_host: int = 0          # bytes served out of the host LRU tier to
                                 # device staging (every get: hit or demand
                                 # read; structurally zero for the pinned
                                 # in-RAM tier, which holds no LRU)
    host_evictions: int = 0      # host-LRU entries dropped to fit capacity
    delta_overlays: int = 0      # bundles rebuilt from a generation view's
                                 # pending delta overlay (stale pids staged
                                 # through apply_records instead of a clean
                                 # shard read)

    @property
    def warm_loads(self) -> int:
        return self.hits

    @property
    def cold_loads(self) -> int:
        return self.misses

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def copy(self) -> "LoadStats":
        return dataclasses.replace(self)

    def __sub__(self, other: "LoadStats") -> "LoadStats":
        return LoadStats(**{f.name: getattr(self, f.name) - getattr(other, f.name)
                            for f in dataclasses.fields(self)})

    def __add__(self, other: "LoadStats") -> "LoadStats":
        """Counter-wise sum — the scheduler accumulates one query's
        participation view by adding the per-load-event deltas it took
        part in."""
        return LoadStats(**{f.name: getattr(self, f.name) + getattr(other, f.name)
                            for f in dataclasses.fields(self)})

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["warm_loads"] = self.warm_loads
        d["cold_loads"] = self.cold_loads
        d["hit_rate"] = self.hit_rate
        return d


@dataclasses.dataclass
class StoreEntry:
    """One device-resident unit: evaluator inputs + the matching g2l row(s)."""

    key: StoreKey
    part: Dict[str, jax.Array]   # evaluator input dict ([...] or stacked [n, ...])
    g2l: jax.Array               # [V] row (single) or [n, V] rows (stacked)
    nbytes: int
    prefetched: bool = False     # staged by prefetch(), not yet touched by get()

    @property
    def cost_parts(self) -> int:
        return len(self.key) if isinstance(self.key, tuple) else 1


class PartitionStore:
    """Owns which partitions are device-resident for one PartitionedGraph.

    All three engines request partitions through the store instead of
    holding private device copies; ``GraphSession`` shares one store across
    every query it serves, which is what makes a repeated query warm.
    """

    def __init__(self, pg: PartitionedGraph,
                 capacity_parts: Optional[int] = None,
                 capacity_bytes: Optional[int] = None,
                 max_stacked_entries: Optional[int] = 8,
                 backing: Optional[Any] = None,
                 host_cache_parts: Optional[int] = None,
                 host_cache_bytes: Optional[int] = None,
                 read_ahead: bool = True,
                 tracer: Optional[Any] = None,
                 profiler: Optional[Any] = None):
        if capacity_parts is not None and capacity_parts < 1:
            raise ValueError(f"capacity_parts must be >= 1, got {capacity_parts}")
        if capacity_bytes is not None and capacity_bytes < 1:
            raise ValueError(f"capacity_bytes must be >= 1, got {capacity_bytes}")
        if max_stacked_entries is not None and max_stacked_entries < 1:
            raise ValueError(f"max_stacked_entries must be >= 1, "
                             f"got {max_stacked_entries}")
        self.pg = pg
        self.capacity_parts = capacity_parts
        self.capacity_bytes = capacity_bytes
        # stacked entries duplicate their partitions' buffers, so even an
        # otherwise-unbounded store caps how many distinct pid tuples stay
        # resident (LRU beyond this) — a long-lived TraditionalMP session
        # cycling through many top-p sets must not grow device memory
        # without limit
        self.max_stacked_entries = max_stacked_entries
        self.stats = LoadStats()
        self.backing = backing
        # observability: spans on load/prefetch paths; defaults to the
        # no-op singleton so hot loops pay ~nothing when untraced
        from ..obs.trace import NULL_TRACER
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # resource profiling (obs/profile.py): device live-bytes sampled
        # at span close; the no-op singleton when profiling is off
        from ..obs.profile import NULL_PROFILER
        self.profiler = profiler if profiler is not None else NULL_PROFILER
        # the host tier the device cache stages from: the whole graph
        # pinned in RAM (no backing — pre-PR-5 behaviour), or a
        # disk-backed host LRU with background read-ahead (out of core)
        if backing is not None:
            from ..storage.host_cache import HostShardCache
            self._host_tier: Any = HostShardCache(
                backing, self.stats, capacity_parts=host_cache_parts,
                capacity_bytes=host_cache_bytes, read_ahead=read_ahead,
                tracer=self.tracer)
        else:
            from ..storage.host_cache import HostArrayTier
            self._host_tier = HostArrayTier(pg)
        self._cache: "OrderedDict[Any, StoreEntry]" = OrderedDict()
        self._owner_dev: Optional[jax.Array] = None
        # pinned base keys (refcounted): protected from LRU eviction while
        # a caller evaluates against them — the double-buffer guarantee
        self._pins: Dict[Any, int] = {}
        # the ambient generation view (storage/deltas.py GenerationView),
        # set per-thread by ``viewing(view)``: with a view active, cache
        # keys become the view's bundle tokens (pid, generation, seq,
        # geometry) and host misses stage through the view's delta-overlay
        # loader instead of a plain shard read.  ``None`` (the default)
        # is the pre-delta behaviour, bit-for-bit.
        self._local = threading.local()
        # device-committed owner tables per (generation, seq) — small LRU:
        # one mutation epoch is one entry, and a handful of pinned
        # generations can be in flight at once
        self._owner_cache: "OrderedDict[Any, jax.Array]" = OrderedDict()

    # -- generation views (streaming updates) ------------------------------

    @property
    def view(self):
        """The thread's ambient GenerationView, or None (static graph)."""
        return getattr(self._local, "view", None)

    @contextlib.contextmanager
    def viewing(self, view):
        """``with store.viewing(snapshot): ...`` — every load inside the
        block resolves against that pinned generation: cache keys carry
        (generation, per-pid seq, geometry), so two generations of the
        same pid coexist in both cache tiers without invalidation, and a
        stale pid (pending deltas newer than its shard) stages through
        the view's overlay loader.  ``view=None`` explicitly restores the
        plain-pid behaviour for the block."""
        prev = getattr(self._local, "view", None)
        self._local.view = view
        try:
            yield self
        finally:
            self._local.view = prev

    @property
    def current_generation(self) -> Optional[int]:
        """Generation the thread's loads resolve against (None: in-RAM
        store with no backing — there is no generation to speak of)."""
        v = self.view
        if v is not None:
            return int(v.generation)
        return int(self.backing.generation) if self.backing is not None else None

    def _vk(self, pid: int):
        """The cache key one partition id resolves to under the ambient
        view: the view's bundle token, or the plain pid (no view)."""
        v = self.view
        return int(pid) if v is None else v.bundle_token(int(pid))

    def _vkey(self, key: StoreKey):
        if isinstance(key, tuple):
            return tuple(self._vk(p) for p in key)
        return self._vk(key)

    def _host_get(self, pid: int):
        """Host-tier lookup for one pid under the ambient view."""
        v = self.view
        if v is None:
            return self._host_tier.get(int(pid))
        return self._host_tier.get(self._vk(pid), loader=self._overlay_loader(pid))

    def _overlay_loader(self, pid: int):
        """A host-miss loader bound to the ambient view: rebuilds the
        bundle from the pinned generation (clean pids: a checksum-verified
        shard read re-padded to view geometry; stale pids: the delta
        overlay's rebuilt arrays)."""
        v = self.view
        pid = int(pid)

        def load():
            from ..storage.host_cache import HostBundle, bundle_nbytes
            part, g2l = v.load_bundle(pid)
            return HostBundle(part=part, g2l=g2l,
                              nbytes=bundle_nbytes(part, g2l))
        return load

    # -- global (non-partition) arrays ------------------------------------

    @property
    def owner(self) -> jax.Array:
        """[V] owner table, device-committed once and shared by every run.

        Under an ambient view the table is the view's overlay assignment
        (vertex adds/deletes move ownership between generations), cached
        per (generation, seq) so pinned generations never recommit."""
        v = self.view
        if v is None:
            if self._owner_dev is None:
                self._owner_dev = jax.device_put(self.pg.owner)
            return self._owner_dev
        ok = (int(v.generation), int(v.seq))
        got = self._owner_cache.get(ok)
        if got is None:
            got = jax.device_put(np.asarray(v.assignment))
            self._owner_cache[ok] = got
            while len(self._owner_cache) > 4:
                self._owner_cache.popitem(last=False)
        self._owner_cache.move_to_end(ok)
        return got

    @property
    def part_keys(self):
        """Key set of the evaluator input dict (shared by every entry)."""
        return self._host_tier.part_keys

    @property
    def host_tier(self):
        """The disk→host staging tier (storage/host_cache.py); pinned
        arrays when the store has no backing."""
        return self._host_tier

    # -- residency queries -------------------------------------------------

    def resident_keys(self) -> list:
        return [e.key for e in self._cache.values()]

    def contains(self, key: StoreKey) -> bool:
        """True when ``key`` is resident under ANY staging (a stacked entry
        staged with a sharding is cached under a (key, sharding) pair)."""
        return bool(self._cache_keys_for(key))

    def host_nbytes(self, pid: int) -> int:
        return self._host_tier.nbytes(int(pid))

    # -- loads -------------------------------------------------------------

    def get(self, pid: int) -> StoreEntry:
        """One partition's evaluator inputs, device-resident (OPAT's load)."""
        return self._lookup(int(pid), sharding=None)

    def get_stacked(self, pids: Sequence[int],
                    sharding: Optional[Any] = None) -> StoreEntry:
        """A stacked [n, ...] bundle over ``pids`` (order-sensitive), the
        unit TraditionalMP ships per iteration and MapReduceMP ships once.
        ``sharding`` (e.g. ``NamedSharding(mesh, P('part'))``) distributes
        the leading axis across devices at staging time."""
        key = tuple(int(p) for p in pids)
        if not key:
            raise ValueError("get_stacked needs at least one partition id")
        return self._lookup(key, sharding=sharding)

    def prefetch(self, pid: int) -> bool:
        """Stage ``pid`` off the critical path; a later ``get(pid)`` then
        never pays the staged tier's latency.  Host-resident partitions
        get the async ``device_put`` (pre-PR-5 behaviour); with a disk
        backing, a partition not yet in host RAM gets a background-thread
        *read-ahead* instead — device staging now would block this thread
        on the disk read, defeating the overlap.  Returns True when work
        was actually issued (False: already resident / in flight)."""
        pid = int(pid)
        vk = self._vk(pid)
        if vk in self._cache:
            return False
        if not self._host_tier.resident(vk):
            v = self.view
            if v is None:
                return self._host_tier.read_ahead(pid)
            issued = self._host_tier.read_ahead(
                vk, loader=self._overlay_loader(pid))
            if issued and pid in v.stale_pids:
                self.stats.delta_overlays += 1
            return issued
        with self.tracer.span("store.prefetch", pid=pid) as sp:
            entry = self._stage(pid, sharding=None)
            entry.prefetched = True
            self.stats.prefetch_issued += 1
            self.stats.bytes_prefetched += entry.nbytes
            sp.set(nbytes=entry.nbytes)
            self._insert(entry, cache_key=vk)
            self.profiler.sample_device(sp, self)
        return True

    # -- pinning (double-buffered streaming) --------------------------------

    def pin(self, key: StoreKey) -> None:
        """Protect ``key`` from LRU eviction until the matching unpin().

        This is what makes double-buffered partition streaming safe: while
        partition i is being evaluated, prefetching the heuristic's
        runner-up i+1 (its H2D copy overlapping i's kernel execution) may
        push the cache over capacity — pinning i guarantees the in-flight
        staging evicts something ELSE, never the buffers the running
        kernel reads.  The cache may transiently exceed its budget by the
        pinned entries (the price of the second buffer).  Pins refcount;
        explicit drop()/release()/clear() still remove pinned entries
        (pins only guard the implicit LRU path).
        """
        nk = self._normkey(key)
        self._pins[nk] = self._pins.get(nk, 0) + 1

    def unpin(self, key: StoreKey) -> None:
        nk = self._normkey(key)
        n = self._pins.get(nk, 0) - 1
        if n <= 0:
            self._pins.pop(nk, None)
            # the pin may have let the cache run over budget (that is the
            # point of the second buffer); restore the capacity invariant
            # now that the entry is evictable again
            self._evict_to_capacity(keep=None)
        else:
            self._pins[nk] = n

    @contextlib.contextmanager
    def pinned(self, *keys: StoreKey):
        """``with store.pinned(pid): ...`` — pin for the block's duration."""
        for k in keys:
            self.pin(k)
        try:
            yield self
        finally:
            for k in keys:
                self.unpin(k)

    def drop(self, key: StoreKey) -> bool:
        """Explicitly release every staging of ``key`` — including
        sharding-qualified ones (not counted as evictions)."""
        cks = self._cache_keys_for(key)
        for ck in cks:
            del self._cache[ck]
        return bool(cks)

    def release(self, key: StoreKey) -> bool:
        """A counted ``drop``: the scheduler's retirement hook.  When every
        query waiting on a partition has retired, the scheduler releases
        the entry so its device memory is reclaimed immediately instead of
        waiting to age out of the LRU; ``LoadStats.released`` makes that
        observable.  A later ``get`` simply re-stages cold — release never
        affects correctness, only residency."""
        ok = self.drop(key)
        if ok:
            self.stats.released += 1
        return ok

    def clear(self) -> None:
        """Drop every device entry (the host tier is untouched: cleared
        device residency is a serving experiment, not an invalidation)."""
        self._cache.clear()

    def close(self) -> None:
        """Release both cache tiers and join any in-flight read-ahead —
        the teardown hook ``GraphSession`` calls before rebinding, so a
        repartitioned session can never be served stale host entries of
        the old layout."""
        self._cache.clear()
        self._host_tier.clear()

    # -- internals ---------------------------------------------------------

    @staticmethod
    def _normkey(key: StoreKey):
        return tuple(int(p) for p in key) if isinstance(key, tuple) else int(key)

    def _cache_keys_for(self, key: StoreKey) -> list:
        """All cache keys whose *base* key matches (sharded stagings live
        under (key, str(sharding)) composite cache keys)."""
        nk = self._normkey(key)
        return [ck for ck, e in self._cache.items() if self._normkey(e.key) == nk]

    def _lookup(self, key: StoreKey, sharding: Optional[Any]) -> StoreEntry:
        # the ambient view folds (generation, seq, geometry) into the
        # cache key; a stacked entry staged under a different sharding
        # must not be served for a differently-sharded request either
        vk = self._vkey(key)
        ck = (vk, str(sharding)) if sharding is not None else vk
        with self.tracer.span("store.load", pid=self._normkey(key)) as sp:
            got = self._cache.get(ck)
            if got is not None:
                self._cache.move_to_end(ck)
                self.stats.hits += 1
                if got.prefetched:
                    got.prefetched = False
                    self.stats.prefetch_hits += 1
                    sp.set(tier="prefetch")
                else:
                    sp.set(tier="warm")
                self.profiler.sample_device(sp, self)
                return got
            sp.set(tier="cold")
            entry = self._stage(key, sharding=sharding)
            self.stats.misses += 1
            self.stats.bytes_cold += entry.nbytes
            sp.set(nbytes=entry.nbytes,
                   generation=self.current_generation)
            self._insert(entry, cache_key=ck)
            self.profiler.sample_device(sp, self)
            return entry

    def _stage(self, key: StoreKey, sharding: Optional[Any]) -> StoreEntry:
        """Pull the host bundle through the host tier (a pinned-array
        lookup, a host-LRU hit, or a disk shard read — under an ambient
        view, the view's generation-pinned loader) and dispatch its
        device transfer (``device_put`` is asynchronous: it returns
        immediately with arrays whose data lands on the device in the
        background)."""
        v = self.view
        if v is not None:
            # attribute overlay rebuilds on the calling thread, before the
            # host get hides whether the loader actually ran
            for p in (key if isinstance(key, tuple) else (key,)):
                if int(p) in v.stale_pids \
                        and not self._host_tier.resident(self._vk(p)):
                    self.stats.delta_overlays += 1
        if isinstance(key, tuple):
            bundles = [self._host_get(p) for p in key]
            host = {k: np.stack([b.part[k] for b in bundles])
                    for k in bundles[0].part.keys()}
            g2l = np.stack([np.asarray(b.g2l) for b in bundles])
        else:
            bundle = self._host_get(key)
            host, g2l = bundle.part, np.asarray(bundle.g2l)
        nbytes = sum(np.asarray(v).nbytes for v in host.values()) + g2l.nbytes
        if sharding is not None:
            dev = {k: jax.device_put(v, sharding) for k, v in host.items()}
            g2l_dev = jax.device_put(g2l, sharding)
        else:
            dev = jax.device_put(host)
            g2l_dev = jax.device_put(g2l)
        return StoreEntry(key=key, part=dev, g2l=g2l_dev, nbytes=nbytes)

    def _insert(self, entry: StoreEntry, cache_key: Optional[Any] = None) -> None:
        ck = cache_key if cache_key is not None else self._normkey(entry.key)
        self._cache[ck] = entry
        self._cache.move_to_end(ck)
        self._evict_to_capacity(keep=ck)

    def _is_pinned(self, ck: Any) -> bool:
        e = self._cache.get(ck)
        return e is not None and self._normkey(e.key) in self._pins

    def _evict_to_capacity(self, keep: Any) -> None:
        """Drop least-recently-used entries until within capacity.  The
        just-inserted entry is never evicted, even if it alone exceeds the
        budget — the caller needs it regardless.  Pinned entries are
        likewise skipped (double-buffered streaming: the entry under
        evaluation must survive the overlapped staging of the next one),
        so the cache can transiently exceed capacity by the pinned set."""
        def over() -> bool:
            if self.capacity_parts is not None:
                if sum(e.cost_parts for e in self._cache.values()) > self.capacity_parts:
                    return True
            if self.capacity_bytes is not None:
                if sum(e.nbytes for e in self._cache.values()) > self.capacity_bytes:
                    return True
            return False

        while over():
            victim = next((k for k in self._cache
                           if k != keep and not self._is_pinned(k)), None)
            if victim is None:
                break
            del self._cache[victim]
            self.stats.evictions += 1

        if self.max_stacked_entries is not None:
            def stacked():
                return [k for k, e in self._cache.items()
                        if isinstance(e.key, tuple)]
            while len(stacked()) > self.max_stacked_entries:
                victim = next((k for k in stacked()
                               if k != keep and not self._is_pinned(k)), None)
                if victim is None:
                    break
                del self._cache[victim]
                self.stats.evictions += 1
