"""MapReduceMP — map/reduce-style parallel query evaluation (paper Sec. 9),
adapted to TPU as a single SPMD ``shard_map`` program.

Mapping of the paper's roles onto JAX/TPU constructs (see DESIGN.md):

  mapper task (one per partition)   -> one device on the "part" mesh axis,
                                       holding its partition resident in HBM
  one-edge expansion per iteration  -> one dense [EB, W] tile-match step
                                       (NO within-partition closure; exactly
                                       the paper's mapper semantics)
  emit (dest partition id, value)   -> rows tagged with owner[frontier]
  shuffle on partition id           -> quota-based ragged jax.lax.all_to_all
  reducer (update SNI/IMA/FAA)      -> masked merge into device-local buffers
  jobtracker SNI merge / stop check -> jax.lax.psum of active counts inside
                                       a lax.while_loop

The whole query runs as ONE compiled program: iterations are a
``lax.while_loop`` whose condition is a global psum — there is no host
round-trip between iterations, which is the beyond-paper response-time win
(the paper's Hadoop incarnation pays a full job launch per iteration).
The same condition also carries the answer budget ("all or specified
number of answers", Sec. 1): a psum of per-mapper UNIQUE-answer counts
(dedup is done device-side; duplicates of an answer always converge on the
mapper owning its last frontier vertex, so per-mapper distinct counts add
up exactly) reaching ``max_answers`` exits the compiled program early
on-device — ``max_answers=K`` returns exactly K unique answers in one run.

Backpressure: rows whose destination quota is full simply stay in the local
buffer and are re-offered next iteration — deadlock-free because delivered
rows strictly drain and the while-loop only ends when nothing is active
anywhere.  Overflow of the *merge* buffer sets a flag the host checks.

When fewer mapper nodes than partitions are available (the paper's
m < required(i) case), ``m_limit`` gates expansion to the top-m partitions
per iteration, ranked on-device by the SN heuristics — including MAX-YIELD,
whose per-partition completed/spawned counters are carried through the
while_loop state and all_gather'd at ranking time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from .engine import EngineConfig, _expand_classify
from .graph import PartitionedGraph, WILDCARD
from .heuristics import MAX_SN, MAX_YIELD, MIN_SN, RANDOM_SN
from .metrics import RunStats, l_ideal_for_plan
from .plan import Plan, PlanArrays
from .runner import RunReport, RunRequest, truncate_answers
from .state import apply_value_op
from .store import PartitionStore

# "no budget" sentinel for the on-device answer-count stop test
_NO_BUDGET = np.int32(2**31 - 1)


@dataclasses.dataclass
class MapReduceMPResult:
    answers: np.ndarray
    stats: RunStats
    n_iterations: int
    # per-partition yield counters carried through the while_loop state —
    # the same completed/spawned observations the host-loop engines feed
    # into QueryState.observe_yield, surfaced for the session profile
    completed_from: np.ndarray = None   # [P] int64
    spawned_from: np.ndarray = None     # [P] int64


def _heuristic_id(h: str) -> int:
    # MAX-YIELD (id 3) ranks on SNI x completion rate; the completed/
    # spawned counters it needs are carried through the while_loop state
    # and all_gather'd at ranking time, so it runs fully on-device.
    return {MAX_SN: 0, MIN_SN: 1, RANDOM_SN: 2, MAX_YIELD: 3}[h]


class MapReduceMPEngine:
    """One partition per device along the ``part`` mesh axis (k == mesh size)."""

    def __init__(self, pg: PartitionedGraph, mesh: Mesh,
                 cfg: Optional[EngineConfig] = None,
                 quota_per_dest: Optional[int] = None,
                 m_limit: Optional[int] = None,
                 heuristic: str = MAX_SN,
                 max_outer_iters: int = 4096,
                 store: Optional[PartitionStore] = None,
                 tracer=None,
                 profiler=None):
        self.pg = pg
        self.mesh = mesh
        self.cfg = cfg or EngineConfig()
        self.P = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        assert pg.k == self.P, (
            f"MapReduceMP requires one partition per device (k={pg.k}, "
            f"mesh={self.P}); repartition or resize the mesh")
        self.axis = mesh.axis_names[0]
        assert len(mesh.axis_names) == 1, "use a 1-D 'part' mesh"
        self.quota = quota_per_dest or max(8, self.cfg.cap // (4 * self.P))
        self.m_limit = m_limit if m_limit is not None else self.P
        self.heuristic = heuristic
        self.max_outer_iters = max_outer_iters
        self._compiled = None

        # all partitions ship at once, one per device along the mesh axis:
        # the job-start load in MapReduce terms.  The store stages the
        # stacked [P, ...] bundle sharded so device d holds partition d;
        # the first run is a cold load, later runs on the same store reuse
        # the device-resident shards (a warm load).
        self.store = store if store is not None else PartitionStore(pg)
        self._part_sharding = NamedSharding(mesh, P(mesh.axis_names[0]))
        from ..obs.trace import NULL_TRACER
        self.tracer = tracer if tracer is not None else NULL_TRACER
        from ..obs.profile import NULL_PROFILER
        self.profiler = profiler if profiler is not None else NULL_PROFILER
        self._eval_traced = False

    # -- the SPMD program ----------------------------------------------------

    def _build(self, plan_pad_steps: int):
        cfg = self.cfg
        Q, S = cfg.q_pad, cfg.s_pad
        CAP = cfg.cap
        PP, quota = self.P, self.quota
        FAA_CAP = cfg.cap
        axis = self.axis
        hid = _heuristic_id(self.heuristic)
        m_limit = self.m_limit

        def unique_rows(faa, faa_n):
            """#distinct rows among the first faa_n FAA entries, on-device.

            Lexicographic sort via Q iterated stable argsorts (invalid rows
            sentinel-filled with INT32_MAX so they sort last), then count
            rows that differ from their predecessor.  Exact — no hashing.
            """
            N = faa.shape[0]
            valid = jnp.arange(N, dtype=jnp.int32) < faa_n
            rows = jnp.where(valid[:, None], faa, jnp.int32(2**31 - 1))
            order = jnp.arange(N, dtype=jnp.int32)
            for q in range(Q - 1, -1, -1):
                keys = jnp.take(rows[:, q], order)
                order = jnp.take(order, jnp.argsort(keys, stable=True))
            srt = jnp.take(rows, order, axis=0)
            vsrt = jnp.take(valid, order)
            first = jnp.concatenate(
                [jnp.ones(1, bool), jnp.any(srt[1:] != srt[:-1], axis=1)])
            return (vsrt & first).sum(dtype=jnp.int32)

        def frontier_info(rows, step, valid, plan, n_steps, g2l_row, n_core):
            s = jnp.clip(step, 0, S - 1)
            src_slot = plan.src_slot[s]
            fg = jnp.take_along_axis(rows, src_slot[:, None], axis=1)[:, 0]
            fg_safe = jnp.clip(fg, 0, g2l_row.shape[0] - 1)
            lidx = jnp.where(fg >= 0, jnp.take(g2l_row, fg_safe), -1)
            local = (lidx >= 0) & (lidx < n_core)
            live = valid & (step < n_steps)
            return live & local, live & ~local, lidx, fg

        def device_fn(part, g2l_row, owner, plan, n_steps, rngseed, budget):
            # per-device state; partition id == device index on `axis`
            my = jax.lax.axis_index(axis)
            n_core = part["n_core"][0]
            node_label = part["node_label"][0]
            node_value = part["node_value"][0]
            node_gid = part["node_gid"][0]
            pdict = {k: v[0] for k, v in part.items()}
            g2l_row = g2l_row[0]
            # geometry off the input shapes (static at trace time) — one
            # engine serves any padded layout; jit retraces per shape
            Np = node_label.shape[0]
            W = pdict["ell_dst"].shape[1]
            EB = min(cfg.expand_block, CAP + Np)

            if cfg.use_pallas:
                # locality tables for the fused kernel — once per query,
                # outside the while loop (cfg is a closure constant)
                from ..kernels import ops as kops
                aux = kops.denorm_locality(pdict["ell_dgid"], g2l_row, owner)
            else:
                aux = None

            # ---- iteration-0 seeding on every partition (all mappers) ----
            node_idx = jnp.arange(Np, dtype=jnp.int32)
            start_ok = ((node_idx < n_core)
                        & ((plan.start_label == WILDCARD)
                           | (node_label == plan.start_label))
                        & apply_value_op(plan.start_value_op, node_value,
                                         plan.start_value))
            col = jnp.arange(Q, dtype=jnp.int32)
            seed_rows = jnp.where(
                (col[None, :] == plan.start_slot) & start_ok[:, None],
                node_gid[:, None], jnp.int32(-1))

            WT = CAP + Np
            rows = jnp.concatenate(
                [seed_rows, jnp.full((CAP, Q), -1, jnp.int32)], axis=0)
            step = jnp.zeros(WT, jnp.int32)
            valid = jnp.concatenate([start_ok, jnp.zeros(CAP, bool)])
            # single-node queries: seeds may already be complete
            faa = jnp.full((FAA_CAP, Q), -1, jnp.int32)
            faa_n = jnp.int32(0)
            done0 = valid & (step >= n_steps)
            cnt0 = jnp.cumsum(done0.astype(jnp.int32)) - 1
            tgt0 = jnp.where(done0, cnt0, FAA_CAP)
            faa = faa.at[tgt0].set(rows, mode="drop")
            faa_n = jnp.minimum(done0.sum(dtype=jnp.int32), FAA_CAP)
            valid = valid & ~done0

            overflow = jnp.bool_(False)
            # unique-FAA count for the budget stop (seeds are distinct
            # vertices so seed answers are duplicate-free, but keep the
            # same gated computation for uniformity)
            uniq_n = jax.lax.cond(budget < _NO_BUDGET,
                                  lambda: unique_rows(faa, faa_n),
                                  lambda: faa_n)
            # per-partition yield counters (MAX-YIELD observations)
            comp_cnt = faa_n
            spawn_cnt = jnp.int32(0)

            def cond(st):
                rows, step, valid, faa, faa_n, uniq, _c, _s, ovf, it = st
                live = (valid & (step < n_steps)).sum(dtype=jnp.int32)
                total = jax.lax.psum(live, axis)
                # answer-budget stop: the jobtracker's global UNIQUE answer
                # count (psum of per-mapper distinct-FAA sizes; duplicates
                # of an answer always land on one mapper, so per-device
                # unique counts add up exactly) reaching K ends the single
                # compiled program early — no host round-trip and no
                # host-side re-run (Sec. 9 + runner.py budget semantics)
                got = jax.lax.psum(uniq, axis)
                return (total > 0) & (got < budget) & (it < self.max_outer_iters)

            def body(st):
                rows, step, valid, faa, faa_n, uniq, comp, spawn, ovf, it = st
                act, pend, lidx, fg = frontier_info(rows, step, valid, plan,
                                                    n_steps, g2l_row, n_core)

                # -- heuristic gating when m_limit < P (paper Sec. 9.2) --
                my_sni = act.sum(dtype=jnp.int32)
                all_sni = jax.lax.all_gather(my_sni, axis)       # [P]
                if m_limit < PP:
                    if hid == 0:        # MAX-SN: most start/cont. nodes first
                        key = -all_sni
                    elif hid == 1:      # MIN-SN among non-empty
                        key = jnp.where(all_sni > 0, all_sni, jnp.int32(2**30))
                    elif hid == 3:      # MAX-YIELD: SNI x completion rate
                        # the on-device mirror of heuristics.rank_partitions:
                        # Laplace-smoothed completed/(completed+spawned)
                        # from the counters carried in the loop state
                        all_comp = jax.lax.all_gather(comp, axis)    # [P]
                        all_spawn = jax.lax.all_gather(spawn, axis)  # [P]
                        rate = ((all_comp.astype(jnp.float32) + 1.0)
                                / ((all_comp + all_spawn).astype(jnp.float32)
                                   + 2.0))
                        key = -(all_sni.astype(jnp.float32) * rate)
                    else:               # RANDOM among non-empty
                        r = jax.random.permutation(
                            jax.random.fold_in(jax.random.PRNGKey(rngseed), it), PP)
                        key = jnp.where(all_sni > 0, r.astype(jnp.int32),
                                        jnp.int32(2**30))
                    rank = jnp.argsort(jnp.argsort(key))          # dense ranks
                    chosen = rank[my] < m_limit
                else:
                    chosen = jnp.bool_(True)
                act = act & chosen

                # -- map: ONE-edge expansion of up to EB active rows --
                sel = jnp.argsort(~act, stable=True)[:EB]
                m = jnp.take(act, sel)
                rows_b = jnp.take(rows, sel, axis=0)
                step_b = jnp.take(step, sel)
                lidx_b = jnp.take(lidx, sel)
                valid = valid.at[sel].set(jnp.take(valid, sel) & ~m)

                (ok, dg, ns, nr, done_t, keep_t, outm_t, _dest) = \
                    _expand_classify(rows_b, step_b, lidx_b, m, pdict,
                                     g2l_row, owner, aux, plan, n_steps,
                                     cfg.use_pallas)
                EBW = EB * W
                ok_f = ok.reshape(EBW)
                nr_f = nr.reshape(EBW, Q)
                ns_f = ns.reshape(EBW)
                done = done_t.reshape(EBW)

                cnt = jnp.cumsum(done.astype(jnp.int32)) - 1
                tgt = jnp.where(done, faa_n + cnt, FAA_CAP)
                faa = faa.at[tgt].set(nr_f, mode="drop")
                new_faa_n = faa_n + done.sum(dtype=jnp.int32)
                ovf = ovf | (new_faa_n > FAA_CAP)
                faa_n = jnp.minimum(new_faa_n, FAA_CAP)
                uniq = jax.lax.cond(budget < _NO_BUDGET,
                                    lambda f, n: unique_rows(f, n),
                                    lambda f, n: n, faa, faa_n)

                # yield observations: completions here vs continuations
                # spawned into another partition's buffers (the kernel's
                # `out` class — next frontier owned elsewhere)
                comp = comp + done.sum(dtype=jnp.int32)
                spawn = spawn + outm_t.reshape(EBW).sum(dtype=jnp.int32)

                # ALL continuing rows stay local until the shuffle below —
                # the mapper holds non-local rows back-pressured in its own
                # buffer (kernel classes keep | out)
                keep = (keep_t | outm_t).reshape(EBW)
                free = jnp.argsort(valid, stable=True)
                ovf = ovf | (keep.sum(dtype=jnp.int32)
                             > (~valid).sum(dtype=jnp.int32))
                pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
                tgt2 = jnp.where(keep & (pos < WT),
                                 free[jnp.clip(pos, 0, WT - 1)], WT)
                rows = rows.at[tgt2].set(nr_f, mode="drop")
                step = step.at[tgt2].set(ns_f, mode="drop")
                valid = valid.at[tgt2].set(True, mode="drop")

                # -- shuffle: quota-based all_to_all on destination pid --
                _, pend, _, fg = frontier_info(rows, step, valid, plan,
                                               n_steps, g2l_row, n_core)
                dest = jnp.take(owner, jnp.clip(fg, 0, owner.shape[0] - 1))
                dest = jnp.where(pend, dest, PP)          # PP = "no send"
                order = jnp.argsort(dest, stable=True)    # group rows by dest
                sdest = jnp.take(dest, order)
                # rank within each destination group
                grp_start = jnp.searchsorted(sdest, jnp.arange(PP + 1,
                                                               dtype=sdest.dtype))
                rank_in_grp = jnp.arange(WT, dtype=jnp.int32) - grp_start[
                    jnp.clip(sdest, 0, PP)]
                sendable = (sdest < PP) & (rank_in_grp < quota)
                slot = jnp.where(sendable, sdest * quota + rank_in_grp,
                                 PP * quota)
                send_rows = jnp.full((PP * quota, Q), -1, jnp.int32)
                send_step = jnp.zeros(PP * quota, jnp.int32)
                send_valid = jnp.zeros(PP * quota, bool)
                src_idx = order
                send_rows = send_rows.at[slot].set(jnp.take(rows, src_idx, axis=0),
                                                   mode="drop")
                send_step = send_step.at[slot].set(jnp.take(step, src_idx),
                                                   mode="drop")
                send_valid = send_valid.at[slot].set(sendable, mode="drop")
                # invalidate sent rows locally
                sent_src = jnp.where(sendable, src_idx, WT)
                valid = valid.at[sent_src].set(False, mode="drop")

                recv_rows = jax.lax.all_to_all(
                    send_rows.reshape(PP, quota, Q), axis, 0, 0, tiled=False
                ).reshape(PP * quota, Q)
                recv_step = jax.lax.all_to_all(
                    send_step.reshape(PP, quota), axis, 0, 0, tiled=False
                ).reshape(PP * quota)
                recv_valid = jax.lax.all_to_all(
                    send_valid.reshape(PP, quota), axis, 0, 0, tiled=False
                ).reshape(PP * quota)

                # -- reduce: merge received rows into free local slots --
                free2 = jnp.argsort(valid, stable=True)
                ovf = ovf | (recv_valid.sum(dtype=jnp.int32)
                             > (~valid).sum(dtype=jnp.int32))
                pos2 = jnp.cumsum(recv_valid.astype(jnp.int32)) - 1
                tgt3 = jnp.where(recv_valid & (pos2 < WT),
                                 free2[jnp.clip(pos2, 0, WT - 1)], WT)
                rows = rows.at[tgt3].set(recv_rows, mode="drop")
                step = step.at[tgt3].set(recv_step, mode="drop")
                valid = valid.at[tgt3].set(True, mode="drop")

                return (rows, step, valid, faa, faa_n, uniq, comp, spawn,
                        ovf, it + 1)

            st = (rows, step, valid, faa, faa_n, uniq_n, comp_cnt, spawn_cnt,
                  overflow, jnp.int32(0))
            (rows, step, valid, faa, faa_n, uniq_n, comp_cnt, spawn_cnt,
             overflow, iters) = jax.lax.while_loop(cond, body, st)
            # did the loop end because the work drained (vs budget/iter cap)?
            live_end = (valid & (step < n_steps)).sum(dtype=jnp.int32)
            exhausted = jax.lax.psum(live_end, axis) == 0
            return (faa[None], faa_n[None], overflow[None], iters[None],
                    exhausted[None], comp_cnt[None], spawn_cnt[None])

        pspec = P(axis)
        in_specs = (
            {k: pspec for k in self.store.part_keys},  # parts sharded by device
            pspec,                              # g2l rows
            P(),                                # owner replicated
            P(),                                # plan replicated
            P(),                                # n_steps
            P(),                                # rng seed
            P(),                                # answer budget (replicated)
        )
        out_specs = (pspec, pspec, pspec, pspec, pspec, pspec, pspec)
        fn = shard_map(device_fn, mesh=self.mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
        return jax.jit(fn)

    def run(self, plan: Plan, seed: int = 0,
            max_answers: Optional[int] = None) -> MapReduceMPResult:
        cfg = self.cfg
        assert plan.n_slots <= cfg.q_pad and plan.n_steps <= cfg.s_pad
        if self._compiled is None:
            self._compiled = self._build(cfg.s_pad)
        plan_arrays = PlanArrays.from_plan(plan, pad_steps=cfg.s_pad)
        # The device-side budget stop counts UNIQUE answers (per-mapper
        # distinct-FAA sizes; duplicates of an answer always converge on
        # one mapper), so a single compiled run suffices — no geometric
        # host re-run on duplicate-heavy workloads.
        dev_budget = (int(_NO_BUDGET) if max_answers is None
                      else int(max_answers))
        load0 = self.store.stats.copy()
        entry = self.store.get_stacked(tuple(range(self.P)),
                                       sharding=self._part_sharding)
        with self.tracer.span("kernel.eval", engine="mapreduce",
                              n_parts=self.P) as ksp:
            if not self._eval_traced:
                self._eval_traced = True
                ksp.set(first_call=True)
                self.profiler.attribute_kernel(
                    ("mapreduce", "eval"), self._compiled, entry.part,
                    entry.g2l, self.store.owner, plan_arrays,
                    np.int32(plan.n_steps), np.int32(seed),
                    np.int32(min(dev_budget, int(_NO_BUDGET))))
                with self.tracer.span("kernel.compile", engine="mapreduce"):
                    out = self._compiled(
                        entry.part, entry.g2l, self.store.owner, plan_arrays,
                        np.int32(plan.n_steps), np.int32(seed),
                        np.int32(min(dev_budget, int(_NO_BUDGET))))
            else:
                out = self._compiled(
                    entry.part, entry.g2l, self.store.owner, plan_arrays,
                    np.int32(plan.n_steps), np.int32(seed),
                    np.int32(min(dev_budget, int(_NO_BUDGET))))
            faa, faa_n, overflow, iters, exhausted, comp, spawn = out
            faa = np.asarray(faa)          # device sync inside the span
            faa_n = np.asarray(faa_n)
            self.profiler.stamp_kernel(ksp, ("mapreduce", "eval"))
            self.profiler.sample_device(ksp, self.store)
        if bool(np.asarray(overflow).any()):
            raise RuntimeError(
                "MapReduceMP buffer overflow; raise cap/quota")
        rows = [faa[p, : faa_n[p]] for p in range(self.P) if faa_n[p]]
        answers = (np.unique(np.concatenate(rows), axis=0) if rows
                   else np.zeros((0, cfg.q_pad), dtype=np.int32))
        answers = truncate_answers(answers, max_answers)
        n_iter = int(np.asarray(iters).max())
        delta = self.store.stats - load0
        stats = RunStats(query=plan.query.name, scheme=self.pg.scheme,
                         heuristic=self.heuristic,
                         loads=[], l_ideal=l_ideal_for_plan(self.pg, plan),
                         n_answers=int(answers.shape[0]),
                         iterations=n_iter,
                         answers_requested=max_answers,
                         cold_loads=delta.cold_loads,
                         warm_loads=delta.warm_loads,
                         prefetch_hits=delta.prefetch_hits,
                         disk_reads=delta.disk_reads,
                         read_ahead_hits=delta.read_ahead_hits,
                         bytes_cold=delta.bytes_cold,
                         bytes_prefetched=delta.bytes_prefetched,
                         bytes_disk=delta.bytes_disk,
                         bytes_host=delta.bytes_host)
        return MapReduceMPResult(
            answers=answers, stats=stats, n_iterations=n_iter,
            completed_from=np.asarray(comp).astype(np.int64).reshape(-1),
            spawned_from=np.asarray(spawn).astype(np.int64).reshape(-1))

    def run_request(self, req: RunRequest) -> RunReport:
        """The shared ``QueryRunner`` protocol (see core/runner.py).

        The engine's heuristic is fixed at construction (it is baked into
        the compiled program); a conflicting per-request heuristic is an
        error rather than a silent ignore.
        """
        if req.heuristic != self.heuristic:
            raise ValueError(
                f"MapReduceMPEngine was compiled with heuristic "
                f"{self.heuristic!r}; rebuild the engine to run "
                f"{req.heuristic!r}")
        res = self.run(req.plan, seed=req.seed, max_answers=req.max_answers)
        return RunReport(answers=res.answers, stats=res.stats,
                         engine="mapreduce",
                         extra={"n_iterations": res.n_iterations,
                                "completed_from": res.completed_from,
                                "spawned_from": res.spawned_from})
