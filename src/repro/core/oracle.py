"""Whole-graph reference matcher — the correctness ground truth.

The paper validates PGQP against QP-Subdue running on the unpartitioned
graph in main memory.  This module plays that role: a deliberately simple,
*independent* backtracking subgraph matcher over the host numpy graph.  It
shares no code with the partitioned engines, so agreement between the two is
meaningful evidence of correctness (used heavily by the hypothesis property
tests).

Semantics (identical to the engines):
  * injective node mapping (subgraph isomorphism, not homomorphism),
  * undirected graph edges satisfy any query direction; directed graph edges
    match QDIR_OUT along, QDIR_IN against, QDIR_ANY either,
  * nodes without numeric values fail every value predicate,
  * answers are binding rows (slot -> global vertex id); pattern-automorphic
    embeddings count as distinct answers, exactly as in the engines.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from .graph import Graph, WILDCARD
from .query import (OP_BY_NAME, QDIR_ANY, QDIR_IN, QDIR_OUT, DisjunctiveQuery,
                    Query)
from .state import apply_value_op


def _build_adj(graph: Graph):
    adj: List[List[tuple]] = [[] for _ in range(graph.n_nodes)]
    for i in range(graph.n_edges):
        s, d = int(graph.edge_src[i]), int(graph.edge_dst[i])
        l = int(graph.edge_label[i])
        directed = bool(graph.edge_directed[i])
        adj[s].append((d, l, +1 if directed else 0))
        adj[d].append((s, l, -1 if directed else 0))
    return adj


def _node_ok(graph: Graph, vid: int, label_id: int, op: int, value: float) -> bool:
    if label_id != WILDCARD and int(graph.node_label[vid]) != label_id:
        return False
    return bool(apply_value_op(op, np.float32(graph.node_value[vid]), value))


def match_query(graph: Graph, query: Query, q_pad: Optional[int] = None
                ) -> np.ndarray:
    """All embeddings as sorted unique [n, q_pad] rows (-1 = unused slot)."""
    query.validate()
    nl = query.node_label_ids(graph)
    el = query.edge_label_ids(graph)
    ops = [OP_BY_NAME[qn.value_op] for qn in query.nodes]
    vals = [float(qn.value) for qn in query.nodes]
    Q = query.n_nodes
    pad = q_pad or Q
    adj = _build_adj(graph)

    # adjacency of the query pattern
    qadj: List[List[tuple]] = [[] for _ in range(Q)]
    for ei, e in enumerate(query.edges):
        qadj[e.a].append((e.b, ei, True))
        qadj[e.b].append((e.a, ei, False))

    results: List[tuple] = []
    binding = [-1] * Q

    def edge_dir_ok(qdir: int, from_a: bool, gdir: int) -> bool:
        if not from_a:  # flip the constraint when traversing b -> a
            qdir = {QDIR_ANY: QDIR_ANY, QDIR_OUT: QDIR_IN, QDIR_IN: QDIR_OUT}[qdir]
        if qdir == QDIR_ANY or gdir == 0:
            return True
        return (qdir == QDIR_OUT and gdir == +1) or (qdir == QDIR_IN and gdir == -1)

    def consistent(slot: int, vid: int) -> bool:
        if vid in binding:
            return False  # injectivity
        if not _node_ok(graph, vid, nl[slot], ops[slot], vals[slot]):
            return False
        # all pattern edges to already-bound neighbours must exist
        for other, ei, from_this in qadj[slot]:
            if binding[other] == -1:
                continue
            qe = query.edges[ei]
            found = False
            for (nbr, lab, gdir) in adj[vid]:
                if nbr != binding[other]:
                    continue
                if el[ei] != WILDCARD and lab != el[ei]:
                    continue
                if not edge_dir_ok(qe.direction, from_this, gdir):
                    continue
                found = True
                break
            if not found:
                return False
        return True

    # order slots BFS from slot 0 so each new slot touches a bound one
    order = [0]
    seen = {0}
    qi = 0
    while qi < len(order):
        for other, _, _ in qadj[order[qi]]:
            if other not in seen:
                seen.add(other)
                order.append(other)
        qi += 1

    def backtrack(oi: int) -> None:
        if oi == Q:
            results.append(tuple(binding))
            return
        slot = order[oi]
        if oi == 0:
            candidates = range(graph.n_nodes)
        else:
            # candidates = neighbours of any bound pattern-neighbour
            cand = set()
            for other, _, _ in qadj[slot]:
                if binding[other] != -1:
                    for (nbr, _, _) in adj[binding[other]]:
                        cand.add(nbr)
            candidates = sorted(cand)
        for vid in candidates:
            if consistent(slot, vid):
                binding[slot] = vid
                backtrack(oi + 1)
                binding[slot] = -1

    backtrack(0)
    out = np.full((len(results), pad), -1, dtype=np.int32)
    for i, r in enumerate(sorted(set(results))):
        out[i, :Q] = r
    return np.unique(out, axis=0) if out.shape[0] else out


def match_disjunctive(graph: Graph, dq: DisjunctiveQuery,
                      q_pad: Optional[int] = None) -> np.ndarray:
    pad = q_pad or max(q.n_nodes for q in dq.disjuncts)
    parts = [match_query(graph, q, q_pad=pad) for q in dq.disjuncts]
    parts = [p for p in parts if p.shape[0]]
    if not parts:
        return np.zeros((0, pad), dtype=np.int32)
    return np.unique(np.concatenate(parts, axis=0), axis=0)
