"""Partition-choice heuristics (paper Sec. 5).

MAX-SN  : load the eligible partition with the most start/continuation nodes
          (greedy; the paper's best performer).
MIN-SN  : load the eligible partition with the fewest, accumulating spanning
          work into big-SN partitions hoping to process them once.
RANDOM  : baseline — uniform choice among eligible partitions.

Ties are resolved randomly, as in the paper.  The same functions order the
top-p set for TraditionalMP / MapReduceMP (Sec. 8.1 line 4/13).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

MAX_SN = "max-sn"
MIN_SN = "min-sn"
RANDOM_SN = "random-sn"
ALL_HEURISTICS = (MAX_SN, MIN_SN, RANDOM_SN)


def rank_partitions(heuristic: str, eligible: Sequence[int],
                    sni_counts: Sequence[int], rng: np.random.Generator
                    ) -> List[int]:
    """Return ``eligible`` ordered best-first under ``heuristic``."""
    elig = list(eligible)
    if not elig:
        return []
    if heuristic == RANDOM_SN:
        order = list(rng.permutation(len(elig)))
        return [elig[i] for i in order]
    counts = np.asarray([sni_counts[p] for p in elig], dtype=np.int64)
    tie = rng.permutation(len(elig))  # random tie-break
    if heuristic == MAX_SN:
        keys = list(zip(-counts, tie))
    elif heuristic == MIN_SN:
        keys = list(zip(counts, tie))
    else:
        raise ValueError(f"unknown heuristic {heuristic!r}")
    order = sorted(range(len(elig)), key=lambda i: (int(keys[i][0]), int(keys[i][1])))
    return [elig[i] for i in order]


def choose_partition(heuristic: str, eligible: Sequence[int],
                     sni_counts: Sequence[int], rng: np.random.Generator) -> int:
    return rank_partitions(heuristic, eligible, sni_counts, rng)[0]


def choose_top_p(heuristic: str, eligible: Sequence[int],
                 sni_counts: Sequence[int], p: int,
                 rng: np.random.Generator) -> List[int]:
    return rank_partitions(heuristic, eligible, sni_counts, rng)[:p]
