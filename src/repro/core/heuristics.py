"""Partition-choice heuristics (paper Sec. 5, plus a budget-aware one).

MAX-SN   : load the eligible partition with the most start/continuation
           nodes (greedy; the paper's best performer).
MIN-SN   : load the eligible partition with the fewest, accumulating
           spanning work into big-SN partitions hoping to process them once.
RANDOM   : baseline — uniform choice among eligible partitions.
MAX-YIELD: budget-aware (answer-budget runs, ``max_answers=K``): rank by
           SNI count x the partition's *observed completion rate* — the
           fraction of rows processed there so far that completed an
           answer rather than spawning a continuation (Laplace-smoothed,
           so unseen partitions score on SNI alone like MAX-SN).  Under a
           small K this prefers partitions likely to FINISH answers over
           ones that merely fan out spanning work; with no observations or
           K=inf it degrades gracefully toward MAX-SN.

Ties are resolved randomly, as in the paper.  The same functions order the
top-p set for TraditionalMP / MapReduceMP (Sec. 8.1 line 4/13).
"""
from __future__ import annotations

from typing import List, Mapping, Optional, Sequence

import numpy as np

MAX_SN = "max-sn"
MIN_SN = "min-sn"
RANDOM_SN = "random-sn"
MAX_YIELD = "max-yield"
ALL_HEURISTICS = (MAX_SN, MIN_SN, RANDOM_SN)          # the paper's three
BUDGET_HEURISTICS = (MAX_SN, MIN_SN, MAX_YIELD)       # the K-sweep set


def rank_partitions(heuristic: str, eligible: Sequence[int],
                    sni_counts: Sequence[int], rng: np.random.Generator,
                    completion_rates: Optional[Mapping[int, float]] = None,
                    ) -> List[int]:
    """Return ``eligible`` ordered best-first under ``heuristic``.

    ``completion_rates`` maps pid -> observed completed/(completed+spawned)
    rate in [0, 1]; only MAX-YIELD reads it (missing -> 0.5, the smoothed
    no-information prior).
    """
    elig = list(eligible)
    if not elig:
        return []
    if heuristic == RANDOM_SN:
        order = list(rng.permutation(len(elig)))
        return [elig[i] for i in order]
    counts = np.asarray([sni_counts[p] for p in elig], dtype=np.int64)
    tie = rng.permutation(len(elig))  # random tie-break
    if heuristic == MAX_SN:
        keys = list(zip(-counts, tie))
    elif heuristic == MIN_SN:
        keys = list(zip(counts, tie))
    elif heuristic == MAX_YIELD:
        rates = np.asarray(
            [0.5 if completion_rates is None
             else float(completion_rates.get(p, 0.5)) for p in elig])
        # expected completions if loaded now ~ SNI x completion rate
        keys = list(zip(-(counts * rates), tie))
    else:
        raise ValueError(f"unknown heuristic {heuristic!r}")
    order = sorted(range(len(elig)),
                   key=lambda i: (float(keys[i][0]), int(keys[i][1])))
    return [elig[i] for i in order]


def choose_partition(heuristic: str, eligible: Sequence[int],
                     sni_counts: Sequence[int], rng: np.random.Generator,
                     completion_rates: Optional[Mapping[int, float]] = None,
                     ) -> int:
    return rank_partitions(heuristic, eligible, sni_counts, rng,
                           completion_rates)[0]


def choose_top_p(heuristic: str, eligible: Sequence[int],
                 sni_counts: Sequence[int], p: int,
                 rng: np.random.Generator,
                 completion_rates: Optional[Mapping[int, float]] = None,
                 ) -> List[int]:
    return rank_partitions(heuristic, eligible, sni_counts, rng,
                           completion_rates)[:p]
