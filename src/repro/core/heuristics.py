"""Partition-choice heuristics (paper Sec. 5, plus budget/workload-aware).

MAX-SN   : load the eligible partition with the most start/continuation
           nodes (greedy; the paper's best performer).
MIN-SN   : load the eligible partition with the fewest, accumulating
           spanning work into big-SN partitions hoping to process them once.
RANDOM   : baseline — uniform choice among eligible partitions.
MAX-YIELD: budget-aware (answer-budget runs, ``max_answers=K``): rank by
           SNI count x the partition's *observed completion rate* — the
           fraction of rows processed there so far that completed an
           answer rather than spawning a continuation (Laplace-smoothed,
           so unseen partitions score on SNI alone like MAX-SN).  Under a
           small K this prefers partitions likely to FINISH answers over
           ones that merely fan out spanning work; with no observations or
           K=inf it degrades gracefully toward MAX-SN.

MAX-YIELD-SHARED generalizes the per-query ranking to a *workload*: the
``QueryScheduler`` (core/scheduler.py) has many queries pending at once,
and one device-resident partition can advance all of them.
``rank_partitions_shared`` therefore scores each candidate partition by
the total expected yield summed over every pending query that needs it —
Σ_q SNI_q(p) × completion_rate_q(p) — so one cold load services many
queries.  Summing plain SNI (heuristic MAX-SN) is the throughput-greedy
variant with no yield signal.

Ties are resolved randomly, as in the paper.  The same functions order the
top-p set for TraditionalMP / MapReduceMP (Sec. 8.1 line 4/13).
"""
from __future__ import annotations

from typing import List, Mapping, Optional, Sequence, Tuple

import numpy as np

MAX_SN = "max-sn"
MIN_SN = "min-sn"
RANDOM_SN = "random-sn"
MAX_YIELD = "max-yield"
MAX_YIELD_SHARED = "max-yield-shared"
ALL_HEURISTICS = (MAX_SN, MIN_SN, RANDOM_SN)          # the paper's three
BUDGET_HEURISTICS = (MAX_SN, MIN_SN, MAX_YIELD)       # the K-sweep set
SHARED_HEURISTICS = (MAX_SN, MAX_YIELD_SHARED)        # workload-level ranking


def rank_partitions(heuristic: str, eligible: Sequence[int],
                    sni_counts: Sequence[int], rng: np.random.Generator,
                    completion_rates: Optional[Mapping[int, float]] = None,
                    tracer=None) -> List[int]:
    """Return ``eligible`` ordered best-first under ``heuristic``.

    ``completion_rates`` maps pid -> observed completed/(completed+spawned)
    rate in [0, 1]; only MAX-YIELD reads it (missing -> 0.5, the smoothed
    no-information prior).

    An enabled ``tracer`` (obs/trace.py) records one *decision record* per
    call: the per-partition score breakdown (SNI term, completion-rate
    term, final score) plus the chosen pid and ranked order, so
    ``tools/trace_report.py`` can replay why P3 was loaded before P1.
    The untraced path computes nothing extra.
    """
    elig = list(eligible)
    if not elig:
        return []
    if heuristic == RANDOM_SN:
        order = list(rng.permutation(len(elig)))
        ranked = [elig[i] for i in order]
        if tracer is not None and tracer.enabled:
            tracer.decision(
                "heuristic.rank", heuristic=heuristic, chosen=ranked[0],
                ranked=ranked,
                breakdown={int(p): {"sni": int(sni_counts[p]), "score": 0.0}
                           for p in elig})
        return ranked
    counts = np.asarray([sni_counts[p] for p in elig], dtype=np.int64)
    tie = rng.permutation(len(elig))  # random tie-break
    rates = None
    if heuristic == MAX_SN:
        keys = list(zip(-counts, tie))
    elif heuristic == MIN_SN:
        keys = list(zip(counts, tie))
    elif heuristic == MAX_YIELD:
        rates = np.asarray(
            [0.5 if completion_rates is None
             else float(completion_rates.get(p, 0.5)) for p in elig])
        # expected completions if loaded now ~ SNI x completion rate
        keys = list(zip(-(counts * rates), tie))
    else:
        raise ValueError(f"unknown heuristic {heuristic!r}")
    order = sorted(range(len(elig)),
                   key=lambda i: (float(keys[i][0]), int(keys[i][1])))
    ranked = [elig[i] for i in order]
    if tracer is not None and tracer.enabled:
        breakdown = {}
        for i, p in enumerate(elig):
            entry = {"sni": int(counts[i]),
                     # sort keys negate "bigger is better" scores; expose
                     # the natural orientation (argmax(score) == chosen)
                     "score": float(-keys[i][0]) if heuristic != MIN_SN
                     else float(-counts[i])}
            if rates is not None:
                entry["completion_rate"] = float(rates[i])
            breakdown[int(p)] = entry
        tracer.decision("heuristic.rank", heuristic=heuristic,
                        chosen=ranked[0], ranked=ranked,
                        breakdown=breakdown)
    return ranked


def choose_partition(heuristic: str, eligible: Sequence[int],
                     sni_counts: Sequence[int], rng: np.random.Generator,
                     completion_rates: Optional[Mapping[int, float]] = None,
                     tracer=None) -> int:
    return rank_partitions(heuristic, eligible, sni_counts, rng,
                           completion_rates, tracer=tracer)[0]


def choose_top_p(heuristic: str, eligible: Sequence[int],
                 sni_counts: Sequence[int], p: int,
                 rng: np.random.Generator,
                 completion_rates: Optional[Mapping[int, float]] = None,
                 tracer=None) -> List[int]:
    return rank_partitions(heuristic, eligible, sni_counts, rng,
                           completion_rates, tracer=tracer)[:p]


def rank_partitions_shared(heuristic: str,
                           waiting: Mapping[int, Sequence[Tuple]],
                           rng: np.random.Generator,
                           fairness_gamma: float = 0.0,
                           tracer=None) -> List[int]:
    """Workload-level ranking: order candidate partitions best-first by the
    total expected yield over every pending query waiting on them.

    ``waiting`` maps pid -> the per-waiting-query ``(sni_count,
    completion_rate)``, ``(sni_count, completion_rate, rounds_waiting)``,
    or ``(sni_count, completion_rate, rounds_waiting, urgency)``
    observations for that partition (one tuple per query whose SNI/IMA
    makes the partition eligible).  Base scores:

      MAX-SN           : Σ_q sni_q(p)            — most shared pending work
      MAX-YIELD-SHARED : Σ_q sni_q(p) × rate_q(p) — most expected completed
                         answers across the workload (rates are the same
                         Laplace-smoothed per-query observations MAX-YIELD
                         uses, so a fresh workload degrades to MAX-SN/2)

    Fairness under skew: a query whose partitions nobody shares has a
    yield that never dominates a hot partition's, so pure yield ranking
    can starve it for as long as hot traffic keeps arriving.  With
    ``fairness_gamma > 0`` every waiter contributes an *aging* term
    ``gamma × sni_q(p) × rounds_waiting_q`` on top of the base score —
    linear in how many scheduler rounds the query has been passed over —
    so any starving query's partition eventually outranks every bounded
    hot score and is guaranteed service within
    ``O(max_hot_score / (gamma × sni))`` rounds.  ``gamma = 0`` (the
    default) is exactly the pure-yield ranking.

    Deadline awareness: the SLO serving front end (serving/frontend.py)
    attaches a per-query *urgency* — its slack-weighted deadline pressure
    — as the observation's fourth element.  Every waiter then contributes
    ``sni_q(p) × urgency_q`` on top of the base score, so partitions that
    advance deadline-critical queries outrank hotter but slack-rich work.
    All-zero (or absent) urgencies leave every score bit-identical to the
    plain ranking, keeping non-SLO serving byte-for-byte unchanged.

    Ties are resolved randomly, matching ``rank_partitions``.
    """
    pids = sorted(waiting)
    if not pids:
        return []

    def age_of(obs: Tuple) -> float:
        return float(obs[2]) if len(obs) > 2 else 0.0

    if heuristic == MAX_SN:
        base = [float(sum(obs[0] for obs in waiting[p])) for p in pids]
    elif heuristic == MAX_YIELD_SHARED:
        base = [float(sum(obs[0] * obs[1] for obs in waiting[p]))
                for p in pids]
    else:
        raise ValueError(f"unknown shared heuristic {heuristic!r} "
                         f"(one of {SHARED_HEURISTICS})")
    scores = list(base)
    fairness = [0.0] * len(pids)
    if fairness_gamma:
        fairness = [fairness_gamma * sum(obs[0] * age_of(obs)
                                         for obs in waiting[p])
                    for p in pids]
        scores = [s + f for s, f in zip(scores, fairness)]
    urgency = [sum(obs[0] * (float(obs[3]) if len(obs) > 3 else 0.0)
                   for obs in waiting[p]) for p in pids]
    if any(urgency):
        scores = [s + u for s, u in zip(scores, urgency)]
    else:
        urgency = [0.0] * len(pids)
    tie = rng.permutation(len(pids))
    order = sorted(range(len(pids)), key=lambda i: (-scores[i], int(tie[i])))
    ranked = [pids[i] for i in order]
    if tracer is not None and tracer.enabled:
        tracer.decision(
            "heuristic.rank_shared", heuristic=heuristic,
            fairness_gamma=float(fairness_gamma),
            chosen=ranked[0], ranked=ranked,
            breakdown={int(p): {
                "sni": int(sum(obs[0] for obs in waiting[p])),
                "waiters": len(waiting[p]),
                "base": base[i],
                "fairness": fairness[i],
                "urgency": urgency[i],
                "score": scores[i],
            } for i, p in enumerate(pids)})
    return ranked
