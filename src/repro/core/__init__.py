"""PGQP-JAX core: partitioned graph query processing (Das et al., 2019).

Public API:

  Graph / GraphBuilder / PartitionedGraph / build_partitions
  partition_graph / SCHEMES            — multilevel partitioner (6 schemes)
  build_catalog / generate_plan        — cost-based planning
  Query / DisjunctiveQuery / make_*    — query construction
  OPATEngine / TraditionalMPEngine / MapReduceMPEngine
  RunRequest / RunReport / QueryRunner — unified runner protocol with
                                         answer budgets (core/runner.py)
  PartitionStore / LoadStats           — explicit partition residency: LRU
                                         device cache + prefetch (core/store.py);
                                         with a DiskCatalog backing it is a
                                         three-tier disk->host->device cache
                                         (src/repro/storage/, GraphSession
                                         .save/.open)
  GraphSession / QueryResult           — stateful serving API: one session,
                                         many queries, shared residency and
                                         a per-partition workload profile
                                         (core/session.py)
  QueryScheduler / ScheduleReport      — shared-load multi-query serving:
                                         workload-level load ordering
                                         (MAX-YIELD-SHARED) with batched
                                         partition evaluation and per-query
                                         budget retirement
                                         (core/scheduler.py)
  repartition / RepartitionConfig      — workload-aware repartitioning: a
                                         saved profile reweights the graph
                                         and the multilevel partitioner
                                         re-runs as scheme "waw"
                                         (core/repartition.py)
  oracle.match_query                   — whole-graph ground truth
"""
from .catalog import Catalog, build_catalog
from .engine import EngineConfig, make_partition_evaluator
from .graph import (Graph, GraphBuilder, LabelVocab, PartitionArrays,
                    PartitionedGraph, WILDCARD, build_partitions)
from .heuristics import (ALL_HEURISTICS, BUDGET_HEURISTICS, MAX_SN, MAX_YIELD,
                         MAX_YIELD_SHARED, MIN_SN, RANDOM_SN,
                         SHARED_HEURISTICS, choose_partition, choose_top_p,
                         rank_partitions, rank_partitions_shared)
from .metrics import (RunStats, avg_load_ratio_across_schemes,
                      avg_load_ratio_for_batch, l_ideal_for_plan,
                      total_connected_components, validate_run_residency)
from .opat import OPATEngine, OPATResult
from .oracle import match_disjunctive, match_query
from .partition import SCHEMES, PartitionScheme, partition_graph, partition_quality
from .plan import Plan, PlanArrays, PlanStep, generate_plan
from .query import (DisjunctiveQuery, Query, QueryEdge, QueryNode,
                    make_path_query, make_star_query)
from .repartition import (WAW_SCHEME, RepartitionConfig, answer_span_matrix,
                          load_profile, repartition, repartition_assignment,
                          reweight_edges)
from .runner import QueryRunner, RunReport, RunRequest, truncate_answers
from .scheduler import QueryScheduler, ScheduleReport, batch_bucket
from .session import GraphSession, QueryResult
from .state import BindingBatch, QueryState
from .store import LoadStats, PartitionStore, StoreEntry
from .traditional_mp import TraditionalMPEngine, TraditionalMPResult

__all__ = [
    "Catalog", "build_catalog", "EngineConfig", "make_partition_evaluator",
    "Graph", "GraphBuilder", "LabelVocab", "PartitionArrays",
    "PartitionedGraph", "WILDCARD", "build_partitions",
    "ALL_HEURISTICS", "BUDGET_HEURISTICS", "MAX_SN", "MAX_YIELD",
    "MAX_YIELD_SHARED", "MIN_SN", "RANDOM_SN", "SHARED_HEURISTICS",
    "choose_partition", "choose_top_p", "rank_partitions",
    "rank_partitions_shared",
    "QueryRunner", "RunReport", "RunRequest", "truncate_answers",
    "RunStats", "avg_load_ratio_across_schemes", "avg_load_ratio_for_batch",
    "l_ideal_for_plan", "total_connected_components",
    "validate_run_residency",
    "OPATEngine", "OPATResult", "match_disjunctive", "match_query",
    "SCHEMES", "PartitionScheme", "partition_graph", "partition_quality",
    "Plan", "PlanArrays", "PlanStep", "generate_plan",
    "DisjunctiveQuery", "Query", "QueryEdge", "QueryNode",
    "make_path_query", "make_star_query",
    "WAW_SCHEME", "RepartitionConfig", "answer_span_matrix", "load_profile",
    "repartition", "repartition_assignment", "reweight_edges",
    "BindingBatch", "QueryState",
    "LoadStats", "PartitionStore", "StoreEntry",
    "GraphSession", "QueryResult",
    "QueryScheduler", "ScheduleReport", "batch_bucket",
    "TraditionalMPEngine", "TraditionalMPResult",
]
