"""Query representation for PGQP-JAX.

The paper's queries (QP-Subdue style) are subgraph patterns whose nodes and
edges carry label predicates, comparison operators over numeric values
(<, <=, >, >=, !=, =), wildcards ('?'), and Boolean combinations (AND / OR).

A ``Query`` here is a single conjunctive pattern (AND of all node/edge
predicates).  OR queries are normalized to a *disjunction of conjunctive
patterns* (DNF) — the paper's Q3 ("Fred Wolf writer OR Salma Hayek actress")
becomes two patterns whose answer sets are unioned; this is exactly how
QP-Subdue handles top-level ORs (one plan per disjunct).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple


from .graph import Graph, WILDCARD

NO_MATCH = -3  # label absent from the graph vocabulary; matches nothing

# value comparison ops
OP_NONE, OP_EQ, OP_NE, OP_LT, OP_LE, OP_GT, OP_GE = 0, 1, 2, 3, 4, 5, 6
OP_BY_NAME = {"": OP_NONE, "=": OP_EQ, "!=": OP_NE, "<": OP_LT, "<=": OP_LE,
              ">": OP_GT, ">=": OP_GE}

# edge direction constraint in a query
QDIR_ANY, QDIR_OUT, QDIR_IN = 0, 1, 2


@dataclasses.dataclass
class QueryNode:
    label: str = "?"                 # "?" is a wildcard
    value_op: str = ""               # one of OP_BY_NAME keys
    value: float = 0.0


@dataclasses.dataclass
class QueryEdge:
    a: int                           # query-node index
    b: int
    label: str = "?"
    direction: int = QDIR_ANY        # constraint from a's point of view


@dataclasses.dataclass
class Query:
    """One conjunctive subgraph pattern."""

    nodes: List[QueryNode]
    edges: List[QueryEdge]
    name: str = "q"

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    def to_json_dict(self) -> dict:
        """Plain-JSON form — one line of a ``serve.py --workload`` file."""
        return {
            "name": self.name,
            "nodes": [dataclasses.asdict(n) for n in self.nodes],
            "edges": [dataclasses.asdict(e) for e in self.edges],
        }

    @staticmethod
    def from_json_dict(d: dict) -> "Query":
        q = Query(
            nodes=[QueryNode(**n) for n in d["nodes"]],
            edges=[QueryEdge(**e) for e in d["edges"]],
            name=d.get("name", "q"))
        q.validate()
        return q

    def validate(self) -> None:
        n = self.n_nodes
        assert n >= 1
        for e in self.edges:
            assert 0 <= e.a < n and 0 <= e.b < n and e.a != e.b
        # the pattern must be connected for plan generation
        if n > 1:
            seen = {0}
            frontier = [0]
            adj = {i: [] for i in range(n)}
            for e in self.edges:
                adj[e.a].append(e.b)
                adj[e.b].append(e.a)
            while frontier:
                v = frontier.pop()
                for u in adj[v]:
                    if u not in seen:
                        seen.add(u)
                        frontier.append(u)
            assert len(seen) == n, "query pattern must be connected"

    def node_label_ids(self, graph: Graph) -> List[int]:
        # labels absent from the graph vocabulary map to NO_MATCH (-3), a
        # sentinel that matches nothing (NOT to the wildcard!)
        return [WILDCARD if qn.label == "?" else graph.node_vocab.get(qn.label, NO_MATCH)
                for qn in self.nodes]

    def edge_label_ids(self, graph: Graph) -> List[int]:
        return [WILDCARD if qe.label == "?" else graph.edge_vocab.get(qe.label, NO_MATCH)
                for qe in self.edges]


@dataclasses.dataclass
class DisjunctiveQuery:
    """Top-level OR of conjunctive patterns (paper's Boolean operators)."""

    disjuncts: List[Query]
    name: str = "q_or"

    def to_json_dict(self) -> dict:
        return {"name": self.name,
                "disjuncts": [q.to_json_dict() for q in self.disjuncts]}

    @staticmethod
    def from_json_dict(d: dict) -> "DisjunctiveQuery":
        """Accepts the full ``{"disjuncts": [...]}`` form or a bare
        conjunctive pattern (treated as a single disjunct) — so a
        workload file can mix both."""
        if "disjuncts" in d:
            if not d["disjuncts"]:
                raise ValueError(
                    f"query {d.get('name', '?')!r} has no disjuncts")
            return DisjunctiveQuery(
                disjuncts=[Query.from_json_dict(q) for q in d["disjuncts"]],
                name=d.get("name", "q_or"))
        q = Query.from_json_dict(d)
        return DisjunctiveQuery([q], name=q.name)


def make_path_query(labels: Sequence[str], edge_labels: Sequence[str],
                    name: str = "path") -> Query:
    """Convenience: a simple path pattern L0 -e0- L1 -e1- L2 ..."""
    assert len(edge_labels) == len(labels) - 1
    nodes = [QueryNode(label=l) for l in labels]
    edges = [QueryEdge(a=i, b=i + 1, label=el) for i, el in enumerate(edge_labels)]
    q = Query(nodes=nodes, edges=edges, name=name)
    q.validate()
    return q


def make_star_query(center: str, leaves: Sequence[Tuple[str, str]],
                    name: str = "star") -> Query:
    """Star pattern: center node connected to each (edge_label, leaf_label)."""
    nodes = [QueryNode(label=center)] + [QueryNode(label=l) for _, l in leaves]
    edges = [QueryEdge(a=0, b=i + 1, label=el) for i, (el, _) in enumerate(leaves)]
    q = Query(nodes=nodes, edges=edges, name=name)
    q.validate()
    return q
