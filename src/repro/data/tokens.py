"""Deterministic synthetic token pipeline for the LM substrate.

A tiny order-1 Markov source over the vocabulary (Zipf-ish marginals, sparse
transitions) so that a model can actually reduce loss — pure-random tokens
give a constant-entropy floor and make training demos meaningless.

The pipeline is stateless-per-step: batch ``i`` is a pure function of
(seed, i), so data-pipeline state is a single integer.  Checkpoints store
``step`` and restarts are bitwise reproducible (DESIGN.md §6 fault
tolerance).  At cluster scale each host draws its own slice by folding
``process_index`` into the key — same code path here with one host.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    branching: int = 4          # out-degree of the Markov chain
    step: int = 0               # checkpointable cursor

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse deterministic transition table [vocab, branching]
        self._next = rng.integers(0, self.vocab,
                                  size=(self.vocab, self.branching),
                                  dtype=np.int32)
        # Zipf-ish start distribution
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._start_p = (p / p.sum()).astype(np.float64)

    def batch_at(self, step: int, process_index: int = 0) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + process_index) * 2_654_435_761 + step)
        starts = rng.choice(self.vocab, size=self.batch, p=self._start_p)
        seqs = np.empty((self.batch, self.seq + 1), dtype=np.int32)
        seqs[:, 0] = starts
        # vectorized Markov walk with occasional resets (doc boundaries)
        for t in range(self.seq):
            branch = rng.integers(0, self.branching, size=self.batch)
            nxt = self._next[seqs[:, t], branch]
            reset = rng.random(self.batch) < 0.01
            if reset.any():
                nxt = np.where(reset,
                               rng.choice(self.vocab, size=self.batch,
                                          p=self._start_p), nxt)
            seqs[:, t + 1] = nxt
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    # --- checkpoint integration -----------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"pipeline_step": self.step, "pipeline_seed": self.seed}

    def load_state_dict(self, d: Dict[str, int]) -> None:
        assert int(d.get("pipeline_seed", self.seed)) == self.seed, \
            "pipeline seed changed across restart"
        self.step = int(d["pipeline_step"])


def frontend_batch(cfg, batch: int, seq: int, seed: int = 0
                   ) -> Dict[str, np.ndarray]:
    """Synthetic frontend-stub tensors for audio/vlm families."""
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}
    from ..models.config import FAMILY_AUDIO, FAMILY_VLM
    if cfg.family == FAMILY_AUDIO:
        out["frame_embeds"] = rng.normal(
            size=(batch, seq, cfg.frontend_dim())).astype(np.float32)
    elif cfg.family == FAMILY_VLM and cfg.frontend_tokens:
        F = min(cfg.frontend_tokens, seq // 2)
        out["image_embeds"] = rng.normal(
            size=(batch, F, cfg.frontend_dim())).astype(np.float32)
    return out
