from .generators import (imdb_like_graph, imdb_queries, subgen_like_graph,
                         subgen_queries)

__all__ = ["imdb_like_graph", "imdb_queries", "subgen_like_graph",
           "subgen_queries"]
