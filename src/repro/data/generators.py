"""Synthetic dataset generators mirroring the paper's two datasets (Sec. 7).

``imdb_like_graph``  — a typed movie graph: Movie/Person/Genre/Year/Company
entity nodes linked by labeled edges ("acted_in", "genre_is", "in_year",
"produced_by", ...), with *unique* name labels for people/movies (the paper
notes IMDB answers are often unique because vertex labels are unique) and
numeric year values for comparison predicates.

``subgen_like_graph`` — the paper's Subgen-style uniform random graph with a
configurable number of vertex/edge labels and ``n_embed`` planted instances
of a 4-node template substructure, so queries have many answers that span
partitions (the paper embeds 200 instances).

Both scale down to CPU test sizes; the paper-scale configs live in
``benchmarks/`` (IMDB 1750K/5100K, synthetic 400K/1200K).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from ..core.graph import Graph, GraphBuilder
from ..core.query import (DisjunctiveQuery, Query, QueryEdge, QueryNode)


# ---------------------------------------------------------------------------
# IMDB-like
# ---------------------------------------------------------------------------

def imdb_like_graph(n_movies: int = 300, n_people: int = 400,
                    n_companies: int = 40, n_genres: int = 12,
                    year_lo: int = 1980, year_hi: int = 2015,
                    n_communities: int = 8, locality: float = 0.9,
                    seed: int = 0) -> Graph:
    """Typed movie graph WITH community structure: actors/companies mostly
    work within a community (era/industry cluster), as in the real IMDB —
    this is what gives METIS-style partitioners a small cut and makes the
    paper's load ratios (answers mostly within one partition) reproducible.
    ``locality`` is the probability a cast/production edge stays inside the
    movie's community."""
    rng = np.random.default_rng(seed)
    b = GraphBuilder()

    genres = [b.add_node(f"genre_{i}") for i in range(n_genres)]
    years: Dict[int, int] = {y: b.add_node("year", value=float(y))
                             for y in range(year_lo, year_hi + 1)}
    companies = [b.add_node(f"company_{i}") for i in range(n_companies)]
    people = [b.add_node(f"person_{i}") for i in range(n_people)]
    C = max(1, n_communities)
    comm_people = [list(range(c, n_people, C)) for c in range(C)]
    comm_companies = [list(range(c, n_companies, C)) for c in range(C)]
    movies = []
    for i in range(n_movies):
        m = b.add_node(f"movie_{i}")
        movies.append(m)
        c = int(rng.integers(0, C))
        b.add_edge(m, years[int(rng.integers(year_lo, year_hi + 1))], "in_year")
        for g in rng.choice(genres, size=int(rng.integers(1, 4)), replace=False):
            b.add_edge(m, int(g), "genre_is")
        comp_pool = comm_companies[c] if (comm_companies[c]
                                          and rng.random() < locality) \
            else range(n_companies)
        b.add_edge(m, companies[int(rng.choice(list(comp_pool)))], "produced_by")
        n_cast = int(rng.integers(1, 6))
        local_pool = comm_people[c]
        for j in range(n_cast):
            if local_pool and rng.random() < locality:
                p = people[int(rng.choice(local_pool))]
            else:
                p = people[int(rng.integers(0, n_people))]
            role = "acted_in" if (j > 0 or rng.random() < 0.8) else "wrote"
            b.add_edge(int(p), m, role)
    # a few writers as well (community-local)
    for _ in range(n_movies // 3):
        c = int(rng.integers(0, C))
        pool = comm_people[c] or list(range(n_people))
        b.add_edge(people[int(rng.choice(pool))],
                   movies[int(rng.integers(0, n_movies))], "wrote")
    return b.build()


def imdb_queries(graph: Graph, seed: int = 0) -> List[DisjunctiveQuery]:
    """Three queries with the paper's Q1/Q2/Q3 *characteristics*:

    Q1 — person + two genres star (answers likely to need a partition twice),
    Q2 — movie/company/genre/year with a != year predicate (spanning answers),
    Q3 — OR of two patterns (answers often inside one partition).
    """
    rng = np.random.default_rng(seed)
    # pick labels that actually occur so answers exist
    def pick(label_prefix: str) -> str:
        ids = [i for i in range(graph.n_nodes)
               if graph.node_vocab.str_of(int(graph.node_label[i])).startswith(label_prefix)]
        return graph.node_vocab.str_of(int(graph.node_label[int(rng.choice(ids))]))

    person = pick("person_")
    genre_a, genre_b = pick("genre_"), pick("genre_")

    q1 = Query(name="Q1", nodes=[
        QueryNode(label=person),      # 0 actor
        QueryNode(label="?"),         # 1 movie (wildcard)
        QueryNode(label=genre_a),     # 2
        QueryNode(label="?"),         # 3 company
    ], edges=[
        QueryEdge(0, 1, "acted_in"),
        QueryEdge(1, 2, "genre_is"),
        QueryEdge(1, 3, "produced_by"),
    ])

    q2 = Query(name="Q2", nodes=[
        QueryNode(label=person),
        QueryNode(label="?"),                       # movie
        QueryNode(label=genre_b),
        QueryNode(label="year", value_op="!=", value=2000.0),
    ], edges=[
        QueryEdge(0, 1, "acted_in"),
        QueryEdge(1, 2, "genre_is"),
        QueryEdge(1, 3, "in_year"),
    ])

    person2 = pick("person_")
    q3a = Query(name="Q3a", nodes=[
        QueryNode(label=person), QueryNode(label="?"), QueryNode(label="?")],
        edges=[QueryEdge(0, 1, "wrote"), QueryEdge(1, 2, "produced_by")])
    q3b = Query(name="Q3b", nodes=[
        QueryNode(label=person2), QueryNode(label="?"), QueryNode(label="?")],
        edges=[QueryEdge(0, 1, "acted_in"), QueryEdge(1, 2, "produced_by")])

    return [DisjunctiveQuery([q1], name="Q1"),
            DisjunctiveQuery([q2], name="Q2"),
            DisjunctiveQuery([q3a, q3b], name="Q3")]


# ---------------------------------------------------------------------------
# Subgen-like
# ---------------------------------------------------------------------------

TEMPLATE_LABELS = ("tmpl_A", "tmpl_B", "tmpl_C", "tmpl_D")
TEMPLATE_EDGES = (("e_ab", 0, 1), ("e_bc", 1, 2), ("e_bd", 1, 3))


def subgen_like_graph(n_nodes: int = 2000, n_edges: int = 6000,
                      n_vlabels: int = 50, n_elabels: int = 100,
                      n_embed: int = 50, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    b = GraphBuilder()
    # background uniform-label nodes
    for i in range(n_nodes):
        b.add_node(f"v{int(rng.integers(0, n_vlabels))}")
    # embedded template instances (paper: 200 instances of Fig. 6)
    inst_nodes = []
    for _ in range(n_embed):
        ids = [b.add_node(l) for l in TEMPLATE_LABELS]
        for el, a, c in TEMPLATE_EDGES:
            b.add_edge(ids[a], ids[c], el)
        inst_nodes.append(ids)
    total = n_nodes + 4 * n_embed
    # background uniform edges
    for _ in range(n_edges):
        s, d = rng.integers(0, total, size=2)
        while s == d:
            s, d = rng.integers(0, total, size=2)
        b.add_edge(int(s), int(d), f"e{int(rng.integers(0, n_elabels))}")
    # tie instances into the background so they cross partitions
    for ids in inst_nodes:
        s = int(rng.integers(0, n_nodes))
        b.add_edge(s, ids[0], f"e{int(rng.integers(0, n_elabels))}")
    return b.build()


def waw_skewed_graph(n_left: int = 400, n_right: int = 440,
                     intra_edges: int = 1500, bridge_edges: int = 8,
                     n_instances: int = 12, n_cold_pairs: int = 8,
                     seed: int = 0) -> Graph:
    """Skewed-workload benchmark graph for workload-aware repartitioning.

    Two dense background communities ("left"/"right") joined by a few
    bridge edges, so every balanced min cut separates the communities.
    ``n_instances`` hot template instances (the Subgen template of
    ``TEMPLATE_LABELS``) deliberately STRADDLE that cut: A, C, D are
    anchored into the left community (one anchor edge each) and B into the
    right (three anchors), so splitting an instance (cutting its three
    template edges) costs exactly as much as co-locating it (cutting three
    anchors) — a topology-only partitioner is indifferent and, with
    anchors inserted first in adjacency order, dissolves each instance
    into its anchor communities, leaving every hot answer spanning two
    partitions.  Only the observed workload can break the tie: a profile
    of template queries pulls the template edges' weights up and the
    repartitioner co-locates each instance without raising the edge cut.

    ``n_cold_pairs`` plants cold 2-node patterns (``cold_A -e_cold->
    cold_B``) wholly inside the left community — the rarely-queried
    control that must not regress — and also balances the communities'
    node counts (left gains 3 nodes per instance + 2 per cold pair, right
    gains 1 + the pre-sized surplus).
    """
    rng = np.random.default_rng(seed)
    b = GraphBuilder()
    left = [b.add_node(f"bgL{int(rng.integers(0, 20))}") for _ in range(n_left)]
    right = [b.add_node(f"bgR{int(rng.integers(0, 20))}") for _ in range(n_right)]
    for side in (left, right):
        for _ in range(intra_edges):
            s, d = rng.choice(len(side), size=2, replace=False)
            b.add_edge(side[int(s)], side[int(d)],
                       f"e{int(rng.integers(0, 30))}")
    for _ in range(bridge_edges):
        b.add_edge(left[int(rng.integers(0, n_left))],
                   right[int(rng.integers(0, n_right))], "e_bridge")
    # hot template instances straddling the communities.  Anchor edges are
    # added BEFORE template edges so they come first in each instance
    # node's adjacency: the partitioner's tie-breaking (sorted heavy-edge
    # matching takes the first heaviest neighbour) then contracts instance
    # nodes into their anchor communities, i.e. the baseline splits them.
    for _ in range(n_instances):
        ids = [b.add_node(l) for l in TEMPLATE_LABELS]
        a, bb, c, d = ids
        b.add_edge(a, left[int(rng.integers(0, n_left))], "anchor")
        b.add_edge(c, left[int(rng.integers(0, n_left))], "anchor")
        b.add_edge(d, left[int(rng.integers(0, n_left))], "anchor")
        for _ in range(3):
            b.add_edge(bb, right[int(rng.integers(0, n_right))], "anchor")
        for el, s, t in TEMPLATE_EDGES:
            b.add_edge(ids[s], ids[t], el)
    # cold pairs wholly inside the left community
    for _ in range(n_cold_pairs):
        ca = b.add_node("cold_A")
        cb = b.add_node("cold_B")
        b.add_edge(ca, left[int(rng.integers(0, n_left))], "anchor")
        b.add_edge(cb, left[int(rng.integers(0, n_left))], "anchor")
        b.add_edge(ca, cb, "e_cold")
    return b.build()


def waw_skewed_queries(hot_repeats: int = 6) -> List[DisjunctiveQuery]:
    """The skewed query mix for ``waw_skewed_graph``: the hot template
    query repeated ``hot_repeats`` times (the traffic the repartitioner
    should optimise for) plus one cold within-community query (the control
    that must stay cheap)."""
    hot = Query(name="HOT", nodes=[
        QueryNode(label=l) for l in TEMPLATE_LABELS],
        edges=[QueryEdge(0, 1, "e_ab"), QueryEdge(1, 2, "e_bc"),
               QueryEdge(1, 3, "e_bd")])
    cold = Query(name="COLD", nodes=[
        QueryNode(label="cold_A"), QueryNode(label="cold_B")],
        edges=[QueryEdge(0, 1, "e_cold")])
    mix = [DisjunctiveQuery([hot], name=f"HOT{i+1}")
           for i in range(hot_repeats)]
    mix.append(DisjunctiveQuery([cold], name="COLD"))
    return mix


def subgen_queries(graph: Graph) -> List[DisjunctiveQuery]:
    """Q4 — subgraph of the embedded template; Q5 — the template itself;
    Q6 — pattern only partially present (2 nodes + 1 edge exist)."""
    q4 = Query(name="Q4", nodes=[
        QueryNode(label="tmpl_A"), QueryNode(label="tmpl_B"),
        QueryNode(label="tmpl_C")],
        edges=[QueryEdge(0, 1, "e_ab"), QueryEdge(1, 2, "e_bc")])
    q5 = Query(name="Q5", nodes=[
        QueryNode(label=l) for l in TEMPLATE_LABELS],
        edges=[QueryEdge(0, 1, "e_ab"), QueryEdge(1, 2, "e_bc"),
               QueryEdge(1, 3, "e_bd")])
    q6 = Query(name="Q6", nodes=[
        QueryNode(label="tmpl_A"), QueryNode(label="tmpl_B"),
        QueryNode(label="tmpl_D")],
        edges=[QueryEdge(0, 1, "e_ab"), QueryEdge(1, 2, "e_cd_missing")])
    return [DisjunctiveQuery([q4], name="Q4"),
            DisjunctiveQuery([q5], name="Q5"),
            DisjunctiveQuery([q6], name="Q6")]
