"""granite-3-2b [dense] — 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155, tied embeddings.  [hf:ibm-granite/granite-3.0-2b-base]"""
from ..models.config import FAMILY_DENSE, ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-3-2b",
    family=FAMILY_DENSE,
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49155,
    tie_embeddings=True,
    rope_theta=10_000.0,
)
