"""xlstm-125m [ssm] — 12L d_model=768 4H vocab=50304; sLSTM + mLSTM blocks
(pattern m,m,m,s — one sLSTM per four blocks), d_ff=0 (blocks are
self-contained).  O(1) state -> runs the long_500k cell.
[arXiv:2405.04517]"""
from ..models.config import (BLOCK_MLSTM, BLOCK_SLSTM, FAMILY_SSM,
                             ModelConfig)

CONFIG = ModelConfig(
    arch_id="xlstm-125m",
    family=FAMILY_SSM,
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    tie_embeddings=True,
    block_pattern=(BLOCK_MLSTM, BLOCK_MLSTM, BLOCK_MLSTM, BLOCK_SLSTM),
)
