"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000; RG-LRU + local attention 1:2 (pattern r,r,local), window 2048.
O(1)/O(window) state -> runs the long_500k cell.  [arXiv:2402.19427]"""
from ..models.config import (BLOCK_LOCAL_ATTN, BLOCK_RECURRENT,
                             FAMILY_HYBRID, ModelConfig)

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family=FAMILY_HYBRID,
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    block_pattern=(BLOCK_RECURRENT, BLOCK_RECURRENT, BLOCK_LOCAL_ATTN),
    local_window=2048,
    lru_width=4096,
    rope_theta=10_000.0,
)
