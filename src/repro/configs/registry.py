"""Architecture registry: the ten assigned configs, the four input shapes,
reduced smoke-test variants, and ShapeDtypeStruct input specs for the
dry-run (no allocation).

Each ``<arch>.py`` module in this package defines ``CONFIG``; this registry
imports them all and owns the shape logic shared by launch/dryrun.py,
benchmarks/roofline.py and the smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import FAMILY_AUDIO, FAMILY_VLM, ModelConfig

_ARCH_IDS = [
    "qwen1_5_110b",
    "qwen2_1_5b",
    "qwen3_4b",
    "granite_3_2b",
    "deepseek_moe_16b",
    "granite_moe_1b_a400m",
    "musicgen_medium",
    "llava_next_mistral_7b",
    "xlstm_125m",
    "recurrentgemma_9b",
]

# canonical dashed ids (CLI --arch) -> module names
ALIASES = {i.replace("_", "-"): i for i in _ARCH_IDS}


def _load() -> Dict[str, ModelConfig]:
    out = {}
    for mid in _ARCH_IDS:
        mod = importlib.import_module(f"repro.configs.{mid}")
        out[mid] = mod.CONFIG
    return out


ARCHS: Dict[str, ModelConfig] = _load()


def get_config(arch: str) -> ModelConfig:
    """Accepts module ids (qwen2_1_5b) and canonical ids (qwen2-1.5b)."""
    key = arch.replace("-", "_").replace(".", "_")
    return ARCHS[key]


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention state (DESIGN.md §long-context)."""
    if shape.name == "long_500k" and not cfg.attention_free:
        return False, ("full-attention arch: a 500k dense KV cache is the "
                       "architecture's own limit; skipped per assignment")
    return True, ""


def applicable_cells() -> List[Tuple[str, str]]:
    cells = []
    for aid, cfg in ARCHS.items():
        for sname, sh in SHAPES.items():
            ok, _ = shape_applicable(cfg, sh)
            if ok:
                cells.append((aid, sname))
    return cells


# ---------------------------------------------------------------------------
# Reduced (smoke-test) configs — same family, tiny geometry
# ---------------------------------------------------------------------------

def reduced(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving reduction for CPU smoke tests: keep the block
    pattern / MoE structure / frontends, shrink everything else."""
    period = max(1, len(cfg.block_pattern))
    n_layers = cfg.first_dense_layers + 2 * period + (1 if period > 1 else 0)
    H = min(cfg.n_heads, 4)
    Hkv = max(1, min(cfg.n_kv_heads, H))
    while H % Hkv:
        Hkv -= 1
    d = 64
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=d,
        n_heads=H,
        n_kv_heads=Hkv,
        head_dim=(d // H) if cfg.head_dim is None else 32,
        d_ff=128 if cfg.d_ff else 0,
        vocab=128,
        n_experts=min(cfg.n_experts, 4),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        expert_d_ff=32 if cfg.expert_d_ff else 0,
        dense_d_ff=96 if cfg.dense_d_ff else 0,
        # capacity >= E/top_k guarantees no token drops, so smoke tests can
        # compare train/prefill/decode paths exactly (full configs keep 1.25)
        capacity_factor=max(cfg.capacity_factor,
                            (min(cfg.n_experts, 4) / max(1, min(cfg.top_k, 2))) + 0.5)
        if cfg.n_experts else cfg.capacity_factor,
        local_window=32,
        lru_width=64 if cfg.lru_width else 0,
        frontend_tokens=min(cfg.frontend_tokens, 8),
        d_frontend=32 if cfg.family in (FAMILY_AUDIO, FAMILY_VLM) else 0,
        param_dtype="float32",
        compute_dtype="float32",
    )


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs; the dry-run never allocates)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model-input stand-ins for one (arch, shape) cell.

    train   : full batch with labels (+frontend stubs)
    prefill : batch without labels
    decode  : one new token (+``pos``); caches are built separately
    """
    B, S = shape.batch, shape.seq
    f32, i32 = jnp.float32, jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == FAMILY_AUDIO:
            batch = {"frame_embeds": _sds((B, S, cfg.frontend_dim()), f32)}
        else:
            batch = {"tokens": _sds((B, S), i32)}
            if cfg.family == FAMILY_VLM and cfg.frontend_tokens:
                F = min(cfg.frontend_tokens, S // 2)
                batch["image_embeds"] = _sds((B, F, cfg.frontend_dim()), f32)
        if shape.kind == "train":
            batch["labels"] = _sds((B, S), i32)
        return batch
    # decode: one token against a seq-S cache at position pos
    if cfg.family == FAMILY_AUDIO:
        inp = {"frame_embeds": _sds((B, cfg.frontend_dim()), f32)}
    else:
        inp = {"token": _sds((B,), i32)}
    return inp


def concrete_batch(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0):
    """Real (small!) arrays matching input_specs — smoke tests only."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, s in input_specs(cfg, shape).items():
        if s.dtype == jnp.int32:
            out[k] = jnp.asarray(
                rng.integers(0, max(2, cfg.vocab - 1), size=s.shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(size=s.shape).astype(np.float32))
    return out
