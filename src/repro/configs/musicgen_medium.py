"""musicgen-medium [audio] — 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048; decoder-only over EnCodec tokens.  The EnCodec frontend is a
STUB: input_specs() provides precomputed frame embeddings (d_frontend=128,
the EnCodec latent width); the in-model projection + backbone are real.
[arXiv:2306.05284]"""
from ..models.config import FAMILY_AUDIO, ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-medium",
    family=FAMILY_AUDIO,
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,              # EnCodec codebook size
    d_frontend=128,
    rope_theta=10_000.0,
)
