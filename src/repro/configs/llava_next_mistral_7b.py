"""llava-next-mistral-7b [vlm] — mistral-7B backbone: 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000.  The anyres tiling vision tower is a
STUB: input_specs() provides precomputed CLIP patch embeddings
(d_frontend=1024, up to 2880 anyres tokens); the 2-layer projector and the
backbone are real.  [hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from ..models.config import FAMILY_VLM, ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-mistral-7b",
    family=FAMILY_VLM,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    frontend_tokens=2880,    # anyres: 5 tiles x 576 patches
    d_frontend=1024,
    rope_theta=1_000_000.0,
)
