"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8), 32 routed
experts (d_ff=512) top-8, vocab=49155, tied embeddings.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from ..models.config import FAMILY_MOE, ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-1b-a400m",
    family=FAMILY_MOE,
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    n_experts=32,
    n_shared_experts=0,
    top_k=8,
    expert_d_ff=512,
    tie_embeddings=True,
    rope_theta=10_000.0,
)
