"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936, qk_norm, explicit head_dim=128.  [hf:Qwen/Qwen3-4B]"""
from ..models.config import FAMILY_DENSE, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-4b",
    family=FAMILY_DENSE,
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
)
