"""qwen2-1.5b [dense] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, GQA + QKV bias, tied embeddings.  [arXiv:2407.10671]"""
from ..models.config import FAMILY_DENSE, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-1.5b",
    family=FAMILY_DENSE,
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)
