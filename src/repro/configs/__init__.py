from .registry import (ARCHS, SHAPES, ShapeSpec, get_config, reduced,
                        input_specs, shape_applicable, applicable_cells)

__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "get_config", "reduced",
           "input_specs", "shape_applicable", "applicable_cells"]
