"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (MHA kv=16) vocab=102400,
fine-grained MoE: 64 routed experts (d_ff=1408 each) top-6 + 2 shared
experts; layer 0 is a dense FFN (d_ff=10944).  [arXiv:2401.06066]"""
from ..models.config import FAMILY_MOE, ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-moe-16b",
    family=FAMILY_MOE,
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,               # routed-expert width (assignment table value)
    vocab=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    expert_d_ff=1408,
    first_dense_layers=1,
    dense_d_ff=10944,        # hf intermediate_size for the dense first layer
    rope_theta=10_000.0,
)
