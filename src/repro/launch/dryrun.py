import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
record memory/cost/collective analysis (EXPERIMENTS.md §Dry-run, §Roofline).

MUST be run as its own process (the XLA_FLAGS line above has to execute
before jax initializes devices — do not import this module from a live jax
process):

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out results/dryrun

One JSON per cell is written to --out; existing files are skipped (the
driver is resumable, so a killed run restarts where it left off).
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCHS, SHAPES, get_config, input_specs,
                           shape_applicable)
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (ShardingRules, act_constraint,
                                   batch_shardings, cache_shardings,
                                   logit_constraint, opt_shardings,
                                   param_shardings)
from repro.models.config import ModelConfig
from repro.models.transformer import abstract_params
from repro.serving.decode import abstract_caches, decode_step, prefill
from repro.train.optimizer import abstract_opt_state
from repro.train.step import TrainConfig, make_train_step


def model_flops(cfg: ModelConfig, kind: str, batch: int, seq: int) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode), N = active params."""
    n = cfg.n_active_params()
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch


def build_cell(cfg: ModelConfig, shape, mesh, tcfg: TrainConfig,
               *, embed_vocab_shard: bool = True, moe_tp: bool = False):
    """Returns (jitted_fn, abstract_args tuple)."""
    rules = ShardingRules(mesh)
    p_abs = abstract_params(cfg)
    p_sh = param_shardings(cfg, mesh, embed_vocab_shard=embed_vocab_shard)
    batch_abs = input_specs(cfg, shape)
    b_sh = batch_shardings(mesh, batch_abs)
    act = act_constraint(mesh, shape.batch, tp_act=tcfg.tp_act)
    lshard = logit_constraint(mesh, shape.batch, cfg.vocab)
    moe_fn = None
    if moe_tp and cfg.is_moe:
        from repro.launch.sharding import _batch_dim_spec
        from repro.models.layers import make_tp_moe_fn
        moe_fn = make_tp_moe_fn(mesh, _batch_dim_spec(mesh, shape.batch), cfg)

    if shape.kind == "train":
        o_abs = abstract_opt_state(p_abs)
        o_sh = opt_shardings(cfg, mesh, embed_vocab_shard=embed_vocab_shard)
        step = make_train_step(cfg, tcfg, act_shard=act, logit_shard=lshard,
                               moe_fn=moe_fn)
        fn = jax.jit(step,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
        return fn, (p_abs, o_abs, batch_abs)

    if shape.kind == "prefill":
        c_sh = cache_shardings(cfg, mesh, shape.batch, shape.seq)
        logits_sh = rules.named(rules.resolve(
            (shape.batch, cfg.vocab), (None, "vocab")))
        def wrapped(params, batch):
            return prefill(params, cfg, batch, q_chunk=tcfg.q_chunk,
                           act_shard=act, moe_fn=moe_fn)
        fn = jax.jit(wrapped, in_shardings=(p_sh, b_sh),
                     out_shardings=(logits_sh, c_sh))
        return fn, (p_abs, batch_abs)

    # decode: one new token against a seq-S cache
    c_abs = abstract_caches(cfg, shape.batch, shape.seq)
    c_sh = cache_shardings(cfg, mesh, shape.batch, shape.seq)
    logits_sh = rules.named(rules.resolve(
        (shape.batch, cfg.vocab), (None, "vocab")))

    def wrapped(params, caches, inputs, pos):
        return decode_step(params, cfg, caches, inputs, pos)

    fn = jax.jit(wrapped,
                 in_shardings=(p_sh, c_sh, b_sh, None),
                 out_shardings=(logits_sh, c_sh),
                 donate_argnums=(1,))
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    return fn, (p_abs, c_abs, batch_abs, pos_abs)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             tcfg: Optional[TrainConfig] = None,
             hlo_path: Optional[str] = None,
             mlstm_chunk: int = 0,
             embed_vocab_shard: bool = True,
             moe_tp: bool = False) -> Dict:
    import dataclasses as _dc
    cfg = get_config(arch)
    if mlstm_chunk:
        cfg = _dc.replace(cfg, mlstm_chunk=mlstm_chunk)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "kind": shape.kind, "batch": shape.batch, "seq": shape.seq}
    if not ok:
        rec["status"] = "skipped"
        rec["skip_reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    tcfg = tcfg or TrainConfig()
    t0 = time.time()
    with mesh:
        fn, args = build_cell(cfg, shape, mesh, tcfg,
                              embed_vocab_shard=embed_vocab_shard,
                              moe_tp=moe_tp)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    if hlo_path:
        import gzip
        try:
            with gzip.open(hlo_path, "wt") as f:
                f.write(compiled.as_text())
        except Exception as e:
            rec["hlo_save_error"] = repr(e)
    info = hlo_analysis.analyze_compiled(compiled, lowered)
    terms = hlo_analysis.roofline_from_info(info)
    mf = model_flops(cfg, shape.kind, shape.batch, shape.seq)
    hlo_total = terms.device_flops * n_chips
    rec.update({
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "info": info,
        "roofline": terms.as_dict(),
        "model_flops_total": mf,
        "hlo_flops_total": hlo_total,
        "useful_flops_ratio": (mf / hlo_total) if hlo_total else None,
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
    })
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="baseline",
                    help="experiment tag appended to output filenames")
    ap.add_argument("--causal-skip", action="store_true",
                    help="enable the causal-skip flash attention variant")
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--kv-chunk", type=int, default=512)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--tp-act", action="store_true",
                    help="shard [B,S,d] activations over the model axis")
    ap.add_argument("--mlstm-chunk", type=int, default=0,
                    help="chunkwise-parallel mLSTM chunk size (§Perf-A)")
    ap.add_argument("--embed-replicated", action="store_true",
                    help="vocab-replicated embedding table (§Perf-C)")
    ap.add_argument("--moe-tp", action="store_true",
                    help="expert-parallel MoE dispatch over model (§Perf-B)")
    ap.add_argument("--attn-remat", action="store_true",
                    help="recompute attention tiles in backward (§Perf-C4)")
    ap.add_argument("--flash-cv", action="store_true",
                    help="custom-VJP flash attention (§Perf-C8)")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [
        a.replace("-", "_") for a in args.arch.split(",")]
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    tcfg = TrainConfig(remat=not args.no_remat, causal_skip=args.causal_skip,
                       q_chunk=args.q_chunk, kv_chunk=args.kv_chunk,
                       tp_act=args.tp_act, attn_remat=args.attn_remat,
                       flash_cv=args.flash_cv)

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tagm = "multi" if mp else "single"
                path = os.path.join(
                    args.out, f"{arch}__{shape}__{tagm}__{args.tag}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip existing] {path}")
                    continue
                print(f"[cell] {arch} x {shape} x {tagm} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp, tcfg,
                                   hlo_path=path.replace(".json", ".hlo.gz"),
                                   mlstm_chunk=args.mlstm_chunk,
                                   embed_vocab_shard=not args.embed_replicated,
                                   moe_tp=args.moe_tp)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": tagm,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2, default=str)
                status = rec.get("status")
                if status == "ok":
                    r = rec["roofline"]
                    print(f"  ok: dominant={r['dominant']} "
                          f"t_comp={r['t_compute_s']:.4f}s "
                          f"t_mem={r['t_memory_s']:.4f}s "
                          f"t_coll={r['t_collective_s']:.4f}s "
                          f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)",
                          flush=True)
                elif status == "skipped":
                    print(f"  skipped: {rec['skip_reason']}")
                else:
                    print(f"  ERROR: {rec.get('error')}")


if __name__ == "__main__":
    main()
