"""Static cost analyzer over optimized (partitioned) HLO text.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits every
instruction ONCE — ``while`` bodies (= every ``lax.scan``: our layer stacks,
recurrent cells, flash-attention chunk loops) are not multiplied by their
trip counts, undercounting FLOPs/bytes/collectives by orders of magnitude
for deep or recurrent models.  This analyzer parses the optimized HLO,
computes per-computation costs bottom-up, and multiplies while-body costs by
the trip count recovered from the loop condition's compare-against-constant.

Three cost streams, all PER DEVICE (partitioned shapes are shard shapes):

flops      dot = 2*numel(result)*K (K = product of lhs contracting dims,
           operand shapes resolved through a per-computation symbol table);
           elementwise = numel(result); reduce = numel(operand).

bytes_min  the roofline memory term: MINIMUM HBM traffic under perfect
           operator fusion/tiling on the TPU target.  Data is charged only
           when it must cross HBM:
             * operands whose ORIGIN is off-chip — parameters, constants,
               loop carries (get-tuple-element), anything passing through a
               view op from those — are charged at each consumer;
             * each computation ROOT is charged as a write (while-body
               roots = the carry write per iteration), EXCEPT tuple
               elements passed through unchanged (loop invariants, e.g.
               scanned weight stacks, are buffer-aliased by XLA);
             * dynamic-update-slice charges only the update (in-place);
               gather/dynamic-slice charge the result (the rows actually
               read); copies charge operand+result; collectives charge
               wire traffic.
           Everything produced AND consumed on-chip (e.g. the flash-
           attention probability tile between its two dots) is free — a
           perfectly-fused kernel keeps it in VMEM.

bytes_xla  the XLA HloCostAnalysis convention (operands+results of every
           op, fusion-internal ops free) — pessimistic on CPU where fusion
           is conservative; kept as a diagnostic upper band.

collectives: ring accounting — all-gather: result; all-reduce: 2x operand;
reduce-scatter / all-to-all / collective-permute: operand.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(
    r"\b(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128|token)"
    r"\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"\s([a-z][a-z0-9\-]*)\(")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
# new-style HLO (jit .lower().as_text(dialect="hlo")) prints operands
# without the % sigil: "dot(Arg_0.1, Arg_0.1)"; the bare form is the
# last identifier-like token of each comma-separated piece (shapes may
# precede it in long-form dumps)
_BARE_OPERAND_RE = re.compile(r"([\w.\-]+)\s*$")


def _operand_names(operand_str: str) -> List[str]:
    names = _OPERAND_RE.findall(operand_str)
    if names or not operand_str.strip():
        return names
    out: List[str] = []
    for piece in operand_str.split(","):
        m = _BARE_OPERAND_RE.search(piece.strip())
        if m:
            out.append(m.group(1))
    return out

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "cosine", "sine",
    "logistic", "atan2", "remainder", "compare", "select", "and", "or",
    "xor", "not", "clamp", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "popcnt", "clz", "erf", "tan",
}
_ZERO_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
# view-ish ops: propagate data origin, charge nothing themselves
_VIEW_OPS = {"bitcast", "reshape", "broadcast", "convert", "transpose",
             "get-tuple-element", "tuple"}
_OFFCHIP_OPS = {"parameter", "constant", "rng-bit-generator", "infeed"}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _numel(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _shapes_bytes(shapes: List[Tuple[str, str]]) -> float:
    return float(sum(_numel(d) * _DTYPE_BYTES.get(t, 4) for t, d in shapes))


def _shapes_numel(shapes: List[Tuple[str, str]]) -> int:
    return sum(_numel(d) for _, d in shapes)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    bytes_min: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_ops: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.bytes_min += mult * other.bytes_min
        for k in _COLLECTIVES:
            self.coll[k] += mult * other.coll[k]
        self.coll_ops += mult * other.coll_ops

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


@dataclasses.dataclass
class _Instr:
    name: str
    opcode: str
    result_shapes: List[Tuple[str, str]]
    operand_names: List[str]
    line: str
    is_root: bool = False


@dataclasses.dataclass
class _Comp:
    name: str
    instrs: List[_Instr]
    symbols: Dict[str, "_Instr"]


def _parse(text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    entry: Optional[str] = None
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        if cur is None:
            h = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$", line)
            if not (h and "->" in line):
                # new-style dumps open computations without the
                # "(params) -> result" signature: "ENTRY main.24 {"
                h = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\{\s*$", line)
            if h:
                cur = _Comp(h.group(2), [], {})
                comps[cur.name] = cur
                if h.group(1):
                    entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        is_root, name, rhs = bool(m.group(1)), m.group(2), m.group(3)
        om = _OPCODE_RE.search(" " + rhs)
        if not om:
            continue
        opcode = om.group(1)
        head = rhs[: rhs.find(opcode + "(")]
        after = rhs[rhs.find(opcode + "(") + len(opcode) + 1:]
        operand_str = after[: after.find(")")] if ")" in after else after
        ins = _Instr(name=name, opcode=opcode,
                     result_shapes=_SHAPE_RE.findall(head),
                     operand_names=_operand_names(operand_str),
                     line=rhs, is_root=is_root)
        cur.instrs.append(ins)
        cur.symbols[name] = ins
    return comps, entry


def _trip_count(cond: _Comp) -> int:
    best = 1
    for ins in cond.instrs:
        for c in _CONST_RE.findall(ins.line):
            best = max(best, int(c))
    return best


class HloCostModel:
    VMEM_NOTE = "bytes_min assumes perfect fusion/tiling (see module doc)"

    def __init__(self, text: str):
        self.comps, self.entry = _parse(text)
        self._memo: Dict[str, Cost] = {}
        self._origin_memo: Dict[Tuple[str, str], bool] = {}
        self.warnings: List[str] = []
        self.contributors: List[Tuple[float, str, str]] = []

    # ------------------------------------------------------------------
    def cost(self, comp: Optional[str] = None) -> Cost:
        name = comp or self.entry
        if name is None:
            self.warnings.append("no ENTRY computation found")
            total = Cost()
            for n in self.comps:
                total.add(self._comp_cost(n, 1.0))
            return total
        return self._comp_cost(name, 1.0)

    def _comp_cost(self, name: str, mult: float) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()
        comp = self.comps.get(name)
        total = Cost()
        if comp is not None:
            for ins in comp.instrs:
                total.add(self._instr_cost(comp, ins, mult))
        self._memo[name] = total
        return total

    # -- data origin -----------------------------------------------------
    def _offchip(self, comp: _Comp, name: str, depth: int = 0) -> bool:
        key = (comp.name, name)
        if key in self._origin_memo:
            return self._origin_memo[key]
        self._origin_memo[key] = False  # cycle guard
        ins = comp.symbols.get(name)
        if ins is None or depth > 64:
            out = True   # unknown name: be conservative (charge it)
        elif ins.opcode in _OFFCHIP_OPS or ins.opcode == "get-tuple-element":
            out = True
        elif ins.opcode in _VIEW_OPS:
            out = any(self._offchip(comp, o, depth + 1)
                      for o in ins.operand_names[:1]) if ins.operand_names \
                else False
        elif ins.opcode in ("copy", "copy-start", "copy-done"):
            out = True   # copies materialize
        else:
            out = False
        self._origin_memo[key] = out
        return out

    def _op_shapes(self, comp: _Comp, ins: _Instr) -> List[List[Tuple[str, str]]]:
        out = []
        for nm in ins.operand_names:
            src = comp.symbols.get(nm)
            out.append(src.result_shapes if src else [])
        if not out:   # old printing: shapes inline in the operand list
            after = ins.line[ins.line.find(ins.opcode + "(") + len(ins.opcode) + 1:]
            inline = _SHAPE_RE.findall(after[: after.find(")")])
            out = [[s] for s in inline]
        return out

    def _operand_bytes_offchip(self, comp: _Comp, ins: _Instr) -> float:
        total = 0.0
        for nm, shapes in zip(ins.operand_names, self._op_shapes(comp, ins)):
            if self._offchip(comp, nm):
                total += _shapes_bytes(shapes)
        return total

    # -- per instruction ---------------------------------------------------
    def _instr_cost(self, comp: _Comp, ins: _Instr, mult: float) -> Cost:
        c = Cost()
        op = ins.opcode
        out_elems = _shapes_numel(ins.result_shapes)
        res_bytes = _shapes_bytes(ins.result_shapes)
        op_shapes = self._op_shapes(comp, ins)
        all_op_bytes = sum(_shapes_bytes(s) for s in op_shapes)

        if op == "while":
            body = _BODY_RE.search(ins.line)
            cond = _COND_RE.search(ins.line)
            trips = 1
            if cond and cond.group(1) in self.comps:
                trips = _trip_count(self.comps[cond.group(1)])
                c.add(self._comp_cost(cond.group(1), mult * trips), trips)
            if body and body.group(1) in self.comps:
                c.add(self._comp_cost(body.group(1), mult * trips), trips)
            return c

        if op == "fusion":
            m = _CALLS_RE.search(ins.line)
            if m and m.group(1) in self.comps:
                inner = self._comp_cost(m.group(1), mult)
                c.flops += inner.flops
                c.bytes_min += inner.bytes_min
                for k in _COLLECTIVES:
                    c.coll[k] += inner.coll[k]
                c.coll_ops += inner.coll_ops
            c.bytes += res_bytes + all_op_bytes
            # fusion boundary traffic under the min model: off-chip operands
            c.bytes_min += self._operand_bytes_offchip(comp, ins)
            if ins.is_root:
                c.bytes_min += res_bytes
            self._note(c.bytes_min * mult, ins)
            return c

        if op in ("call", "custom-call", "async-start"):
            m = _CALLS_RE.search(ins.line) or _TOAPPLY_RE.search(ins.line)
            if m and m.group(1) in self.comps:
                c.add(self._comp_cost(m.group(1), mult))
            c.bytes += res_bytes + all_op_bytes
            if op == "custom-call":
                c.bytes_min += res_bytes + all_op_bytes
            self._note(c.bytes_min * mult, ins)
            return c

        if op == "conditional":
            m = re.search(r"branch_computations=\{([^}]*)\}", ins.line)
            names = re.findall(r"%?([\w.\-]+)", m.group(1)) if m else []
            for branch in names:
                if branch in self.comps:
                    c.add(self._comp_cost(branch, mult))
            c.bytes += res_bytes + all_op_bytes
            return c

        is_coll = None
        for k in _COLLECTIVES:
            if op == k or op == k + "-start":
                is_coll = k
                break
        if is_coll:
            opn = all_op_bytes or res_bytes
            # CPU-backend artifact: XLA float-normalization promotes bf16
            # collectives to f32 (operand arrives via a convert).  TPU runs
            # them natively in bf16, so charge at the pre-convert width.
            scale = 1.0
            for nm in ins.operand_names:
                src = comp.symbols.get(nm)
                if src is not None and ("convert" in src.opcode
                                        or "convert" in src.name):
                    scale = 0.5
                    break
            if is_coll == "all-gather":
                c.coll[is_coll] += res_bytes * scale
            elif is_coll == "all-reduce":
                c.coll[is_coll] += 2 * opn * scale
            else:
                c.coll[is_coll] += opn * scale
            c.coll_ops += 1
            c.bytes += res_bytes + opn
            c.bytes_min += (res_bytes + opn) * scale
            self._note(c.bytes_min * mult, ins)
            return c

        if op.endswith("-done") or op.endswith("-update") or op in _ZERO_OPS:
            # ROOT tuple of a while body = the carry write; charge only
            # elements that changed (pass-through gte = loop invariant)
            if op == "tuple" and ins.is_root:
                for nm, shapes in zip(ins.operand_names, op_shapes):
                    src = comp.symbols.get(nm)
                    if src is not None and src.opcode in (
                            "get-tuple-element", "parameter",
                            # in-place / already charged at the producer:
                            "dynamic-update-slice", "copy", "bitcast"):
                        continue
                    c.bytes_min += _shapes_bytes(shapes)
                self._note(c.bytes_min * mult, ins)
            return c

        # ---- flops ----
        if op == "dot":
            k = 1
            m = _LHS_CDIMS_RE.search(ins.line)
            lhs = op_shapes[0] if op_shapes else []
            if m and lhs:
                dims = lhs[0][1]
                sizes = [int(x) for x in dims.split(",")] if dims else []
                for idx in (int(x) for x in m.group(1).split(",") if x):
                    if idx < len(sizes):
                        k *= sizes[idx]
            c.flops += 2.0 * out_elems * k
        elif op == "convolution":
            kern = _shapes_numel(op_shapes[1]) if len(op_shapes) > 1 else 1
            c.flops += 2.0 * out_elems * kern
        elif op in _ELEMENTWISE:
            c.flops += out_elems
        elif op in ("reduce", "reduce-window"):
            c.flops += _shapes_numel(op_shapes[0]) if op_shapes else out_elems

        # ---- bytes (XLA convention) ----
        c.bytes += res_bytes + all_op_bytes

        # ---- bytes_min (perfect-fusion floor) ----
        if op == "dynamic-update-slice":
            # in-place update: charge the update slice only
            if len(op_shapes) > 1:
                c.bytes_min += _shapes_bytes(op_shapes[1])
        elif op in ("gather", "dynamic-slice", "slice"):
            c.bytes_min += res_bytes          # the rows actually read
        elif op in ("copy", "copy-start"):
            c.bytes_min += res_bytes + all_op_bytes
        elif op in ("scatter",):
            upd = _shapes_bytes(op_shapes[2]) if len(op_shapes) > 2 else res_bytes
            c.bytes_min += upd
        else:
            c.bytes_min += self._operand_bytes_offchip(comp, ins)
        if ins.is_root and op != "tuple":
            c.bytes_min += res_bytes          # escapes the computation
        self._note(c.bytes_min * mult, ins)
        return c

    def _note(self, weighted_bytes: float, ins: _Instr) -> None:
        if weighted_bytes > 0:
            self.contributors.append((weighted_bytes, ins.opcode,
                                      ins.line[:160]))

    def top_contributors(self, k: int = 20) -> List[Tuple[float, str, str]]:
        return sorted(self.contributors, reverse=True)[:k]


def analyze_hlo_text(text: str) -> Dict[str, object]:
    model = HloCostModel(text)
    c = model.cost()
    out: Dict[str, object] = {
        "flops": c.flops,
        "bytes": c.bytes_min,            # roofline memory term
        "bytes_xla_convention": c.bytes,  # diagnostic upper band
        "collective_bytes": dict(c.coll),
        "collective_bytes_total": c.coll_total,
        "collective_op_executions": c.coll_ops,
    }
    if model.warnings:
        out["warnings"] = model.warnings
    return out


def top_contributors(text: str, k: int = 20) -> List[Tuple[float, str, str]]:
    model = HloCostModel(text)
    model.cost()
    return model.top_contributors(k)
