"""End-to-end LM training driver (substrate demo + fault-tolerance harness).

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Features exercised: sharded train_step (pjit), AdamW, checkpoint/restart
(kill it mid-run and relaunch — it resumes from the last committed step with
bitwise-identical data order), straggler watchdog, loss logging.

On CPU this runs REDUCED configs (--smoke) or small customs; on a TPU fleet
the same driver takes --production for make_production_mesh().
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, reduced
from repro.data.tokens import TokenPipeline, frontend_batch
from repro.distributed import CheckpointManager, StepWatchdog
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.sharding import act_constraint, logit_constraint, opt_shardings, param_shardings
from repro.models.config import FAMILY_AUDIO
from repro.models.transformer import init_params
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.step import TrainConfig, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced (CPU-sized) config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--production", action="store_true",
                    help="use the production (16,16) mesh (TPU fleet)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    mesh = (make_production_mesh() if args.production
            else make_test_mesh((jax.device_count(), 1)))

    tcfg = TrainConfig(opt=OptConfig(lr=args.lr, total_steps=args.steps),
                       remat=True)
    act = act_constraint(mesh, args.batch)
    lsh = logit_constraint(mesh, args.batch, cfg.vocab)
    step_fn = make_train_step(cfg, tcfg, act_shard=act, logit_shard=lsh)

    p_sh = param_shardings(cfg, mesh)
    o_sh = opt_shardings(cfg, mesh)
    with mesh:
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        params = jax.device_put(params, p_sh)
        opt = jax.device_put(init_opt_state(params), o_sh)
        jit_step = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None),
                           out_shardings=(p_sh, o_sh, None),
                           donate_argnums=(0, 1))

        pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                             seed=args.seed)
        start = 0
        mgr = None
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
            restored = mgr.restore_or_none({"params": params, "opt": opt},
                                           shardings={"params": p_sh, "opt": o_sh})
            if restored is not None:
                start, state, meta = restored
                params, opt = state["params"], state["opt"]
                pipe.load_state_dict(meta)
                print(f"[train] resumed from step {start}")

        wd = StepWatchdog()
        extra = frontend_batch(cfg, args.batch, args.seq, seed=args.seed)
        for step in range(start, args.steps):
            batch = dict(pipe.batch_at(step))
            batch.update(extra)
            if cfg.family == FAMILY_AUDIO:
                batch.pop("tokens", None)
            wd.start()
            params, opt, metrics = jit_step(params, opt, batch)
            loss = float(metrics["loss"])   # blocks; doubles as step barrier
            dt = wd.stop()
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step:5d} loss {loss:8.4f} "
                      f"nll {float(metrics['nll']):8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"{dt*1000:7.1f} ms"
                      + (" [STRAGGLER]" if wd.is_straggler(dt) else ""),
                      flush=True)
            if mgr is not None:
                mgr.maybe_save(step + 1, {"params": params, "opt": opt},
                               extra_meta=pipe.state_dict())
        if mgr is not None:
            save_path = mgr.maybe_save(args.steps, {"params": params, "opt": opt},
                                       extra_meta=pipe.state_dict())
        print(f"[train] done. final loss {loss:.4f}; "
              f"median step {wd.median*1000:.1f} ms; "
              f"straggler steps {wd.slow_steps}")


if __name__ == "__main__":
    main()
