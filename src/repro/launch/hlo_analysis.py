"""Roofline-term extraction from compiled dry-run artifacts.

``compiled.cost_analysis()`` supplies per-device HLO FLOPs and bytes.
Collective traffic is NOT in cost_analysis, so we parse the partitioned
HLO text and sum per-device wire bytes for every collective op, with ring
accounting:

  all-gather         : result bytes            (each device receives ~R)
  reduce-scatter     : operand bytes           (each device sends ~I)
  all-reduce         : 2 x operand bytes       (ring RS + AG)
  all-to-all         : operand bytes
  collective-permute : operand bytes

Shapes in the partitioned module are already per-shard, so sums are
per-device.  Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s ICI per chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per chip (link-level)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|[sufc]\d+|bf16)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes per collective kind, from partitioned HLO."""
    out = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.lstrip()
        # "%name = TYPE op-name(OPERANDS...)" — find which collective op
        kind = None
        for k in _COLLECTIVES:
            # match ` op-name(` or `op-name-start(` after the "=" result type
            if f" {k}(" in stripped or f" {k}-start(" in stripped:
                kind = k
                break
        if kind is None:
            continue
        shapes = _SHAPE_RE.findall(stripped)
        if not shapes:
            continue
        # first shape token = result; remaining (inside parens) = operands.
        result = _shape_bytes(*shapes[0])
        operands = sum(_shape_bytes(d, s) for d, s in shapes[1:]) or result
        if kind == "all-gather":
            out[kind] += result
        elif kind == "all-reduce":
            out[kind] += 2 * operands
        else:
            out[kind] += operands
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def count_collective_ops(hlo_text: str) -> Dict[str, int]:
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        for k in _COLLECTIVES:
            if f" {k}(" in s or f" {k}-start(" in s:
                counts[k] += 1
                break
    return counts


@dataclasses.dataclass
class RooflineTerms:
    device_flops: float
    device_bytes: float
    device_coll_bytes: float

    @property
    def t_compute(self) -> float:
        return self.device_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.device_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.device_coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline lower bound on step time (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> Dict[str, float]:
        return {
            "device_flops": self.device_flops,
            "device_bytes": self.device_bytes,
            "device_coll_bytes": self.device_coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "t_bound_s": self.t_bound,
            "dominant": self.dominant,
        }


def analyze_compiled(compiled, lowered=None) -> Dict[str, object]:
    """Pull cost/memory/collective numbers out of a compiled executable.

    FLOPs/bytes/collective bytes come from the static HLO cost model
    (launch/hlo_cost.py) which multiplies while bodies by trip counts;
    ``compiled.cost_analysis()`` is recorded alongside for reference (it
    counts loop bodies once and therefore undercounts scanned stacks).
    """
    from . import hlo_cost
    info: Dict[str, object] = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        info["xla_cost_analysis_flops"] = float(ca.get("flops", 0.0))
        info["xla_cost_analysis_bytes"] = float(ca.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        info["cost_analysis_error"] = repr(e)
    try:
        ma = compiled.memory_analysis()
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
            v = getattr(ma, field, None)
            if v is not None:
                info[field] = int(v)
    except Exception as e:  # pragma: no cover
        info["memory_analysis_error"] = repr(e)
    try:
        text = compiled.as_text()
    except Exception:
        text = lowered.as_text() if lowered is not None else ""
    model = hlo_cost.analyze_hlo_text(text)
    info["flops"] = model["flops"]
    info["bytes_accessed"] = model["bytes"]   # perfect-fusion floor
    info["bytes_xla_convention"] = model["bytes_xla_convention"]
    info["collective_bytes"] = dict(model["collective_bytes"])
    info["collective_bytes"]["total"] = model["collective_bytes_total"]
    info["collective_op_executions"] = model["collective_op_executions"]
    info["collective_ops"] = count_collective_ops(text)  # static op counts
    if "warnings" in model:
        info["hlo_cost_warnings"] = model["warnings"]
    return info


def roofline_from_info(info: Dict[str, object]) -> RooflineTerms:
    return RooflineTerms(
        device_flops=float(info.get("flops", 0.0)),
        device_bytes=float(info.get("bytes_accessed", 0.0)),
        device_coll_bytes=float(info["collective_bytes"]["total"]),
    )
