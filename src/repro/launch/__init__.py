from .mesh import make_production_mesh, dp_axes
from .sharding import (ShardingRules, param_shardings, opt_shardings,
                       batch_shardings, cache_shardings, act_constraint,
                       logit_constraint)

__all__ = ["make_production_mesh", "dp_axes", "ShardingRules",
           "param_shardings", "opt_shardings", "batch_shardings",
           "cache_shardings", "act_constraint", "logit_constraint"]
