"""Production meshes.

Single pod : (16, 16)    = ("data", "model")   — 256 chips (one v5e pod)
Multi-pod  : (2, 16, 16) = ("pod", "data", "model") — 512 chips

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init; smoke tests see
the real single CPU device).

Mesh-axis roles (DESIGN.md §6):
  pod   — pure data parallelism; params replicated per pod; the only
          cross-pod (DCN) collective is the gradient all-reduce
  data  — batch DP + FSDP (params/optimizer sharded ZeRO-3 style)
  model — tensor parallelism (heads / ff / vocab / experts / lru)
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    from repro.compat import make_mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — run via "
            f"launch/dryrun.py (which sets xla_force_host_platform_device_count)")
    return make_mesh(shape, axes, devices=devs[:n])


def make_test_mesh(shape: Tuple[int, ...] = (1, 1),
                   axes: Tuple[str, ...] = ("data", "model")):
    """A trivial mesh on however many devices exist (CPU tests)."""
    import jax
    from repro.compat import make_mesh
    n = int(np.prod(shape))
    return make_mesh(shape, axes, devices=jax.devices()[:n])


def dp_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes that carry the batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
