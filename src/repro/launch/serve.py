"""End-to-end query-serving driver — the paper's kind of workload.

Loads (or generates) a graph database, partitions it with a chosen scheme,
builds the catalog, and serves a batch of queries through one of the three
evaluation strategies (OPAT / TraditionalMP / MapReduceMP), reporting the
paper's metrics: partition-load sequences, load ratios vs L_ideal, answer
counts, and per-query latency.

    PYTHONPATH=src python -m repro.launch.serve --dataset imdb --k 4 \
        --scheme ecosocial --engine opat --heuristic max-sn

MapReduceMP needs one device per partition; run with
    XLA_FLAGS=--xla_force_host_platform_device_count=4
(this driver, unlike dryrun.py, leaves device count to the caller so the
other engines see the real machine).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import (EngineConfig, MAX_SN, MAX_YIELD, MIN_SN, RANDOM_SN,
                        OPATEngine, RunRequest, TraditionalMPEngine,
                        build_catalog, build_partitions, generate_plan,
                        match_query, partition_graph, partition_quality,
                        total_connected_components)
from repro.data.generators import (imdb_like_graph, imdb_queries,
                                   subgen_like_graph, subgen_queries)


def load_dataset(name: str, scale: float, seed: int):
    if name == "imdb":
        g = imdb_like_graph(n_movies=int(300 * scale),
                            n_people=int(400 * scale),
                            n_companies=max(4, int(40 * scale)), seed=seed)
        return g, imdb_queries(g, seed=seed)
    if name == "synthetic":
        g = subgen_like_graph(n_nodes=int(2000 * scale),
                              n_edges=int(6000 * scale),
                              n_embed=max(5, int(50 * scale)), seed=seed)
        return g, subgen_queries(g)
    raise ValueError(name)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="imdb", choices=["imdb", "synthetic"])
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--k", type=int, default=4, help="number of partitions")
    ap.add_argument("--scheme", default="kway_shem")
    ap.add_argument("--engine", default="opat",
                    choices=["opat", "traditional", "mapreduce"])
    ap.add_argument("--heuristic", default=MAX_SN,
                    choices=[MAX_SN, MIN_SN, RANDOM_SN, MAX_YIELD])
    ap.add_argument("--processors", type=int, default=2,
                    help="p for TraditionalMP")
    ap.add_argument("--max-answers", type=int, default=None,
                    help="answer budget K per disjunct: stop after K unique "
                         "answers (the paper's 'specified number of "
                         "answers'; default: all)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="check answers against the whole-graph oracle")
    ap.add_argument("--cap", type=int, default=16384)
    ap.add_argument("--json", default="", help="write a JSON report here")
    args = ap.parse_args()

    graph, dqueries = load_dataset(args.dataset, args.scale, args.seed)
    print(f"[serve] graph: {graph.n_nodes} nodes, {graph.n_edges} edges")

    t0 = time.time()
    assign = partition_graph(graph, args.k, args.scheme, seed=args.seed)
    pg = build_partitions(graph, assign, args.k)
    q = partition_quality(graph, assign, args.k)
    print(f"[serve] partitioned k={args.k} scheme={args.scheme} "
          f"cut={q['cut']} ({q['cut_frac']:.1%}) sizes={q['sizes']} "
          f"total_cc={total_connected_components(pg)} "
          f"[{time.time()-t0:.1f}s]")

    catalog = build_catalog(graph)
    ecfg = EngineConfig(cap=args.cap)

    if args.engine == "opat":
        engine = OPATEngine(pg, ecfg)
    elif args.engine == "traditional":
        engine = TraditionalMPEngine(pg, args.processors, ecfg)
    else:
        from repro.compat import make_part_mesh
        from repro.core.mapreduce_mp import MapReduceMPEngine
        mesh = make_part_mesh(args.k)
        engine = MapReduceMPEngine(pg, mesh, ecfg, heuristic=args.heuristic)

    # all three engines speak the QueryRunner protocol (core/runner.py)
    def run(plan):
        return engine.run_request(RunRequest(
            plan=plan, heuristic=args.heuristic,
            max_answers=args.max_answers, seed=args.seed))

    report = []
    for dq in dqueries:
        answers = None
        stats = []
        t0 = time.time()
        for disjunct in dq.disjuncts:
            plan = generate_plan(disjunct, graph, catalog)
            res = run(plan)
            stats.append(res.stats)
            a = res.answers
            answers = a if answers is None else np.unique(
                np.concatenate([answers, a]), axis=0)
        dt = time.time() - t0
        n_loads = sum(s.n_loads for s in stats)
        l_ideal = max(s.l_ideal for s in stats)
        iters = max(s.iterations for s in stats)
        print(f"[serve] {dq.name}: answers={answers.shape[0]:5d} "
              f"loads={n_loads} L_ideal={l_ideal} iters={iters} "
              f"latency={dt*1000:.0f} ms "
              f"load_seq={[s.loads for s in stats]}")
        rec = {"query": dq.name, "answers": int(answers.shape[0]),
               "loads": n_loads, "l_ideal": l_ideal, "iterations": iters,
               "latency_s": dt}
        if args.verify:
            from repro.core.oracle import match_disjunctive
            ref = match_disjunctive(graph, dq, q_pad=answers.shape[1])
            if args.max_answers is None:
                match = (answers.shape[0] == ref.shape[0]
                         and (answers.shape[0] == 0
                              or np.array_equal(np.unique(answers, axis=0),
                                                ref)))
            else:
                # budgeted run: every returned row must be a real answer,
                # and each disjunct returning min(K, total_d) rows means
                # the union can never fall below min(K, ref_total)
                refset = {tuple(r) for r in ref}
                match = (all(tuple(r) in refset for r in answers)
                         and answers.shape[0] >= min(args.max_answers,
                                                     ref.shape[0]))
            rec["oracle_match"] = bool(match)
            print(f"        oracle: {ref.shape[0]} answers "
                  f"{'MATCH' if match else 'MISMATCH'}")
        report.append(rec)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)


if __name__ == "__main__":
    main()
