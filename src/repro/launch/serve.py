"""End-to-end query-serving driver — a thin client of ``GraphSession``.

Loads (or generates) a graph database and opens one ``GraphSession``
(core/session.py): the session partitions the graph with the chosen
scheme, owns the ``PartitionStore`` (device-resident partitions, LRU
capacity via ``--cache-parts``, OPAT runner-up prefetch) and the compiled
evaluators, then serves the query batch through one of the three
strategies (OPAT / TraditionalMP / MapReduceMP).  Reported per query: the
paper's metrics (partition-load sequences, load ratios vs L_ideal, answer
counts, latency) plus the store's cold/warm/prefetch split; the ``--json``
report additionally carries the session's cache counters and per-partition
workload profile (the input of core/repartition.py).

Three serving modes:

  * default — the dataset's query batch, one ``submit`` per query (the
    paper's one-at-a-time shape);
  * ``--workload file.jsonl`` — a batch of queries (one JSON query per
    line, optional per-line ``"max_answers"``, ``"arrival_ms"``,
    ``"slo_class"``) served through the shared-load ``QueryScheduler``
    (core/scheduler.py): overlapping queries share partition loads, plans
    are evaluated batched, and the report adds aggregate throughput
    (queries/sec, loads-per-query, latency percentiles).
    ``--emit-workload file.jsonl`` writes the dataset's own queries in
    that format and exits (``--emit-repeat`` / ``--emit-arrival-spacing-ms``
    / ``--emit-slo-classes`` synthesize overload workloads; combined with
    ``--workload`` it round-trips an existing file losslessly).
    ``--verify`` keeps the same oracle exit-code contract in all modes.
  * ``--slo SPEC`` — SLO serving through the ``ServingFrontend``
    (serving/frontend.py, docs/frontend.md): cost-predicted admission
    control, deadline-aware scheduling, and degrade/defer/shed under
    ``--shed-policy``; per-line arrivals replay on a scalable clock
    (``--arrival-replay``).  Served queries verify under their EFFECTIVE
    (possibly degraded) budget; a shed query missing its ``shed_reason``
    fails the ``--verify`` gate like an oracle mismatch.

Out-of-core serving: ``--save-graph DIR`` persists the session's
partitioned graph as a graph directory (storage/format.py), and
``--graph-dir DIR`` reopens it with partition shards disk-resident
behind the three-tier cache (``--host-cache-parts`` sizes the pinned
host LRU, ``--no-read-ahead`` disables the background disk read-ahead);
``--dataset``/``--seed`` then only name the query batch.  The report
gains the disk-tier counters (``disk_reads``, ``read_ahead_hits``).

The WawPart loop end to end: serve once with ``--profile-json p.json``,
then serve the same dataset/flags with ``--repartition-from p.json`` — the
session re-lays the graph out from the observed traffic (scheme ``"waw"``)
before serving, and ``--verify`` proves answers are unchanged.  The
profile embeds the assignment it was observed under, so both runs must
name the same dataset/scale/seed (the assignment length is validated).

    PYTHONPATH=src python -m repro.launch.serve --dataset imdb --k 4 \
        --scheme ecosocial --engine opat --heuristic max-sn \
        --max-answers 5 --cache-parts 2 --json report.json

MapReduceMP needs one device per partition; run with
    XLA_FLAGS=--xla_force_host_platform_device_count=4
(this driver, unlike dryrun.py, leaves device count to the caller so the
other engines see the real machine).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.core import (EngineConfig, GraphSession, MAX_SN, MAX_YIELD,
                        MAX_YIELD_SHARED, MIN_SN, RANDOM_SN,
                        SHARED_HEURISTICS, partition_quality,
                        total_connected_components)
from repro.core.query import DisjunctiveQuery
from repro.data.generators import (imdb_like_graph, imdb_queries,
                                   subgen_like_graph, subgen_queries)


def load_queries(name: str, graph, seed: int):
    """The dataset's query batch, built against ``graph`` (which may be a
    freshly generated graph or one reopened from a ``--graph-dir``)."""
    if name == "imdb":
        return imdb_queries(graph, seed=seed)
    if name == "synthetic":
        return subgen_queries(graph)
    raise ValueError(name)


def load_dataset(name: str, scale: float, seed: int):
    if name == "imdb":
        g = imdb_like_graph(n_movies=int(300 * scale),
                            n_people=int(400 * scale),
                            n_companies=max(4, int(40 * scale)), seed=seed)
    elif name == "synthetic":
        g = subgen_like_graph(n_nodes=int(2000 * scale),
                              n_edges=int(6000 * scale),
                              n_embed=max(5, int(50 * scale)), seed=seed)
    else:
        raise ValueError(name)
    return g, load_queries(name, g, seed)


def _mutation_soak(session, dqueries, oracle_graph, *, n_deltas: int,
                   compact_every: int, seed: int, max_answers):
    """The --mutate-workload serving loop: before each query, apply a
    burst of random durable delta records (~45% edge inserts, ~45% edge
    deletes, ~10% vertex add/tombstone), optionally folding hot
    partitions into fresh shard generations every ``compact_every``
    deltas; then serve one dataset query against the advanced view.
    ``oracle_graph["g"]`` is re-pointed at the submit-time overlay
    snapshot so --verify checks each answer against exactly the
    generation it was pinned to.  Yields (query, result, budget)."""
    from repro.storage.deltas import DELETED_LABEL
    rng = np.random.default_rng(seed)
    applied = 0
    compacted_at = 0
    qi = 0
    while applied < n_deltas:
        burst = int(min(rng.integers(1, 4), n_deltas - applied))
        for _ in range(burst):
            g = session.graph
            del_id = g.node_vocab.get(DELETED_LABEL, -10)
            alive = np.flatnonzero(np.asarray(g.node_label) != del_id)
            roll = rng.random()
            if roll < 0.45 and alive.size >= 2:
                u, v = rng.choice(alive, size=2, replace=False)
                if g.n_edges:
                    lab = g.edge_vocab.str_of(int(np.asarray(g.edge_label)[
                        int(rng.integers(0, g.n_edges))]))
                else:
                    lab = "soak"
                session.add_edge(int(u), int(v), lab)
            elif roll < 0.90 and g.n_edges:
                i = int(rng.integers(0, g.n_edges))
                session.del_edge(int(np.asarray(g.edge_src)[i]),
                                 int(np.asarray(g.edge_dst)[i]),
                                 g.edge_vocab.str_of(
                                     int(np.asarray(g.edge_label)[i])))
            elif roll < 0.95 and alive.size:
                src = int(rng.choice(alive))
                session.add_vertex(
                    g.node_vocab.str_of(int(np.asarray(g.node_label)[src])),
                    value=float(np.asarray(g.node_value)[src]))
            elif alive.size:
                session.del_vertex(int(rng.choice(alive)))
            applied += 1
        if compact_every and applied - compacted_at >= compact_every:
            pids = session.compact_hot()
            compacted_at = applied
            print(f"[serve] compacted partitions {pids} at delta "
                  f"{applied} -> generation {session.generation}")
        dq = dqueries[qi % len(dqueries)]
        qi += 1
        # snapshot the overlay the submit will pin; the oracle must see
        # the same vertices/edges the evaluator does
        oracle_graph["g"] = session.graph
        res = session.submit(dq, max_answers=max_answers)
        yield dq, res, max_answers
    print(f"[serve] soak done: {applied} deltas, generation "
          f"{session.generation}, "
          f"{int(session._mdir.pending_counts().sum())} pending")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="imdb", choices=["imdb", "synthetic"])
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--k", type=int, default=4, help="number of partitions")
    ap.add_argument("--scheme", default="kway_shem")
    ap.add_argument("--engine", default="opat",
                    choices=["opat", "traditional", "mapreduce"])
    ap.add_argument("--heuristic", default=MAX_SN,
                    choices=[MAX_SN, MIN_SN, RANDOM_SN, MAX_YIELD])
    ap.add_argument("--processors", type=int, default=2,
                    help="p for TraditionalMP")
    ap.add_argument("--max-answers", type=int, default=None,
                    help="answer budget K per disjunct: stop after K unique "
                         "answers (the paper's 'specified number of "
                         "answers'; default: all)")
    ap.add_argument("--cache-parts", type=int, default=None,
                    help="PartitionStore LRU capacity in partitions "
                         "(default: unbounded — everything staged stays "
                         "device-resident)")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable OPAT's runner-up partition prefetch")
    ap.add_argument("--graph-dir", default="", metavar="DIR",
                    help="serve OUT OF CORE from this saved graph "
                         "directory (GraphSession.open): partition shards "
                         "stay on disk behind the host/device cache tiers;"
                         " --dataset/--seed then only name the query "
                         "batch, and --k/--scheme come from the manifest")
    ap.add_argument("--save-graph", default="", metavar="DIR",
                    help="after building (and optionally repartitioning) "
                         "the session, save its partitioned graph as a "
                         "graph directory reopenable via --graph-dir")
    ap.add_argument("--host-cache-parts", type=int, default=None,
                    help="with --graph-dir: pinned-host LRU capacity in "
                         "partitions between disk and device (default: "
                         "unbounded — every shard read stays host-"
                         "resident)")
    ap.add_argument("--no-read-ahead", action="store_true",
                    help="with --graph-dir: disable the background-thread "
                         "disk read-ahead of the heuristic's runner-up")
    ap.add_argument("--mutate-workload", type=int, default=0, metavar="N",
                    help="with --graph-dir: mutation soak — interleave N "
                         "random durable graph updates (edge/vertex "
                         "insert+delete delta records, storage/deltas.py) "
                         "with the dataset's queries; every query runs "
                         "against its pinned generation view and --verify "
                         "checks it against the whole-overlay oracle at "
                         "that same snapshot")
    ap.add_argument("--mutate-compact-every", type=int, default=0,
                    metavar="M",
                    help="with --mutate-workload: fold pending deltas into "
                         "fresh shard generations (compact_hot) after "
                         "every M applied deltas (0 = never compact)")
    ap.add_argument("--mutate-seed", type=int, default=0,
                    help="rng seed of the --mutate-workload update stream")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="check answers against the whole-graph oracle")
    ap.add_argument("--cap", type=int, default=16384)
    ap.add_argument("--json", default="", help="write a JSON report here")
    ap.add_argument("--trace-out", default="", metavar="TRACE.json",
                    help="record end-to-end spans (obs/trace.py) and write "
                         "a Chrome trace-event file loadable in Perfetto / "
                         "chrome://tracing; also enables the decision "
                         "records tools/trace_report.py explains "
                         "(heuristic rankings, admission verdicts)")
    ap.add_argument("--metrics-out", default="", metavar="METRICS.prom",
                    help="write the unified metrics registry "
                         "(obs/metrics.py) in Prometheus text exposition "
                         "format at exit")
    ap.add_argument("--profile-json", default="",
                    help="also write the workload profile alone here")
    ap.add_argument("--repartition-from", default="", metavar="PROFILE.json",
                    help="workload-aware repartitioning: before serving, "
                         "feed this saved workload profile (from a previous "
                         "run's --profile-json) to GraphSession.repartition()"
                         " and serve against the improved 'waw' layout")
    ap.add_argument("--workload", default="", metavar="FILE.jsonl",
                    help="batch mode: serve the queries in this JSON-lines "
                         "file (one query per line, optional per-line "
                         "'max_answers') through the shared-load "
                         "QueryScheduler instead of the dataset's default "
                         "batch; reports per-query latency plus aggregate "
                         "throughput")
    ap.add_argument("--emit-workload", default="", metavar="FILE.jsonl",
                    help="write the dataset's query batch in --workload "
                         "format to this path and exit (round-trips with "
                         "--workload)")
    ap.add_argument("--shared-heuristic", default=MAX_YIELD_SHARED,
                    choices=list(SHARED_HEURISTICS),
                    help="workload-level partition ranking used by "
                         "--workload batch mode")
    ap.add_argument("--fairness-gamma", type=float, default=0.0,
                    help="aging weight (rounds-waiting x SNI) in the "
                         "shared ranking of --workload batch mode; 0 = "
                         "pure yield, >0 bounds starvation of no-overlap "
                         "queries under skew")
    ap.add_argument("--slo", default="", metavar="SPEC",
                    help="SLO serving mode: comma-separated "
                         "name=deadline_seconds classes (e.g. "
                         "'interactive=0.5,batch=5,exhaustive=inf'; order "
                         "is priority order, known names keep their "
                         "strictness flags).  Queries are served through "
                         "the ServingFrontend (serving/frontend.py): "
                         "cost-predicted admission, deadline-aware "
                         "ranking, degrade/defer/shed under --shed-policy")
    ap.add_argument("--shed-policy", default="predictive",
                    choices=["predictive", "deadline", "never"],
                    help="SLO mode overload response: 'predictive' "
                         "degrades (shrinks K), defers, then sheds from "
                         "predicted backlog vs deadline; 'deadline' sheds "
                         "anything predicted to miss; 'never' admits all")
    ap.add_argument("--arrival-replay", type=float, default=0.0,
                    metavar="SPEED",
                    help="replay the workload's per-line arrival_ms on a "
                         "scalable clock: 1.0 = real time, 2.0 = twice as "
                         "fast, 0 (default) = instant (every arrival due "
                         "immediately, deterministic)")
    ap.add_argument("--default-slo", default="",
                    help="SLO class for workload lines (or dataset "
                         "queries) that carry no slo_class of their own "
                         "(default: none — such queries get no deadline)")
    ap.add_argument("--emit-repeat", type=int, default=1, metavar="N",
                    help="with --emit-workload: write the dataset's query "
                         "batch N times over (an overload-scale workload)")
    ap.add_argument("--emit-arrival-spacing-ms", type=float, default=None,
                    metavar="MS",
                    help="with --emit-workload: attach arrival_ms = "
                         "line_index * MS to every emitted line (a "
                         "constant-rate arrival process)")
    ap.add_argument("--emit-slo-classes", default="", metavar="A,B,...",
                    help="with --emit-workload: attach slo_class round-"
                         "robin from this comma-separated list")
    args = ap.parse_args()

    from repro.obs import NULL_TRACER, Tracer
    tracer = Tracer() if args.trace_out else NULL_TRACER

    t0 = time.time()
    if args.graph_dir:
        session = GraphSession.open(args.graph_dir,
                                    engine=args.engine,
                                    heuristic=args.heuristic,
                                    config=EngineConfig(cap=args.cap),
                                    cache_parts=args.cache_parts,
                                    host_cache_parts=args.host_cache_parts,
                                    read_ahead=not args.no_read_ahead,
                                    processors=args.processors,
                                    prefetch=not args.no_prefetch,
                                    seed=args.seed,
                                    tracer=tracer)
        graph = session.graph
        dqueries = load_queries(args.dataset, graph, args.seed)
        print(f"[serve] graph: {graph.n_nodes} nodes, {graph.n_edges} "
              f"edges (opened out of core from {args.graph_dir}: "
              f"{session.pg.backing.total_part_bytes()} shard bytes on "
              f"disk, host cache "
              f"{args.host_cache_parts or 'unbounded'} parts)")
    else:
        graph, dqueries = load_dataset(args.dataset, args.scale, args.seed)
        print(f"[serve] graph: {graph.n_nodes} nodes, {graph.n_edges} edges")
    # --verify's oracle target: static modes check against the one graph,
    # the mutation soak re-points this at each query's pinned overlay
    # snapshot so every answer verifies against exactly the generation
    # (+ pending deltas) it was served under
    oracle_graph = {"g": graph}

    if args.emit_workload:
        if args.workload:
            # round-trip: re-emit an existing workload file's parsed lines
            # losslessly (arrival_ms / slo_class / max_answers included)
            with open(args.workload) as f:
                out_lines = [json.loads(ln) for ln in f if ln.strip()]
        else:
            out_lines = []
            classes = [c for c in args.emit_slo_classes.split(",") if c]
            for rep_i in range(max(1, args.emit_repeat)):
                for dq in dqueries:
                    d = dq.to_json_dict()
                    i = len(out_lines)
                    if args.emit_arrival_spacing_ms is not None:
                        d["arrival_ms"] = i * args.emit_arrival_spacing_ms
                    if classes:
                        d["slo_class"] = classes[i % len(classes)]
                    out_lines.append(d)
        with open(args.emit_workload, "w") as f:
            for d in out_lines:
                f.write(json.dumps(d) + "\n")
        print(f"[serve] wrote {len(out_lines)} queries to "
              f"{args.emit_workload}")
        return

    if not args.graph_dir:
        session = GraphSession(graph, k=args.k, scheme=args.scheme,
                               engine=args.engine, heuristic=args.heuristic,
                               config=EngineConfig(cap=args.cap),
                               cache_parts=args.cache_parts,
                               processors=args.processors,
                               prefetch=not args.no_prefetch,
                               seed=args.seed,
                               tracer=tracer)
    gen0 = session.generation   # None for in-RAM sessions
    q = partition_quality(graph, session.pg.assignment, session.k)
    print(f"[serve] session: k={session.k} scheme={session.scheme} "
          f"engine={args.engine} cut={q['cut']} ({q['cut_frac']:.1%}) "
          f"sizes={q['sizes']} "
          f"total_cc={total_connected_components(session.pg)} "
          f"cache_parts={args.cache_parts or 'unbounded'} "
          f"[{time.time()-t0:.1f}s]")

    if args.repartition_from:
        info = session.repartition(args.repartition_from)
        q = partition_quality(graph, session.pg.assignment, session.k)
        print(f"[serve] repartitioned from {args.repartition_from}: "
              f"scheme={session.scheme} cut {info['cut_before']} -> "
              f"{info['cut_after']} ({q['cut_frac']:.1%}) "
              f"sizes={q['sizes']} "
              f"total_cc={total_connected_components(session.pg)}")

    if args.save_graph:
        manifest = session.save(args.save_graph)
        total = sum(p["nbytes"] for p in manifest["partitions"])
        print(f"[serve] saved graph directory {args.save_graph}: "
              f"k={manifest['k']} scheme={manifest['scheme']} "
              f"{total} shard bytes (reopen with --graph-dir)")

    throughput = None
    slo_report = None
    sched_report = None
    if args.slo:
        from repro.serving import (Request, parse_slo_spec,
                                   requests_from_workload)
        classes = parse_slo_spec(args.slo)
        default_slo = args.default_slo or None
        if default_slo and default_slo not in {c.name for c in classes}:
            sys.exit(f"[serve] --default-slo {default_slo!r} is not in the "
                     f"--slo spec")
        if args.workload:
            with open(args.workload) as f:
                lines = [json.loads(ln) for ln in f if ln.strip()]
            requests = requests_from_workload(
                lines, default_slo=default_slo,
                default_max_answers=args.max_answers)
        else:
            requests = [Request(dq, slo_class=default_slo,
                                max_answers=args.max_answers)
                        for dq in dqueries]
        print(f"[serve] slo serving: {len(requests)} requests, classes "
              f"[{', '.join(f'{c.name}={c.deadline_s}s' for c in classes)}]"
              f", policy={args.shed_policy}, "
              f"replay={f'x{args.arrival_replay:g}' if args.arrival_replay > 0 else 'instant'}")
        fe = session.frontend(slo_classes=classes,
                              shed_policy=args.shed_policy,
                              heuristic=args.shared_heuristic,
                              fairness_gamma=args.fairness_gamma,
                              replay_speed=args.arrival_replay)
        slo_report = fe.serve(requests)
        lat = [o.latency_s for o in slo_report.served]
        qps = (len(slo_report.served) / slo_report.wall_s
               if slo_report.wall_s else 0.0)
        throughput = {
            "n_queries": len(slo_report.served),
            "wall_s": slo_report.wall_s,
            "qps": qps,
            "p50_latency_s": float(np.percentile(lat, 50)) if lat else 0.0,
            "p95_latency_s": float(np.percentile(lat, 95)) if lat else 0.0,
            "p99_latency_s": float(np.percentile(lat, 99)) if lat else 0.0,
            "fairness_gamma": args.fairness_gamma,
            "slo": {
                "classes": slo_report.per_class,
                "counters": slo_report.counters,
                "shed_by_reason": slo_report.shed_by_reason,
                "rounds": slo_report.rounds,
                "shed_policy": args.shed_policy,
                "cost_model": fe.cost_model.snapshot(),
                "slo_burn": slo_report.slo_burn,
            },
        }
    elif args.workload:
        with open(args.workload) as f:
            lines = [json.loads(l) for l in f if l.strip()]
        wqueries = [DisjunctiveQuery.from_json_dict(d) for d in lines]
        budgets = [d.get("max_answers", args.max_answers) for d in lines]
        print(f"[serve] workload: {len(wqueries)} queries from "
              f"{args.workload} via the shared scheduler "
              f"({args.shared_heuristic})")
        report = sched_report = session.submit_many(
            wqueries, max_answers=budgets,
            heuristic=args.shared_heuristic,
            fairness_gamma=args.fairness_gamma)
        lat = [r.latency_s for r in report.results]
        qps = (len(report.results) / report.wall_s if report.wall_s else 0.0)
        throughput = {
            "n_queries": len(report.results),
            "wall_s": report.wall_s,
            "qps": qps,
            "shared": report.shared,
            "workload_loads": report.n_loads,
            "loads_per_query": report.loads_per_query,
            "batch_sizes": report.batch_sizes,
            "p50_latency_s": float(np.percentile(lat, 50)) if lat else 0.0,
            "p95_latency_s": float(np.percentile(lat, 95)) if lat else 0.0,
            "p99_latency_s": float(np.percentile(lat, 99)) if lat else 0.0,
            "cold_loads": report.load_stats.cold_loads,
            "warm_loads": report.load_stats.warm_loads,
            "prefetch_hits": report.load_stats.prefetch_hits,
            "disk_reads": report.load_stats.disk_reads,
            "read_ahead_hits": report.load_stats.read_ahead_hits,
            "fairness_gamma": args.fairness_gamma,
        }
        served = zip(wqueries, report.results, budgets)
    elif args.mutate_workload:
        if not args.graph_dir:
            sys.exit("[serve] --mutate-workload needs --graph-dir (durable "
                     "delta logs live in the graph directory)")
        print(f"[serve] mutation soak: {args.mutate_workload} deltas "
              f"(seed {args.mutate_seed}), compact every "
              f"{args.mutate_compact_every or 'never'}")
        served = _mutation_soak(session, dqueries, oracle_graph,
                                n_deltas=args.mutate_workload,
                                compact_every=args.mutate_compact_every,
                                seed=args.mutate_seed,
                                max_answers=args.max_answers)
    else:
        served = ((dq, session.submit(dq, max_answers=args.max_answers),
                   args.max_answers) for dq in dqueries)

    if slo_report is not None:
        # each served outcome verifies under its EFFECTIVE budget (a
        # degraded query's shrunken K is the contract it was served
        # under); a shed query must carry an explicit shed_reason —
        # missing one is a gate failure like an oracle mismatch
        served = (((req.query if hasattr(req.query, "disjuncts")
                    else DisjunctiveQuery([req.query],
                                          name=req.query.name)),
                   o.result, o.max_answers)
                  for req, o in zip(requests, slo_report.outcomes)
                  if o.status == "ok")
        slo_extras = iter(
            [{"status": "ok", "slo_class": o.slo_class,
              "degraded": o.degraded, "deferred": o.deferred,
              "deadline_s": o.deadline_s, "deadline_met": o.deadline_met,
              "predicted_latency_s": o.predicted_latency_s,
              "effective_max_answers": o.max_answers}
             for o in slo_report.served])

    records = []
    mismatches = 0
    if slo_report is not None:
        for o in slo_report.shed:
            print(f"[serve] {o.name}: SHED ({o.shed_reason}) "
                  f"class={o.slo_class} "
                  f"predicted={o.predicted_latency_s*1000:.0f} ms vs "
                  f"deadline={o.deadline_s*1000:.0f} ms")
            if args.verify and not o.shed_reason:
                mismatches += 1
            records.append({"query": o.name, "status": "shed",
                            "slo_class": o.slo_class,
                            "shed_reason": o.shed_reason,
                            "predicted_latency_s": o.predicted_latency_s,
                            "deadline_s": o.deadline_s})
    for dq, res, budget in served:
        answers = res.answers
        n_loads = res.n_loads
        l_ideal = max(s.l_ideal for s in res.stats)
        iters = max(s.iterations for s in res.stats)
        ls = res.load_stats
        print(f"[serve] {dq.name}: answers={answers.shape[0]:5d} "
              f"loads={n_loads} (cold={ls.cold_loads} warm={ls.warm_loads} "
              f"pf_hits={ls.prefetch_hits}) L_ideal={l_ideal} iters={iters} "
              f"latency={res.latency_s*1000:.0f} ms "
              f"load_seq={[s.loads for s in res.stats]}")
        rec = {"query": dq.name, "answers": int(answers.shape[0]),
               "loads": n_loads, "l_ideal": l_ideal, "iterations": iters,
               "latency_s": res.latency_s,
               "cold_loads": ls.cold_loads, "warm_loads": ls.warm_loads,
               "prefetch_hits": ls.prefetch_hits,
               "disk_reads": ls.disk_reads,
               "read_ahead_hits": ls.read_ahead_hits,
               "generation": res.generation}
        if slo_report is not None:
            rec.update(next(slo_extras))
        if args.verify:
            from repro.core.oracle import match_disjunctive
            ref = match_disjunctive(oracle_graph["g"], dq,
                                    q_pad=answers.shape[1])
            if budget is None:
                match = (answers.shape[0] == ref.shape[0]
                         and (answers.shape[0] == 0
                              or np.array_equal(np.unique(answers, axis=0),
                                                ref)))
            else:
                # budgeted run: every returned row must be a real answer,
                # and each disjunct returning min(K, total_d) rows means
                # the union can never fall below min(K, ref_total)
                refset = {tuple(r) for r in ref}
                match = (all(tuple(r) in refset for r in answers)
                         and answers.shape[0] >= min(budget, ref.shape[0]))
            rec["oracle_match"] = bool(match)
            mismatches += int(not match)
            print(f"        oracle: {ref.shape[0]} answers "
                  f"{'MATCH' if match else 'MISMATCH'}")
        records.append(rec)

    if throughput is not None and "workload_loads" in throughput:
        print(f"[serve] throughput: {throughput['n_queries']} queries in "
              f"{throughput['wall_s']:.2f}s -> {throughput['qps']:.1f} q/s, "
              f"{throughput['workload_loads']} workload loads "
              f"({throughput['loads_per_query']:.2f}/query, "
              f"cold={throughput['cold_loads']} "
              f"warm={throughput['warm_loads']}), "
              f"p50={throughput['p50_latency_s']*1000:.0f} ms "
              f"p95={throughput['p95_latency_s']*1000:.0f} ms "
              f"p99={throughput['p99_latency_s']*1000:.0f} ms")
    elif throughput is not None:
        c = throughput["slo"]["counters"]
        print(f"[serve] slo: {c['arrived']} arrived, {c['admitted']} "
              f"admitted, {c['served']} served "
              f"({c['degraded']} degraded, {c['deferred']} deferred), "
              f"{c['shed']} shed {throughput['slo']['shed_by_reason']}, "
              f"{throughput['slo']['rounds']} scheduler rounds")
        for cls, pc in throughput["slo"]["classes"].items():
            print(f"[serve]   {cls}: {int(pc['served'])} served, "
                  f"p50={pc['p50_latency_s']*1000:.0f} ms "
                  f"p95={pc['p95_latency_s']*1000:.0f} ms "
                  f"p99={pc['p99_latency_s']*1000:.0f} ms")

    cache = session.load_stats.to_dict()
    print(f"[serve] session cache: {cache['cold_loads']} cold / "
          f"{cache['warm_loads']} warm loads "
          f"(hit rate {cache['hit_rate']:.1%}), "
          f"{cache['evictions']} evictions, "
          f"{cache['prefetch_issued']} prefetches "
          f"({cache['prefetch_hits']} hit), "
          f"{cache['bytes_cold']} cold bytes")
    if session.out_of_core:
        print(f"[serve] disk tier: {cache['disk_reads']} shard reads "
              f"({cache['bytes_disk']} bytes), "
              f"{cache['read_ahead_issued']} read-aheads "
              f"({cache['read_ahead_hits']} hit), "
              f"{cache['host_evictions']} host evictions")

    # the unified metrics registry absorbs every subsystem's counters at
    # exit (obs/metrics.py ingesters) — same numbers whether or not spans
    # were recorded; --trace-out additionally dumps the span timeline
    from repro.obs import (MetricsRegistry, ingest_schedule, ingest_session,
                           observability_snapshot, write_chrome_trace,
                           write_prometheus)
    registry = MetricsRegistry()
    ingest_session(registry, session)
    if sched_report is not None:
        ingest_schedule(registry, sched_report.loads,
                        sched_report.batch_sizes)
    if args.trace_out:
        write_chrome_trace(tracer, args.trace_out)
        print(f"[serve] wrote Chrome trace ({len(tracer.spans)} spans, "
              f"{len(tracer.decisions)} decisions) to {args.trace_out}")
    if args.metrics_out:
        write_prometheus(registry, args.metrics_out)
        print(f"[serve] wrote Prometheus metrics to {args.metrics_out}")

    if args.json or args.profile_json:
        # built once: the profile embeds two [V]-length arrays, so don't
        # materialize/serialize it separately per output file
        profile = session.workload_profile()
        if args.json:
            # schema_version 3: adds the "profile" resource block (memory
            # peaks, per-kernel predicted costs, tier byte flows, SLO burn)
            from repro.obs import resource_profile_snapshot
            rep = {"schema_version": 3,
                   "queries": records,
                   "cache": cache,
                   "observability": observability_snapshot(tracer, registry),
                   "profile": resource_profile_snapshot(session),
                   "workload_profile": profile}
            if session.mutable:
                rep["generations"] = {
                    "start": gen0,
                    "end": session.generation,
                    "compactions": session._mdir.compactions,
                    "pending_deltas": int(
                        session._mdir.pending_counts().sum()),
                }
            if throughput is not None:
                rep["throughput"] = throughput
            with open(args.json, "w") as f:
                json.dump(rep, f, indent=2)
        if args.profile_json:
            with open(args.profile_json, "w") as f:
                json.dump(profile, f, indent=2)
    if mismatches:   # --verify is a gate (CI runs this): fail on MISMATCH
        sys.exit(f"[serve] {mismatches} quer{'y' if mismatches == 1 else 'ies'} "
                 f"MISMATCHED the oracle")


if __name__ == "__main__":
    main()
