"""Divisibility-aware sharding rule resolver.

Parameter leaf NAMES carry sharding meaning: ``AXES_BY_NAME`` maps each leaf
name to per-dim logical axes, and ``LOGICAL_TO_MESH`` maps logical axes to
candidate mesh axes.  The resolver assigns a mesh axis to a dim only when
the axis size divides the dim and the axis is not already used in that spec
— so e.g. qwen2-1.5b's 12 heads silently fall back to replication over the
16-wide model axis while its ff/vocab dims still shard (DESIGN.md §6), and
GQA kv-heads smaller than the model axis are stored replicated (Megatron's
kv-replication expressed as a spec).

Stacked body parameters ([n_periods, ...]) get a leading None automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from ..models.transformer import abstract_params
from ..serving.decode import abstract_caches
from ..train.optimizer import abstract_opt_state
from .mesh import dp_axes

# leaf name -> logical axis per (trailing) dim
AXES_BY_NAME: Dict[str, Tuple[Optional[str], ...]] = {
    "embed": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "in_proj": (None, "embed"),
    "img_proj_w1": (None, "embed"),
    "img_proj_w2": (None, "embed"),
    # attention
    "wq": ("embed", "heads", None),
    "wk": ("embed", "kv_heads", None),
    "wv": ("embed", "kv_heads", None),
    "wo": ("heads", None, "embed"),
    "bq": ("heads", None),
    "bk": ("kv_heads", None),
    "bv": ("kv_heads", None),
    # dense FFN (also mLSTM up/gate/down: same shapes/meaning)
    "w_gate": ("embed", "mlp"),
    "w_up": ("embed", "mlp"),
    "w_down": ("mlp", "embed"),
    # MoE
    "router": ("embed", None),
    "e_gate": ("experts", "embed", None),
    "e_up": ("experts", "embed", None),
    "e_down": ("experts", None, "embed"),
    "s_gate": ("embed", "mlp"),
    "s_up": ("embed", "mlp"),
    "s_down": ("mlp", "embed"),
    # RG-LRU
    "w_in": ("embed", "lru"),
    "w_gate_branch": ("embed", "lru"),
    "conv_w": (None, "lru"),
    "w_rgate": ("lru", None),
    "w_igate": ("lru", None),
    "lam": ("lru",),
    "w_out": ("lru", "embed"),
    # mLSTM extras
    "w_q": ("mlp", None),
    "w_k": ("mlp", None),
    "w_v": ("mlp", None),
    "w_i": ("mlp", None),
    "w_f": ("mlp", None),
    "b_i": (None,),
    "b_f": (None,),
    "out_norm": (None,),
    # sLSTM
    "w_x": ("embed", "mlp"),
    "r_h": ("heads", None, None),
    "b": (None,),
    # norms
    "ln1": (None,), "ln2": (None,), "final_norm": (None,),
    "norm": (None,), "q_norm": (None,), "k_norm": (None,),
    # optimizer scalars
    "step": (),
}

LOGICAL_TO_MESH: Dict[str, Tuple[str, ...]] = {
    "embed": ("data",),           # FSDP
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "lru": ("model",),
}


def _leaf_name(path) -> str:
    last = path[-1]
    return str(last.key) if hasattr(last, "key") else str(last)


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    logical_to_mesh: Dict[str, Tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(LOGICAL_TO_MESH))

    def resolve(self, shape: Sequence[int],
                logical: Sequence[Optional[str]]) -> P:
        """Assign mesh axes to dims by divisibility; never reuse an axis."""
        logical = tuple(logical)
        if len(logical) < len(shape):                 # stacked leading dims
            logical = (None,) * (len(shape) - len(logical)) + logical
        used = set()
        spec = []
        for dim, name in zip(shape, logical):
            assigned = None
            if name is not None:
                for ax in self.logical_to_mesh.get(name, ()):
                    if ax in self.mesh.axis_names and ax not in used \
                            and dim % self.mesh.shape[ax] == 0 \
                            and self.mesh.shape[ax] > 1:
                        assigned = ax
                        used.add(ax)
                        break
            spec.append(assigned)
        while spec and spec[-1] is None:
            spec.pop()
        return P(*spec)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def _tree_shardings(tree, rules: ShardingRules, overrides=None):
    def one(path, leaf):
        name = _leaf_name(path)
        logical = (overrides or {}).get(name, AXES_BY_NAME.get(name))
        if logical is None:
            logical = (None,) * len(leaf.shape)
        return rules.named(rules.resolve(leaf.shape, logical))
    return jax.tree_util.tree_map_with_path(one, tree)


def embed_overrides(embed_vocab_shard: bool):
    """embed_vocab_shard=False stores the embedding table vocab-REPLICATED
    (d still FSDP-sharded): the token gather becomes local after one cheap
    weight all-gather instead of forcing a full-activation all-reduce of the
    masked partial gather (§Perf-C iteration 1)."""
    if embed_vocab_shard:
        return {}
    return {"embed": (None, "embed")}


def param_shardings(cfg: ModelConfig, mesh: Mesh, *,
                    embed_vocab_shard: bool = True):
    rules = ShardingRules(mesh)
    return _tree_shardings(abstract_params(cfg), rules,
                           embed_overrides(embed_vocab_shard))


def opt_shardings(cfg: ModelConfig, mesh: Mesh, *,
                  embed_vocab_shard: bool = True):
    rules = ShardingRules(mesh)
    return _tree_shardings(
        abstract_opt_state(abstract_params(cfg)), rules,
        embed_overrides(embed_vocab_shard))


def _batch_dim_spec(mesh: Mesh, b: int):
    """Shard the batch dim over as many dp axes as divide it."""
    axes = []
    rem = b
    for a in dp_axes(mesh):
        sz = mesh.shape[a]
        if sz > 1 and rem % sz == 0:
            axes.append(a)
            rem //= sz
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def batch_shardings(mesh: Mesh, batch_tree):
    """Inputs: [B, ...] -> batch over dp axes, rest replicated."""
    def one(path, leaf):
        if not leaf.shape:
            return NamedSharding(mesh, P())
        bspec = _batch_dim_spec(mesh, leaf.shape[0])
        return NamedSharding(mesh, P(bspec, *([None] * (len(leaf.shape) - 1))))
    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, s_max: int,
                    *, shard_cache_seq: bool = True):
    """KV caches: [.., B, S, Hkv, hd] -> (dp on B, model on S) — S-sharded
    flash-decode layout.  Recurrent states: dp on B, model on the state
    width when divisible."""
    rules = ShardingRules(mesh)
    caches = abstract_caches(cfg, batch, s_max)

    def one(path, leaf):
        name = _leaf_name(path)
        nd = len(leaf.shape)
        if name in ("k", "v") and nd >= 4:
            lead = (None,) * (nd - 4)
            bspec = _batch_dim_spec(mesh, leaf.shape[-4])
            sspec = None
            if shard_cache_seq and "model" in mesh.axis_names \
                    and leaf.shape[-3] % mesh.shape["model"] == 0:
                sspec = "model"
            return rules.named(P(*lead, bspec, sspec, None, None))
        # recurrent states: batch dim is first non-stacked dim
        lead_n = 1 if (path and getattr(path[0], "key", None) == "body") else 0
        spec = [None] * nd
        if nd > lead_n:
            spec[lead_n] = _batch_dim_spec(mesh, leaf.shape[lead_n])
        # shard the trailing width over model when large & divisible
        if nd >= 2 and leaf.shape[-1] >= 1024 and "model" in mesh.axis_names \
                and leaf.shape[-1] % mesh.shape["model"] == 0:
            spec[-1] = "model"
        while spec and spec[-1] is None:
            spec.pop()
        return rules.named(P(*spec))

    return jax.tree_util.tree_map_with_path(one, caches)


def logit_constraint(mesh: Mesh, batch: int, vocab: int):
    """with_sharding_constraint closure for [B, S, V] logits: batch over dp,
    vocab over model (when divisible).  Without this, XLA materializes the
    full f32 logits per device — ~40 GB at production shapes (§Perf iter 0).
    """
    bspec = _batch_dim_spec(mesh, batch)
    vspec = "model" if ("model" in mesh.axis_names
                        and vocab % mesh.shape["model"] == 0) else None

    def constrain(x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(bspec, None, vspec)))
    return constrain


def act_constraint(mesh: Mesh, batch: int, *, tp_act: bool = False):
    """with_sharding_constraint closure for [B, S, d] block activations.

    Baseline: batch over dp axes, d replicated.  ``tp_act=True`` also shards
    d over model (halves the per-layer all-gathers at the cost of norm
    collectives) — a §Perf hillclimb lever.
    """
    bspec = _batch_dim_spec(mesh, batch)
    dspec = "model" if tp_act else None

    def constrain(x):
        if x.ndim == 3 and (x.shape[-1] % mesh.shape["model"] == 0
                            or dspec is None):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bspec, None, dspec)))
        return x
    return constrain
