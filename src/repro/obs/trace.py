"""Span tracing with a free disabled path.

The serving stack's hot loops (store lookups, scheduler rounds, kernel
dispatches) run thousands of times per second, so the tracer's OFF state
must cost essentially nothing: ``NULL_TRACER`` is a stateless singleton
whose ``span()`` returns one shared reentrant no-op context manager —
no allocation, no clock read, no lock.  Engines/stores hold a tracer
reference unconditionally and never branch on configuration themselves.

The ON state (``Tracer``) records:

  spans     — named intervals with monotonic ``perf_counter`` t0/t1, a
              process-unique id, the enclosing span's id as parent
              (per-thread stacks: a read-ahead worker's spans parent
              within the worker, never across threads), and free-form
              attributes.  ``span()`` yields the live ``Span`` so call
              sites can attach outcomes discovered mid-block
              (``sp.set(tier="warm")``).  ``add_span`` records a span
              from externally captured timestamps — the scheduler uses
              it for per-query root spans whose lifetime (admission →
              retirement) doesn't nest in any one call frame.
  decisions — point-in-time records explaining a choice: the heuristics
              emit per-partition score breakdowns, the serving front
              end its predicted-vs-deadline admission inputs.  These are
              what ``tools/trace_report.py`` replays to answer "why was
              P3 loaded before P1?".

Appends take a lock (read-ahead threads trace too); span-stack state is
thread-local.  All timestamps share one ``perf_counter`` timebase, so
spans from different threads order correctly in the exported trace.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class Span:
    """One recorded interval.  ``t0``/``t1`` are ``time.perf_counter()``
    seconds (monotonic, process-wide timebase); ``t1`` is None while the
    span is still open."""

    name: str
    span_id: int
    parent_id: Optional[int]
    t0: float
    t1: Optional[float] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    thread: str = ""

    def set(self, **attrs: Any) -> "Span":
        """Attach attributes discovered mid-span (e.g. the cache tier a
        load resolved to)."""
        self.attrs.update(attrs)
        return self

    @property
    def duration_s(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0


class _NullSpan:
    """The shared no-op span/context-manager: reentrant, stateless, and
    allocation-free — the entire cost of disabled tracing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracing: every method is a no-op.  A single module-level
    instance (``NULL_TRACER``) is shared by every untraced session."""

    enabled = False

    def span(self, name: str, **attrs: Any) -> _NullSpan:
        return _NULL_SPAN

    def add_span(self, name: str, t0: float, t1: float,
                 parent_id: Optional[int] = None, **attrs: Any) -> None:
        return None

    def decision(self, kind: str, **payload: Any) -> None:
        return None

    def event(self, name: str, **attrs: Any) -> None:
        return None


NULL_TRACER = NullTracer()


class _SpanCtx:
    """Context manager for one live span: pushes onto the calling
    thread's stack on enter, stamps ``t1`` and records on exit."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        self._tracer._push(self._span)
        return self._span

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> bool:
        sp = self._span
        sp.t1 = time.perf_counter()
        if exc_type is not None:
            sp.attrs.setdefault("error", exc_type.__name__)
        self._tracer._pop(sp)
        return False


class Tracer:
    """Enabled tracing: records spans, events, and decision records.

    One tracer serves one session (and everything threaded under it —
    store, engines, scheduler, front end, delta layer).  Thread-safe:
    each thread nests spans on its own stack; the recorded lists are
    append-only under a lock.
    """

    enabled = True

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._spans: List[Span] = []
        self._decisions: List[Dict[str, Any]] = []
        self._local = threading.local()
        # the trace's epoch: exporters emit timestamps relative to this
        self.t_epoch = time.perf_counter()

    # -- recording ----------------------------------------------------------

    def span(self, name: str, **attrs: Any) -> _SpanCtx:
        """``with tracer.span("store.load", pid=3) as sp: ...`` — records
        the block as one span, parented under the thread's innermost
        open span."""
        sp = Span(name=name, span_id=next(self._ids),
                  parent_id=self.current_span_id,
                  t0=time.perf_counter(), attrs=dict(attrs),
                  thread=threading.current_thread().name)
        return _SpanCtx(self, sp)

    def add_span(self, name: str, t0: float, t1: float,
                 parent_id: Optional[int] = None, **attrs: Any) -> Span:
        """Record a span from timestamps the caller captured itself
        (``time.perf_counter()`` seconds, same timebase as ``span``)."""
        sp = Span(name=name, span_id=next(self._ids), parent_id=parent_id,
                  t0=float(t0), t1=float(t1), attrs=dict(attrs),
                  thread=threading.current_thread().name)
        with self._lock:
            self._spans.append(sp)
        return sp

    def decision(self, kind: str, **payload: Any) -> None:
        """Record one decision: a heuristic ranking's per-partition score
        breakdown, a frontend admission verdict, ...  Stamped with the
        current time and the enclosing span so reports can correlate
        decisions with the work they caused."""
        rec = {"kind": kind, "ts": time.perf_counter(),
               "span_id": self.current_span_id}
        rec.update(payload)
        with self._lock:
            self._decisions.append(rec)

    def event(self, name: str, **attrs: Any) -> None:
        """A zero-duration marker (exported as an instant event)."""
        t = time.perf_counter()
        self.add_span(name, t, t, parent_id=self.current_span_id, **attrs)

    # -- introspection ------------------------------------------------------

    @property
    def current_span_id(self) -> Optional[int]:
        stack = getattr(self._local, "stack", None)
        return stack[-1].span_id if stack else None

    @property
    def spans(self) -> List[Span]:
        """Snapshot of every *closed* span recorded so far."""
        with self._lock:
            return list(self._spans)

    @property
    def decisions(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._decisions)

    def span_totals(self) -> Dict[str, Dict[str, float]]:
        """Per-name count and total seconds — the summary the JSON
        report embeds."""
        totals: Dict[str, Dict[str, float]] = {}
        for sp in self.spans:
            agg = totals.setdefault(sp.name, {"count": 0, "total_s": 0.0})
            agg["count"] += 1
            agg["total_s"] += sp.duration_s
        return totals

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._decisions.clear()

    # -- internals (called by _SpanCtx) -------------------------------------

    def _push(self, sp: Span) -> None:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        stack.append(sp)

    def _pop(self, sp: Span) -> None:
        stack = getattr(self._local, "stack", None)
        if stack and stack[-1] is sp:
            stack.pop()
        elif stack and sp in stack:       # mis-nested exit: drop through
            stack.remove(sp)
        with self._lock:
            self._spans.append(sp)
