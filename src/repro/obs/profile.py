"""Resource profiling — memory accounting, kernel cost attribution, and
SLO burn-rate monitoring on top of the PR 9 tracing plumbing.

The paper's whole premise is that *resources* (device memory, load
bandwidth) are the binding constraint; PR 9 made the system observable in
*time*.  This module closes the gap with three read-only instruments:

  memory accounting   ``ResourceProfiler.sample_device`` stamps the
                      store's live device bytes onto a closing span
                      (``store.load``/``kernel.eval``) and tracks the
                      session-level peak; ``observe_rss`` samples the
                      process peak RSS from ``getrusage``.  Byte *flows*
                      (cold/prefetch/disk/host-cache traffic) are already
                      counted by ``LoadStats``; the profiler adds the
                      *stock* — what is resident right now.
  cost attribution    ``attribute_kernel`` lowers a jitted evaluator once
                      per compiled bucket (abstract lowering — nothing
                      executes), runs ``launch/hlo_cost.analyze_hlo_text``
                      over the HLO, and folds the FLOPs/bytes estimate
                      through the roofline model
                      (``launch/hlo_analysis.RooflineTerms``).
                      ``stamp_kernel`` then writes the per-key cost onto
                      every ``kernel.eval`` span, so a trace joins
                      *predicted* cost with *measured* wall time —
                      ``tools/trace_report.py --cost`` renders the
                      achieved-vs-predicted table.
  SLO burn rate       ``SloBurnMonitor`` keeps a rolling window of
                      deadline outcomes per SLO class; burn rate is the
                      window's miss fraction over the error budget
                      (burn > 1 → the budget is being spent faster than
                      it accrues — Google SRE workbook semantics).

Discipline is identical to ``trace.NULL_TRACER``: every hot-path call
site holds a profiler reference that is ``NULL_PROFILER`` when profiling
is off, so the disabled path costs ~a method call and profiling on/off
is answer-invariant (tests/test_profiling.py proves parity and the <5%
overhead gate).  All failures inside the profiler degrade to zeroed
attributions — profiling must never break serving.
"""
from __future__ import annotations

import collections
import resource
from typing import Any, Deque, Dict, Optional, Tuple


def _key_str(key: Any) -> str:
    """Canonical string form of a kernel bucket key (tuples stay readable:
    ('opat', 'eval') -> 'opat:eval', ('scheduler.tmp', 8) -> 'scheduler.tmp:8')."""
    if isinstance(key, tuple):
        return ":".join(str(k) for k in key)
    return str(key)


class NullResourceProfiler:
    """The disabled path: every method is a no-op, shared as the module
    singleton ``NULL_PROFILER`` so call sites never branch."""

    __slots__ = ()
    enabled = False

    def sample_device(self, span: Any, store: Any) -> None:
        pass

    def observe_rss(self) -> int:
        return 0

    def attribute_kernel(self, key: Any, fn: Any, *args: Any) -> None:
        pass

    def stamp_kernel(self, span: Any, key: Any) -> None:
        pass

    def snapshot(self) -> Dict[str, Any]:
        return {"enabled": False}


NULL_PROFILER = NullResourceProfiler()


class ResourceProfiler:
    """Collects resource facts for one session; owned by ``GraphSession``
    (built automatically whenever a real ``Tracer`` is attached) and
    threaded to the store and every engine the same way the tracer is."""

    enabled = True

    def __init__(self, tracer: Optional[Any] = None):
        self.tracer = tracer
        self.peak_device_bytes = 0
        self.peak_rss_bytes = 0
        # kernel bucket key -> predicted cost (computed once per key)
        self.kernel_costs: Dict[str, Dict[str, Any]] = {}

    # -- memory accounting -------------------------------------------------

    def sample_device(self, span: Any, store: Any) -> int:
        """Live device bytes held by the store's cache right now, stamped
        onto ``span`` (the closing ``store.load``/``kernel.eval``) and
        folded into the session peak."""
        try:
            live = int(sum(int(e.nbytes) for e in store._cache.values()))
        except Exception:
            return 0
        if live > self.peak_device_bytes:
            self.peak_device_bytes = live
        span.set(device_live_bytes=live)
        return live

    def observe_rss(self) -> int:
        """Process peak RSS in bytes (``ru_maxrss`` is KiB on Linux)."""
        try:
            rss = int(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss) * 1024
        except Exception:
            return self.peak_rss_bytes
        if rss > self.peak_rss_bytes:
            self.peak_rss_bytes = rss
        return rss

    # -- kernel cost attribution -------------------------------------------

    def attribute_kernel(self, key: Any, fn: Any, *args: Any) -> Dict[str, Any]:
        """Predicted cost of the compiled bucket ``key``: lower ``fn`` on
        ``args`` (abstract — no execution), analyze the HLO, fold through
        the roofline.  Computed once per key; call sites invoke this from
        the same first-call branch that owns the ``kernel.compile`` span,
        so steady-state evals never pay for lowering."""
        skey = _key_str(key)
        cached = self.kernel_costs.get(skey)
        if cached is not None:
            return cached
        cost: Dict[str, Any] = {"flops": 0.0, "bytes": 0.0,
                                "t_bound_us": 0.0, "dominant": "unknown"}
        try:
            from ..launch.hlo_analysis import RooflineTerms
            from ..launch.hlo_cost import analyze_hlo_text
            text = fn.lower(*args).as_text(dialect="hlo")
            info = analyze_hlo_text(text)
            terms = RooflineTerms(
                device_flops=float(info["flops"]),
                device_bytes=float(info["bytes"]),
                device_coll_bytes=float(info["collective_bytes_total"]))
            cost = {
                "flops": float(info["flops"]),
                "bytes": float(info["bytes"]),
                "bytes_xla_convention": float(info["bytes_xla_convention"]),
                "t_bound_us": float(terms.t_bound) * 1e6,
                "dominant": terms.dominant,
            }
            if info.get("warnings"):
                cost["warnings"] = list(info["warnings"])
        except Exception as e:  # profiling must never break serving
            cost["cost_error"] = type(e).__name__
        self.kernel_costs[skey] = cost
        return cost

    def stamp_kernel(self, span: Any, key: Any) -> None:
        """Write the bucket's predicted cost onto a ``kernel.eval`` span
        (no-op until ``attribute_kernel`` ran for the key — i.e. before
        the first call compiled the bucket, which cannot happen since the
        first call attributes before it evaluates)."""
        c = self.kernel_costs.get(_key_str(key))
        if c is None:
            return
        span.set(kernel_key=_key_str(key),
                 cost_flops=c["flops"], cost_bytes=c["bytes"],
                 cost_t_bound_us=c["t_bound_us"],
                 cost_dominant=c["dominant"])

    # -- snapshot ----------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        self.observe_rss()
        return {
            "enabled": True,
            "peak_rss_bytes": self.peak_rss_bytes,
            "peak_device_bytes": self.peak_device_bytes,
            "kernel_costs": {k: dict(v) for k, v in self.kernel_costs.items()},
        }


class SloBurnMonitor:
    """Rolling-window error-budget burn per SLO class.

    Each completion lands as ``observe(slo_class, met)``; the window holds
    the last ``window`` outcomes per class.  Burn rate is

        burn = miss_fraction(window) / error_budget

    burn == 1 means deadline misses exactly consume the budget; burn > 1
    means the budget is burning faster than it accrues (alert-worthy);
    burn == 0 means a clean window.  Shed/rejected requests are not
    deadline outcomes and do not enter the window — shedding is the
    mechanism that *protects* the budget, accounted separately by the
    frontend's shed counters.
    """

    def __init__(self, window: int = 100, error_budget: float = 0.01):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if not (0.0 < error_budget <= 1.0):
            raise ValueError(f"error_budget must be in (0, 1], "
                             f"got {error_budget}")
        self.window = int(window)
        self.error_budget = float(error_budget)
        self._events: Dict[str, Deque[bool]] = {}

    def observe(self, slo_class: str, met: bool) -> None:
        dq = self._events.get(slo_class)
        if dq is None:
            dq = self._events[slo_class] = collections.deque(
                maxlen=self.window)
        dq.append(bool(met))

    def miss_fraction(self, slo_class: str) -> float:
        dq = self._events.get(slo_class)
        if not dq:
            return 0.0
        return sum(1 for met in dq if not met) / len(dq)

    def burn_rate(self, slo_class: str) -> float:
        return self.miss_fraction(slo_class) / self.error_budget

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        out: Dict[str, Dict[str, Any]] = {}
        for cls, dq in self._events.items():
            misses = sum(1 for met in dq if not met)
            out[cls] = {
                "window": len(dq),
                "misses": misses,
                "miss_fraction": misses / len(dq) if dq else 0.0,
                "burn_rate": self.burn_rate(cls),
                "error_budget": self.error_budget,
            }
        return out


def resource_profile_snapshot(session: Any) -> Dict[str, Any]:
    """The serve-JSON ``profile`` block (schema_version 3): session peaks,
    per-kernel predicted costs, tier byte flows, and SLO burn."""
    prof = getattr(session, "profiler", NULL_PROFILER)
    block: Dict[str, Any] = {"enabled": bool(prof.enabled)}
    if not prof.enabled:
        return block
    block.update(prof.snapshot())
    ls = getattr(session, "load_stats", None)
    if ls is not None:
        block["bytes"] = {
            "cold": int(ls.bytes_cold),
            "prefetched": int(ls.bytes_prefetched),
            "disk": int(ls.bytes_disk),
            "host": int(getattr(ls, "bytes_host", 0)),
        }
        backing = getattr(getattr(session, "store", None), "backing", None)
        if backing is not None and hasattr(backing, "bytes_read"):
            block["bytes"]["disk_catalog"] = int(backing.bytes_read)
    burn = getattr(session, "_slo_burn", None)
    if burn:
        block["slo_burn"] = dict(burn)
    return block
