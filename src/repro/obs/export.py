"""Exporters: Chrome trace-event JSON, Prometheus text, report snapshot.

Three consumers, three formats, one source of truth (a ``Tracer`` and a
``MetricsRegistry``):

  Chrome trace-event JSON — load the file in Perfetto / chrome://tracing.
      Spans become "X" (complete) events laid out in one *lane* (tid)
      per subsystem — frontend admission, scheduler rounds, store
      loads, kernel eval, compaction — so a query's decomposition reads
      top-to-bottom: root query span, the scheduler rounds under it,
      each round's store load (tagged cold/warm/prefetch/disk) and
      kernel eval, overlay rebuilds and compactions in the delta lane.
      Decision records become "i" (instant) events carrying their full
      payload in ``args``; span/parent ids ride in ``args`` too so
      ``tools/trace_report.py`` can rebuild the tree exactly.

  Prometheus text exposition — `# HELP`/`# TYPE` + samples, histograms
      with cumulative ``le`` buckets, written to a file for scrape-less
      collection (CI uploads it as an artifact).

  observability snapshot — the JSON-safe dict serve.py merges into its
      report under ``"observability"`` (metrics snapshot + span totals
      + decision counts), versioned by the report's ``schema_version``.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from .metrics import MetricsRegistry
from .trace import Tracer

# span-name prefix → Chrome lane (tid).  Order = top-to-bottom layout.
LANES = (
    ("query", "queries"),
    ("frontend.", "frontend admission"),
    ("scheduler.", "scheduler rounds"),
    ("opat.", "scheduler rounds"),
    ("engine.", "scheduler rounds"),
    ("store.", "store loads"),
    ("kernel.", "kernel eval"),
    ("deltas.", "compaction"),
)
_LANE_ORDER = ["queries", "frontend admission", "scheduler rounds",
               "store loads", "kernel eval", "compaction", "other"]


def _lane(name: str) -> str:
    for prefix, lane in LANES:
        if name == prefix or name.startswith(prefix):
            return lane
    return "other"


def _decision_lane(kind: str) -> str:
    return "frontend admission" if kind.startswith("frontend.") \
        else "scheduler rounds"


def _json_safe(v: Any) -> Any:
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    if isinstance(v, (str, int, bool)) or v is None:
        return v
    if isinstance(v, float):
        return v
    if hasattr(v, "item"):           # numpy / jax scalars
        try:
            return _json_safe(v.item())
        except Exception:
            pass
    return str(v)


def to_chrome_trace(tracer: Tracer, pid: int = 1) -> Dict[str, Any]:
    """Render a tracer's spans + decisions as a Chrome trace-event
    object (``{"traceEvents": [...]}``) loadable in Perfetto.
    Timestamps are microseconds relative to the tracer's epoch."""
    epoch = tracer.t_epoch
    events: List[Dict[str, Any]] = []
    tids: Dict[str, int] = {}

    def tid_for(lane: str) -> int:
        if lane not in tids:
            try:
                tids[lane] = _LANE_ORDER.index(lane) + 1
            except ValueError:
                tids[lane] = len(_LANE_ORDER) + len(tids) + 1
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tids[lane], "args": {"name": lane}})
            events.append({"ph": "M", "name": "thread_sort_index",
                           "pid": pid, "tid": tids[lane],
                           "args": {"sort_index": tids[lane]}})
        return tids[lane]

    events.append({"ph": "M", "name": "process_name", "pid": pid,
                   "tid": 0, "args": {"name": "repro serve"}})

    for sp in tracer.spans:
        t1 = sp.t1 if sp.t1 is not None else sp.t0
        args = {"span_id": sp.span_id, "parent_id": sp.parent_id,
                "thread": sp.thread}
        args.update(_json_safe(sp.attrs))
        events.append({
            "ph": "X", "name": sp.name, "cat": _lane(sp.name),
            "pid": pid, "tid": tid_for(_lane(sp.name)),
            "ts": round((sp.t0 - epoch) * 1e6, 3),
            "dur": round(max(t1 - sp.t0, 0.0) * 1e6, 3),
            "args": args,
        })

    for rec in tracer.decisions:
        kind = rec.get("kind", "decision")
        args = _json_safe({k: v for k, v in rec.items()
                           if k not in ("kind", "ts")})
        events.append({
            "ph": "i", "name": kind, "cat": "decision", "s": "t",
            "pid": pid, "tid": tid_for(_decision_lane(kind)),
            "ts": round((rec["ts"] - epoch) * 1e6, 3),
            "args": args,
        })

    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(tracer: Tracer, path: str) -> None:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(tracer), f)


def to_prometheus_text(reg: MetricsRegistry) -> str:
    """Prometheus text exposition (0.0.4): HELP/TYPE headers once per
    metric name, histograms with cumulative ``le`` buckets + +Inf."""
    lines: List[str] = []
    seen_header: set = set()

    def fmt_labels(labels: Dict[str, str], extra: Optional[Dict] = None
                   ) -> str:
        items = dict(labels)
        if extra:
            items.update(extra)
        if not items:
            return ""
        body = ",".join(f'{k}="{v}"' for k, v in sorted(items.items()))
        return "{" + body + "}"

    def fmt_val(v: float) -> str:
        return str(int(v)) if float(v).is_integer() else repr(float(v))

    for m, labels in reg.collect():
        if m.name not in seen_header:
            seen_header.add(m.name)
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
        if m.kind == "histogram":
            acc = 0
            for b, c in zip(m.buckets, m.counts):
                acc += c
                lines.append(
                    f"{m.name}_bucket"
                    f"{fmt_labels(labels, {'le': fmt_val(b)})} {acc}")
            lines.append(
                f"{m.name}_bucket{fmt_labels(labels, {'le': '+Inf'})} "
                f"{m.count}")
            lines.append(f"{m.name}_sum{fmt_labels(labels)} "
                         f"{fmt_val(m.sum)}")
            lines.append(f"{m.name}_count{fmt_labels(labels)} {m.count}")
        else:
            lines.append(f"{m.name}{fmt_labels(labels)} "
                         f"{fmt_val(m.value)}")
    return "\n".join(lines) + "\n"


def write_prometheus(reg: MetricsRegistry, path: str) -> None:
    with open(path, "w") as f:
        f.write(to_prometheus_text(reg))


def observability_snapshot(tracer: Optional[Tracer] = None,
                           registry: Optional[MetricsRegistry] = None
                           ) -> Dict[str, Any]:
    """The ``"observability"`` block of serve's JSON report: always
    present (schema_version 2), with ``enabled`` telling a parser
    whether span data exists or only ingested metrics."""
    enabled = bool(tracer is not None and tracer.enabled)
    block: Dict[str, Any] = {"enabled": enabled}
    if registry is not None:
        block["metrics"] = registry.snapshot()
    if enabled:
        decisions: Dict[str, int] = {}
        for rec in tracer.decisions:
            k = rec.get("kind", "decision")
            decisions[k] = decisions.get(k, 0) + 1
        block["spans"] = {
            name: {"count": int(agg["count"]),
                   "total_s": round(agg["total_s"], 6)}
            for name, agg in sorted(tracer.span_totals().items())}
        block["decisions"] = decisions
    return block
