"""Zero-dependency observability: span tracing, a unified metrics
registry, and trace exporters.

Three modules, one contract (docs/observability.md):

  trace.py   — ``Tracer``: low-overhead span context managers with
               ids/parents/monotonic timestamps/attributes, plus
               *decision records* (heuristic score breakdowns, frontend
               admission inputs).  The disabled path is ``NULL_TRACER``,
               a no-op singleton hot loops pay ~nothing for.
  metrics.py — ``MetricsRegistry``: counters/gauges/histograms that
               absorb the ad-hoc counters scattered across the store,
               host cache, delta layer, scheduler, and serving front
               end into one exportable namespace.
  profile.py — ``ResourceProfiler``: memory accounting (device
               live-bytes per span, session peak RSS/device), kernel
               cost attribution (HLO FLOPs/bytes joined with measured
               eval time via ``tools/trace_report.py --cost``), and
               ``SloBurnMonitor`` rolling error-budget burn.  Disabled
               path: ``NULL_PROFILER``, same discipline as the tracer.
  export.py  — three exporters: Chrome trace-event JSON (Perfetto),
               Prometheus text exposition, and a structured snapshot
               merged into serve's JSON report.

``tools/trace_report.py`` consumes the Chrome trace to answer "what
dominated this query's latency?" and "why was P3 loaded before P1?"
from the trace file alone.
"""
from .metrics import Counter, Gauge, Histogram, MetricsRegistry, \
    ingest_frontend, ingest_load_stats, ingest_schedule, ingest_session, \
    validate_residency
from .trace import NULL_TRACER, NullTracer, Span, Tracer
from .profile import NULL_PROFILER, NullResourceProfiler, \
    ResourceProfiler, SloBurnMonitor, resource_profile_snapshot
from .export import observability_snapshot, to_chrome_trace, \
    to_prometheus_text, write_chrome_trace, write_prometheus

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "Span",
    "ResourceProfiler", "NullResourceProfiler", "NULL_PROFILER",
    "SloBurnMonitor", "resource_profile_snapshot",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "ingest_frontend", "ingest_load_stats", "ingest_schedule",
    "ingest_session", "validate_residency",
    "to_chrome_trace", "write_chrome_trace", "to_prometheus_text",
    "write_prometheus", "observability_snapshot",
]
