"""A unified metrics registry for the serving stack's ad-hoc counters.

Counters with the same meaning live all over the repo under different
names and shapes: ``LoadStats`` fields on the store (cold/warm/prefetch/
disk/read-ahead, core/store.py + storage/host_cache.py), pending-delta
and compaction counts on the mutable directory (storage/deltas.py),
round/batch-occupancy lists on the scheduler (core/scheduler.py), and
admit/degrade/defer/shed dicts on the serving front end
(serving/frontend.py).  This module gives them ONE namespace —
``repro_<subsystem>_<what>`` — without rewriting any hot path: the
sources keep their counters (every existing test and report stays
valid), and ``ingest_*`` absorbs them into the registry at snapshot
time.  Exporters (obs/export.py) then see one flat, label-aware
metric space regardless of which subsystems ran.

Three instrument kinds, deliberately minimal:

  Counter   — monotone total (``inc``); ingestion ``set_total``s it to
              the source's absolute value.
  Gauge     — last-write-wins level (``set``).
  Histogram — fixed-bucket counts + sum (``observe``), Prometheus
              cumulative-bucket semantics on export.

Everything is plain Python; thread safety is a single lock per registry
(ingestion and exporting are report-time operations, never hot).
"""
from __future__ import annotations

import bisect
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0)


def _labelkey(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """A monotone total."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def set_total(self, v: float) -> None:
        """Absorb an externally maintained absolute total (ingestion:
        the source counter is authoritative, the registry mirrors it)."""
        self.value = float(v)


class Gauge:
    """A level: last write wins."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Histogram:
    """Fixed upper-bound buckets, a count, and a sum."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self.counts = [0] * len(self.buckets)   # per-bucket (non-cumulative)
        self.overflow = 0                        # > last bucket (+Inf lane)
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        i = bisect.bisect_left(self.buckets, v)
        if i < len(self.buckets):
            self.counts[i] += 1
        else:
            self.overflow += 1

    def cumulative(self) -> List[Tuple[float, int]]:
        """Prometheus ``le`` semantics: (upper_bound, cumulative count)."""
        out, acc = [], 0
        for b, c in zip(self.buckets, self.counts):
            acc += c
            out.append((b, acc))
        return out


class MetricsRegistry:
    """Name+labels → instrument.  ``counter``/``gauge``/``histogram``
    create on first use and return the live instrument."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, Tuple], Any] = {}
        self._help: Dict[str, str] = {}
        self._labels: Dict[Tuple[str, Tuple], Dict[str, str]] = {}

    def _get(self, cls, name: str, help: str, labels: Dict[str, str],
             **kw: Any):
        key = (name, _labelkey(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, help=help, **kw)
                self._metrics[key] = m
                self._labels[key] = dict(labels)
                if help:
                    self._help.setdefault(name, help)
            return m

    def counter(self, name: str, help: str = "", **labels: str) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels: str) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  **labels: str) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def collect(self) -> List[Tuple[Any, Dict[str, str]]]:
        """Every (instrument, labels) pair, stable name-then-label order."""
        with self._lock:
            keys = sorted(self._metrics, key=lambda k: (k[0], k[1]))
            return [(self._metrics[k], dict(self._labels[k])) for k in keys]

    def snapshot(self) -> Dict[str, Any]:
        """A JSON-safe dump: scalar metrics flat (labelled ones keyed
        ``name{k=v}``), histograms as bucket/count/sum dicts."""
        out: Dict[str, Any] = {}
        for m, labels in self.collect():
            key = m.name if not labels else (
                m.name + "{" + ",".join(f"{k}={v}" for k, v in
                                        sorted(labels.items())) + "}")
            if m.kind == "histogram":
                out[key] = {"count": m.count, "sum": m.sum,
                            "buckets": {str(b): c for b, c
                                        in m.cumulative()},
                            "overflow": m.overflow}
            else:
                v = m.value
                out[key] = int(v) if float(v).is_integer() else v
        return out


# -- ingestion: absorb the repo's existing ad-hoc counters ------------------

_LOAD_STAT_METRICS = (
    # (LoadStats field, unified metric name, help)
    ("hits", "repro_store_warm_loads_total",
     "device-cache hits (entry already resident)"),
    ("misses", "repro_store_cold_loads_total",
     "device-cache misses (device_put on the critical path)"),
    ("evictions", "repro_store_evictions_total",
     "device-LRU entries dropped to fit capacity"),
    ("prefetch_issued", "repro_store_prefetch_issued_total",
     "prefetch() calls that actually staged"),
    ("prefetch_hits", "repro_store_prefetch_hits_total",
     "gets served by a previously prefetched entry"),
    ("released", "repro_store_released_total",
     "entries explicitly release()d (scheduler retirement)"),
    ("bytes_cold", "repro_store_bytes_cold_total",
     "bytes transferred by cold loads"),
    ("bytes_prefetched", "repro_store_bytes_prefetched_total",
     "bytes transferred off the critical path"),
    ("disk_reads", "repro_store_disk_reads_total",
     "shard reads issued against the disk tier"),
    ("read_ahead_issued", "repro_store_read_ahead_issued_total",
     "background-thread shard reads started"),
    ("read_ahead_hits", "repro_store_read_ahead_hits_total",
     "host gets served by a completed/in-flight read-ahead"),
    ("bytes_disk", "repro_store_bytes_disk_total",
     "bytes read off disk (demand + read-ahead)"),
    ("bytes_host", "repro_store_host_bytes_total",
     "bytes served out of the host LRU tier to device staging"),
    ("host_evictions", "repro_store_host_evictions_total",
     "host-LRU entries dropped to fit capacity"),
    ("delta_overlays", "repro_deltas_overlay_rebuilds_total",
     "bundles rebuilt from a generation view's delta overlay"),
)


def ingest_load_stats(reg: MetricsRegistry, stats: Any) -> None:
    """Absorb a ``LoadStats`` (core/store.py) into the unified namespace."""
    for field, name, help in _LOAD_STAT_METRICS:
        reg.counter(name, help=help).set_total(getattr(stats, field))


def ingest_schedule(reg: MetricsRegistry, loads: Sequence[int],
                    batch_sizes: Sequence[int]) -> None:
    """Absorb a scheduler's workload-level load sequence: total rounds
    plus the batch-occupancy histogram (jobs advanced per load)."""
    reg.counter("repro_scheduler_loads_total",
                help="workload-level partition loads").set_total(len(loads))
    h = reg.histogram("repro_scheduler_batch_occupancy",
                      help="jobs advanced per workload-level load",
                      buckets=(1, 2, 4, 8, 16, 32, 64))
    for b in batch_sizes:
        h.observe(b)


def ingest_frontend(reg: MetricsRegistry, counters: Dict[str, int],
                    shed_by_reason: Dict[str, int]) -> None:
    """Absorb the serving front end's admission/degrade/defer/shed
    counters (per run; serve.py calls this once after ``serve``)."""
    for key, n in sorted(counters.items()):
        reg.counter(f"repro_frontend_{key}_total",
                    help=f"front-end requests {key}").set_total(n)
    for reason, n in sorted(shed_by_reason.items()):
        reg.counter("repro_frontend_shed_reason_total",
                    help="sheds by reason", reason=reason).set_total(n)


def ingest_session(reg: MetricsRegistry, session: Any) -> None:
    """One call absorbs everything a ``GraphSession`` can observe: its
    store's ``LoadStats``, the delta layer's write-pressure counters,
    per-session serving totals, and (if the session served SLO traffic)
    the front-end counters it accumulated."""
    ingest_load_stats(reg, session.load_stats)
    reg.counter("repro_session_queries_served_total",
                help="queries absorbed into the workload profile"
                ).set_total(session._queries_served)
    reg.counter("repro_session_answers_served_total",
                help="answer rows returned").set_total(
                    session._answers_served)
    mdir = getattr(session, "_mdir", None)
    if mdir is not None:
        reg.gauge("repro_deltas_generation",
                  help="latest published shard generation").set(
                      mdir.generation)
        reg.gauge("repro_deltas_pending",
                  help="delta records not yet folded").set(
                      int(mdir.pending_counts().sum()))
        reg.counter("repro_deltas_compactions_total",
                    help="log->shard folds published").set_total(
                        mdir.compactions)
    backing = getattr(getattr(session, "store", None), "backing", None)
    if backing is not None and hasattr(backing, "bytes_read"):
        reg.counter("repro_store_disk_bytes_total",
                    help="bytes the disk catalog deserialized (demand + "
                         "read-ahead + overlay rebuild source reads)"
                    ).set_total(backing.bytes_read)
    prof = getattr(session, "profiler", None)
    if prof is not None and getattr(prof, "enabled", False):
        prof.observe_rss()
        reg.gauge("repro_session_peak_rss_bytes",
                  help="process peak RSS observed (ru_maxrss)").set(
                      prof.peak_rss_bytes)
        reg.gauge("repro_session_peak_device_bytes",
                  help="peak live device bytes held by the partition "
                       "store").set(prof.peak_device_bytes)
    for cls, snap in sorted(getattr(session, "_slo_burn", {}).items()):
        reg.gauge("repro_frontend_slo_burn_rate",
                  help="rolling-window error-budget burn rate per SLO "
                       "class (miss_fraction / error_budget; >1 means "
                       "the budget burns faster than it accrues)",
                  slo_class=cls).set(float(snap.get("burn_rate", 0.0)))
    if session._slo_counters or session._slo_shed_reasons:
        ingest_frontend(reg, session._slo_counters,
                        session._slo_shed_reasons)


def validate_residency(cold: Optional[int], warm: Optional[int],
                       prefetch_hits: Optional[int],
                       n_loads: int) -> Dict[str, int]:
    """The residency classification invariant, shared by ``RunStats``
    validation (core/metrics.py) and the benchmarks: every recorded
    partition load is exactly one of {cold, demand-warm, prefetch-hit}
    (``warm_loads`` INCLUDES prefetch hits by definition, so the
    disjoint classes are cold + (warm − prefetch_hits) + prefetch_hits
    and must sum to ``n_loads``).  Returns the classified counts;
    raises ``ValueError`` on miscounted instrumentation."""
    if cold is None or warm is None:
        raise ValueError("residency counters absent")
    ph = int(prefetch_hits or 0)
    cold, warm = int(cold), int(warm)
    if min(cold, warm, ph) < 0:
        raise ValueError(
            f"negative residency counter: cold={cold} warm={warm} "
            f"prefetch_hits={ph}")
    if ph > warm:
        raise ValueError(
            f"prefetch_hits ({ph}) exceed warm_loads ({warm}) — a "
            f"prefetch hit must also count as a warm load")
    if cold + (warm - ph) + ph != n_loads:
        raise ValueError(
            f"cold_loads + warm_loads + prefetch_hits classification "
            f"does not cover the load sequence: cold={cold} + "
            f"demand_warm={warm - ph} + prefetch_hits={ph} != "
            f"n_loads={n_loads}")
    return {"cold": cold, "demand_warm": warm - ph, "prefetch_hits": ph,
            "n_loads": n_loads}
