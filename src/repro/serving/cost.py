"""Admission-time cost prediction from catalog/manifest statistics.

The paper's thesis is that query properties and partition characteristics
can be *correlated in advance* to bound processing time "in terms of the
resources available" (Sec. 1): the number of start-node instances (SNI)
says how much frontier a partition seeds, the connected-component count
(CC) says how fragmented the partition's intra-edges are (Sec. 5.2), and
the set of *required* partitions bounds the load sequence (L_ideal).  All
three are answerable without touching a partition: the in-RAM path reads
whole-graph arrays + the assignment, and the out-of-core path reads the
manifest's per-partition label histograms and ``components`` field
(storage/format.py) — so a ``CostModel`` can price a query *before
admission* even when every shard is still on disk.

``predict`` maps those statistics to abstract *work units*
(``work_units`` below: required partitions weighted by their CC, plus the
SNI mass they seed, scaled by plan length and the answer budget K), then
to seconds through a per-bucket rate table calibrated online: every
observed ``QueryResult`` latency updates an EWMA of seconds-per-unit in
the bucket ``log2(units)`` (near-constant per-query overheads make small
queries pay a different rate than big ones — bucketing keeps both
honest).  An uncalibrated model prices with ``default_rate_s``; the
serving front end (serving/frontend.py) feeds observations back after
every completion, so the estimate converges while traffic flows.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.plan import Plan, generate_plan
from ..core.query import DisjunctiveQuery, Query


def required_partition_mask(pg, plan: Plan) -> np.ndarray:
    """[k] bool: partitions holding at least one node matching ANY query
    node predicate — the same "required partition" set ``l_ideal_for_plan``
    counts (core/metrics.py), kept as a mask so the per-partition CC
    weights can be applied.  Catalog/manifest-only; never reads a shard."""
    from ..core.graph import WILDCARD
    from ..core.query import OP_BY_NAME
    g = pg.graph
    required = np.zeros(pg.k, dtype=bool)
    for qn in plan.query.nodes:
        lid = WILDCARD if qn.label == "?" else g.node_vocab.get(qn.label, -3)
        counts = pg.start_label_counts(lid, OP_BY_NAME[qn.value_op],
                                       float(qn.value))
        required |= counts > 0
    return required


def work_units(sni_counts: np.ndarray, components: np.ndarray,
               required: np.ndarray, n_steps: int = 1, *,
               cc_gain: float = 0.5, sni_gain: float = 0.05,
               step_gain: float = 0.25) -> float:
    """Abstract work for one plan: each required partition costs one load
    plus ``cc_gain`` per extra connected component (fragmented partitions
    re-enter the load sequence, paper Fig. 4c / Sec. 5.2), the seeded SNI
    mass costs ``sni_gain`` per row, and every extra plan step multiplies
    the whole thing (longer plans expand more frontiers per load).

    Monotone by construction: non-decreasing in every SNI count, every
    required partition's CC, the size of the required set, and the plan
    length — the properties tests/test_serving_frontend.py pins down.
    """
    req = np.asarray(required, dtype=bool)
    cc = np.maximum(np.asarray(components, dtype=np.float64), 1.0)
    base = float(np.sum(1.0 + cc_gain * (cc[req] - 1.0)))
    seeded = float(np.sum(np.asarray(sni_counts, dtype=np.float64)[req]))
    return (base + sni_gain * seeded) * (1.0 + step_gain * max(0, n_steps - 1))


@dataclasses.dataclass
class CostEstimate:
    """One query's admission-time price: predicted loads and latency plus
    the calibration bucket the prediction was read from."""

    work_units: float
    loads: int                     # predicted partition loads (Σ_d |required_d|)
    latency_s: float
    bucket: int                    # log2 work-unit bucket of the rate used
    rate_s: float                  # seconds-per-unit applied
    calibrated: bool               # False: default_rate_s (no observations yet)
    max_answers: Optional[int]     # budget K the estimate was priced under


class CostModel:
    """Predict-then-calibrate latency model over one partitioned graph.

    ``pg`` needs only the catalog surface (``k``, ``start_label_counts``,
    ``connected_components_per_partition``) — an
    ``OutOfCorePartitionedGraph`` answers all three from its manifest.
    ``alpha`` is the EWMA weight of each new observation; ``default_rate_s``
    prices queries before any observation lands.  ``observe`` is cheap and
    thread-free; the serving front end calls it once per completion.
    """

    def __init__(self, pg, *, alpha: float = 0.3,
                 default_rate_s: float = 2e-4,
                 cc_gain: float = 0.5, sni_gain: float = 0.05,
                 step_gain: float = 0.25,
                 min_budget_frac: float = 0.05):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.pg = pg
        self.alpha = float(alpha)
        self.default_rate_s = float(default_rate_s)
        self.cc_gain = float(cc_gain)
        self.sni_gain = float(sni_gain)
        self.step_gain = float(step_gain)
        self.min_budget_frac = float(min_budget_frac)
        # per-partition CC is layout-static: one catalog/manifest read
        self._cc = np.asarray(pg.connected_components_per_partition(),
                              dtype=np.int64)
        self._rates: Dict[int, float] = {}     # bucket -> EWMA seconds/unit
        self._observations = 0

    # -- prediction ---------------------------------------------------------

    @property
    def calibrated(self) -> bool:
        return bool(self._rates)

    @property
    def observations(self) -> int:
        return self._observations

    def _budget_factor(self, plan: Plan,
                       max_answers: Optional[int]) -> float:
        """K answers out of an estimated ``plan.est_cost`` total shrink the
        expected work proportionally (the paper's budgeted runs stop after
        K uniques), floored so a tiny K never predicts free."""
        if max_answers is None:
            return 1.0
        if max_answers <= 0:
            return 0.0
        frac = max_answers / max(1.0, float(plan.est_cost))
        return max(self.min_budget_frac, min(1.0, frac))

    def plan_units(self, plan: Plan,
                   max_answers: Optional[int] = None) -> float:
        """Work units for one disjunct's plan (catalog statistics only)."""
        sni = self.pg.start_label_counts(plan.start_label,
                                         plan.start_value_op,
                                         plan.start_value)
        required = required_partition_mask(self.pg, plan)
        units = work_units(sni, self._cc, required, plan.n_steps,
                           cc_gain=self.cc_gain, sni_gain=self.sni_gain,
                           step_gain=self.step_gain)
        return units * self._budget_factor(plan, max_answers)

    def predict_plans(self, plans: Sequence[Plan],
                      max_answers: Optional[int] = None) -> CostEstimate:
        """Price a query given its per-disjunct plans (the budget K applies
        per disjunct, matching ``submit`` semantics)."""
        units = sum(self.plan_units(p, max_answers) for p in plans)
        loads = sum(int(required_partition_mask(self.pg, p).sum())
                    for p in plans)
        bucket = self._bucket(units)
        rate, calibrated = self._rate_for(bucket)
        return CostEstimate(work_units=units, loads=loads,
                            latency_s=units * rate, bucket=bucket,
                            rate_s=rate, calibrated=calibrated,
                            max_answers=max_answers)

    def predict(self, query: Union[Query, DisjunctiveQuery], graph, catalog,
                max_answers: Optional[int] = None) -> CostEstimate:
        """Convenience: plan the query's disjuncts and price them."""
        disjuncts = (query.disjuncts if isinstance(query, DisjunctiveQuery)
                     else [query])
        plans = [generate_plan(q, graph, catalog) for q in disjuncts]
        return self.predict_plans(plans, max_answers)

    # -- online calibration -------------------------------------------------

    @staticmethod
    def _bucket(units: float) -> int:
        return int(math.log2(max(units, 0.0) + 1.0))

    def _rate_for(self, bucket: int) -> Tuple[float, bool]:
        """(seconds-per-unit, calibrated?) for a bucket: the bucket's own
        EWMA, else the nearest observed bucket's (small-to-large latency
        structure is smooth enough that a neighbour beats the static
        default), else ``default_rate_s``."""
        if bucket in self._rates:
            return self._rates[bucket], True
        if self._rates:
            nearest = min(self._rates, key=lambda b: (abs(b - bucket), b))
            return self._rates[nearest], True
        return self.default_rate_s, False

    def observe(self, estimate: CostEstimate, latency_s: float) -> float:
        """Fold one observed (estimate, latency) pair into the bucket's
        EWMA rate; returns the updated seconds-per-unit."""
        if latency_s < 0:
            raise ValueError(f"latency_s must be >= 0, got {latency_s}")
        units = max(estimate.work_units, 1e-9)
        rate_obs = latency_s / units
        bucket = estimate.bucket
        old = self._rates.get(bucket)
        new = rate_obs if old is None else \
            (1.0 - self.alpha) * old + self.alpha * rate_obs
        self._rates[bucket] = new
        self._observations += 1
        return new

    def snapshot(self) -> Dict[str, object]:
        """Observability: the rate table and counters (serve --json)."""
        return {"observations": self._observations,
                "default_rate_s": self.default_rate_s,
                "rates_s_per_unit": {str(b): self._rates[b]
                                     for b in sorted(self._rates)}}
