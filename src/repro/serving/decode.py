"""Serving: prefill + single-token decode against persistent caches.

Cache kinds per block:
  attn   : full KV cache [B, Smax, Hkv, hd] (RoPE applied at write time)
  local  : ring KV cache [B, W, Hkv, hd], W = local_window (RoPE at write)
  rglru  : {h [B,w] f32, conv [B,cw-1,w]}
  mlstm  : {C [B,H,hk,hv] f32, n, m, conv}
  slstm  : {c, n, m, h [B,H,hd] f32}

``decode_step`` lowers one new token against a seq_len cache — the shape
the ``decode_*`` / ``long_*`` dry-run cells require.  Recurrent families
(xlstm, recurrentgemma) carry O(1)/O(window) state, which is exactly why
they are the only families that run the ``long_500k`` cell (DESIGN.md).

The cache tree mirrors the parameter tree segments (head list / stacked
body periods / tail list) so the decode body is a single ``lax.scan`` over
periods, keeping compile time depth-independent.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import rglru as rg
from ..models import xlstm as xl
from ..models.config import (BLOCK_ATTN, BLOCK_LOCAL_ATTN, BLOCK_MLSTM, BLOCK_RECURRENT,
                             BLOCK_SLSTM, FAMILY_AUDIO, ModelConfig)
from ..models.layers import apply_rope, flash_attention, local_attention, rms_norm
from ..models.transformer import Params, _apply_ffn, _dtype, _qkv, embed_inputs, stack_segments

Cache = Dict[str, Any]


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def _block_cache(cfg: ModelConfig, kind: str, batch: int, s_max: int):
    dt = _dtype(cfg.compute_dtype)
    Hkv, hd, H = cfg.n_kv_heads, cfg.hd, cfg.n_heads
    if kind == BLOCK_ATTN:
        return {"k": jnp.zeros((batch, s_max, Hkv, hd), dt),
                "v": jnp.zeros((batch, s_max, Hkv, hd), dt)}
    if kind == BLOCK_LOCAL_ATTN:
        W = min(cfg.local_window, s_max)
        return {"k": jnp.zeros((batch, W, Hkv, hd), dt),
                "v": jnp.zeros((batch, W, Hkv, hd), dt)}
    if kind == BLOCK_RECURRENT:
        w = cfg.lru_width or cfg.d_model
        return {"h": jnp.zeros((batch, w), jnp.float32),
                "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dt)}
    if kind == BLOCK_MLSTM:
        up = 2 * cfg.d_model
        hdm = up // H
        return {"C": jnp.zeros((batch, H, hdm, hdm), jnp.float32),
                "n": jnp.zeros((batch, H, hdm), jnp.float32),
                "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
                "conv": jnp.zeros((batch, cfg.conv1d_width - 1, up), dt)}
    if kind == BLOCK_SLSTM:
        hds = cfg.d_model // H
        return {"c": jnp.zeros((batch, H, hds), jnp.float32),
                "n": jnp.zeros((batch, H, hds), jnp.float32),
                "m": jnp.full((batch, H, hds), -jnp.inf, jnp.float32),
                "h": jnp.zeros((batch, H, hds), jnp.float32)}
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, batch: int, s_max: int) -> Cache:
    head, body, tail = stack_segments(cfg)
    c: Cache = {}
    if head:
        c["head_layers"] = [_block_cache(cfg, cfg.block_kind(i), batch, s_max)
                            for i in head]
    if body:
        kinds = [cfg.block_kind(i) for i in body[0]]
        c["body"] = [jax.tree.map(
            lambda x: jnp.broadcast_to(x, (len(body),) + x.shape).copy(),
            _block_cache(cfg, k, batch, s_max)) for k in kinds]
    if tail:
        c["tail_layers"] = [_block_cache(cfg, cfg.block_kind(i), batch, s_max)
                            for i in tail]
    return c


def abstract_caches(cfg: ModelConfig, batch: int, s_max: int) -> Cache:
    return jax.eval_shape(lambda: init_caches(cfg, batch, s_max))


# ---------------------------------------------------------------------------
# Single-token block application
# ---------------------------------------------------------------------------

def _decode_full_attn(p, cfg: ModelConfig, x, cache, pos, layer_is_moe):
    """x [B,1,d]; full-cache attention at absolute position ``pos``."""
    B = x.shape[0]
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, h)                       # [B,1,H,hd]/[B,1,Hkv,hd]
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, pos, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, pos, 0, 0))
    S = kc.shape[1]
    Hkv, hd, H = cfg.n_kv_heads, cfg.hd, cfg.n_heads
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) / np.sqrt(hd)
    mask = jnp.arange(S) <= pos
    s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    attn = jnp.einsum("bhgs,bshd->bhgd", pr, vc.astype(jnp.float32))
    attn = attn.reshape(B, 1, H, hd).astype(x.dtype)
    x = x + jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    y, _ = _apply_ffn(p["ffn"], cfg, h2, layer_is_moe)
    return x + y, {"k": kc, "v": vc}


def _decode_local_attn(p, cfg: ModelConfig, x, cache, pos, layer_is_moe):
    """Ring-cache sliding-window attention (slot = pos mod W)."""
    B = x.shape[0]
    W = cache["k"].shape[1]
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, h)
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    slot = jnp.mod(pos, W)
    kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    # absolute position stored in ring slot j
    j = jnp.arange(W)
    base = pos - slot
    abs_pos = jnp.where(j <= slot, base + j, base - W + j)
    valid = (abs_pos >= 0) & (abs_pos <= pos) & (abs_pos > pos - cfg.local_window)
    Hkv, hd, H = cfg.n_kv_heads, cfg.hd, cfg.n_heads
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) / np.sqrt(hd)
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    attn = jnp.einsum("bhgs,bshd->bhgd", pr, vc.astype(jnp.float32))
    attn = attn.reshape(B, 1, H, hd).astype(x.dtype)
    x = x + jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    y, _ = _apply_ffn(p["ffn"], cfg, h2, layer_is_moe)
    return x + y, {"k": kc, "v": vc}


def _decode_rglru(p, cfg: ModelConfig, x, cache):
    state = {"h": cache["h"], "conv": cache["conv"]}
    y, st = rg.rglru_apply(p, x, state)
    if cfg.d_ff:
        h2 = rms_norm(y, p["ln2"], cfg.norm_eps)
        f, _ = _apply_ffn(p["ffn"], cfg, h2, False)
        y = y + f
    return y, {"h": st["h"], "conv": st["conv"].astype(cache["conv"].dtype)}


def decode_block(p, cfg: ModelConfig, kind: str, x, cache, pos,
                 layer_is_moe: bool):
    if kind == BLOCK_ATTN:
        return _decode_full_attn(p, cfg, x, cache, pos, layer_is_moe)
    if kind == BLOCK_LOCAL_ATTN:
        return _decode_local_attn(p, cfg, x, cache, pos, layer_is_moe)
    if kind == BLOCK_RECURRENT:
        return _decode_rglru(p, cfg, x, cache)
    if kind == BLOCK_MLSTM:
        st = {"C": cache["C"], "n": cache["n"], "m": cache["m"],
              "conv": cache["conv"]}
        y, ns = xl.mlstm_apply(p, x, st, n_heads=cfg.n_heads)
        ns["conv"] = ns["conv"].astype(cache["conv"].dtype)
        return y, ns
    if kind == BLOCK_SLSTM:
        st = {"c": cache["c"], "n": cache["n"], "m": cache["m"],
              "h": cache["h"]}
        y, ns = xl.slstm_apply(p, x, st, n_heads=cfg.n_heads)
        return y, ns
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# decode_step: one new token against seq_len caches
# ---------------------------------------------------------------------------

def decode_step(params: Params, cfg: ModelConfig, caches: Cache,
                inputs: Dict[str, jax.Array], pos) -> Tuple[jax.Array, Cache]:
    """inputs: {"token": [B] int32} (or {"frame_embeds": [B, d_frontend]} for
    the audio family).  Returns (logits [B, vocab] f32, new caches)."""
    dt = _dtype(cfg.compute_dtype)
    if cfg.family == FAMILY_AUDIO:
        x = inputs["frame_embeds"][:, None, :].astype(dt) @ \
            params["in_proj"].astype(dt)
    else:
        x = jnp.take(params["embed"], inputs["token"][:, None], axis=0).astype(dt)

    head, body, tail = stack_segments(cfg)
    new_caches: Cache = {}

    if head:
        ncl = []
        for i, li in enumerate(head):
            x, nc = decode_block(params["head_layers"][i], cfg,
                                 cfg.block_kind(li), x,
                                 caches["head_layers"][i], pos,
                                 layer_is_moe=False)
            ncl.append(nc)
        new_caches["head_layers"] = ncl

    if body:
        kinds = [cfg.block_kind(li) for li in body[0]]
        moe_flags = [cfg.is_moe and li >= cfg.first_dense_layers
                     for li in body[0]]

        def scan_body(x, pc):
            period_params, period_caches = pc
            ncs = []
            for j, kind in enumerate(kinds):
                x, nc = decode_block(period_params[j], cfg, kind, x,
                                     period_caches[j], pos,
                                     layer_is_moe=moe_flags[j])
                ncs.append(nc)
            return x, ncs

        x, new_body = jax.lax.scan(scan_body, x,
                                   (params["body"], caches["body"]))
        new_caches["body"] = new_body

    if tail:
        ncl = []
        for i, li in enumerate(tail):
            x, nc = decode_block(params["tail_layers"][i], cfg,
                                 cfg.block_kind(li), x,
                                 caches["tail_layers"][i], pos,
                                 layer_is_moe=cfg.is_moe and li >= cfg.first_dense_layers)
            ncl.append(nc)
        new_caches["tail_layers"] = ncl

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w_out = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w_out.astype(x.dtype))[:, 0]
    return logits.astype(jnp.float32), new_caches


# ---------------------------------------------------------------------------
# prefill: full-sequence forward that also fills the caches
# ---------------------------------------------------------------------------

def _prefill_attn(p, cfg, x, positions, *, local: bool, layer_is_moe: bool,
                  q_chunk: int, moe_fn=None):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, h)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    S = x.shape[1]
    qc = min(q_chunk, S)
    if local:
        attn = local_attention(q, k, v, window=cfg.local_window, q_chunk=qc)
        W = min(cfg.local_window, S)
        cache = {"k": k[:, S - W:], "v": v[:, S - W:]}  # last W positions
        # ring layout: slot = pos mod W; re-roll so slot indices line up
        shift = jnp.mod(S - W, W)
        cache = {kk: jnp.roll(vv, shift, axis=1) for kk, vv in cache.items()}
    else:
        attn = flash_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=qc)
        cache = {"k": k, "v": v}
    x = x + jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    y, _ = _apply_ffn(p["ffn"], cfg, h2, layer_is_moe, moe_fn)
    return x + y, cache


def prefill_block(p, cfg: ModelConfig, kind: str, x, positions,
                  layer_is_moe: bool, q_chunk: int = 512, moe_fn=None):
    if kind == BLOCK_ATTN:
        return _prefill_attn(p, cfg, x, positions, local=False,
                             layer_is_moe=layer_is_moe, q_chunk=q_chunk,
                             moe_fn=moe_fn)
    if kind == BLOCK_LOCAL_ATTN:
        return _prefill_attn(p, cfg, x, positions, local=True,
                             layer_is_moe=layer_is_moe, q_chunk=q_chunk,
                             moe_fn=moe_fn)
    if kind == BLOCK_RECURRENT:
        y, st = rg.rglru_apply(p, x)
        if cfg.d_ff:
            h2 = rms_norm(y, p["ln2"], cfg.norm_eps)
            f, _ = _apply_ffn(p["ffn"], cfg, h2, False)
            y = y + f
        dt = _dtype(cfg.compute_dtype)
        return y, {"h": st["h"], "conv": st["conv"].astype(dt)}
    if kind == BLOCK_MLSTM:
        y, st = xl.mlstm_apply(p, x, n_heads=cfg.n_heads,
                               chunk=cfg.mlstm_chunk)
        st["conv"] = st["conv"].astype(_dtype(cfg.compute_dtype))
        return y, st
    if kind == BLOCK_SLSTM:
        return xl.slstm_apply(p, x, n_heads=cfg.n_heads)
    raise ValueError(kind)


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            q_chunk: int = 512, act_shard=None,
            moe_fn=None) -> Tuple[jax.Array, Cache]:
    """Returns (last-position logits [B, vocab] f32, caches sized S)."""
    x = embed_inputs(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    head, body, tail = stack_segments(cfg)
    caches: Cache = {}
    constrain = act_shard if act_shard is not None else (lambda t: t)

    if head:
        cl = []
        for i, li in enumerate(head):
            x, c = prefill_block(params["head_layers"][i], cfg,
                                 cfg.block_kind(li), x, positions,
                                 layer_is_moe=False, q_chunk=q_chunk,
                                 moe_fn=moe_fn)
            x = constrain(x)
            cl.append(c)
        caches["head_layers"] = cl

    if body:
        kinds = [cfg.block_kind(li) for li in body[0]]
        moe_flags = [cfg.is_moe and li >= cfg.first_dense_layers
                     for li in body[0]]

        def scan_body(x, period_params):
            cs = []
            for j, kind in enumerate(kinds):
                x, c = prefill_block(period_params[j], cfg, kind, x,
                                     positions, layer_is_moe=moe_flags[j],
                                     q_chunk=q_chunk, moe_fn=moe_fn)
                x = constrain(x)
                cs.append(c)
            return x, cs

        x, body_caches = jax.lax.scan(scan_body, x, params["body"])
        caches["body"] = body_caches

    if tail:
        cl = []
        for i, li in enumerate(tail):
            x, c = prefill_block(params["tail_layers"][i], cfg,
                                 cfg.block_kind(li), x, positions,
                                 layer_is_moe=cfg.is_moe and li >= cfg.first_dense_layers,
                                 q_chunk=q_chunk, moe_fn=moe_fn)
            x = constrain(x)
            cl.append(c)
        caches["tail_layers"] = cl

    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    w_out = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w_out.astype(x.dtype))[:, 0]
    return logits.astype(jnp.float32), caches
