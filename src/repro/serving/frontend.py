"""SLO-aware serving front end: admission control, cost prediction,
deadline scheduling, and load shedding over one ``GraphSession``.

The paper frames scalable query serving as managing the trade-off between
response time and resources (Sec. 1): a deployment cannot run every
arriving query to completion and still answer interactive traffic within
its deadline.  This module is that trade-off as a subsystem, one layer
above the ``QueryScheduler`` (core/scheduler.py):

  SLO classes — every request carries an ``slo_class`` (interactive /
      batch / exhaustive by default, each with a latency deadline and a
      strictness ladder: strict classes are never shed, degradable
      classes lose answer budget first, deferrable classes park until
      the backlog drains, sheddable classes are rejected outright).
  admission   — a ``CostModel`` (serving/cost.py) prices each query from
      catalog/manifest statistics BEFORE admission — never touching a
      shard — and the front end compares predicted completion (current
      predicted backlog + the query's own predicted latency) against the
      class deadline.  Over-budget work degrades, defers, or sheds (in
      that order, under the default ``predictive`` policy) with an
      explicit ``shed_reason``; admitted work enters the scheduler.
  deadline scheduling — admitted queries get a slack-weighted *urgency*
      refreshed every pump; ``rank_partitions_shared`` adds
      ``SNI × urgency`` to each partition's score, so partitions
      advancing deadline-critical queries outrank hotter slack-rich
      work.  The loop pumps ``scheduler.run(max_rounds=1)`` so admission
      and urgency updates interleave with serving.
  calibration — every completion's observed latency feeds
      ``CostModel.observe``, so prediction converges while traffic flows.

Determinism: every admission/degrade/shed decision reads PREDICTED
quantities (the cost model and the predicted backlog), never wall-clock
measurements, so a fixed workload + seed always produces the same
outcome set — the CI smoke gate and tests/test_serving_frontend.py rely
on it.  Arrival times replay on a virtual clock (``replay_speed``; the
default 0 admits everything instantly in arrival order).

Byte-identity: with no SLO classes configured the front end delegates to
``GraphSession.submit_many`` — same answers, same partition-load
sequence, same rng consumption.  All-zero urgencies add literal ``+0.0``
to the shared ranking's float scores, so even a mixed deployment's
no-deadline traffic schedules bit-identically.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Mapping, Optional, Sequence, Union

from ..core.plan import generate_plan
from ..core.query import DisjunctiveQuery, Query
from ..obs.profile import SloBurnMonitor
from .cost import CostEstimate, CostModel

# shed_reason vocabulary (explicit, closed — the CI gate greps for these)
SHED_DEADLINE = "deadline-unreachable"
SHED_POLICY = "deadline-policy"

SHED_POLICIES = ("predictive", "deadline", "never")


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One service level: a latency deadline plus the degradation ladder.

    ``priority`` orders classes strictest-first (0 = most latency-critical);
    admission charges a query only the predicted backlog of work at its
    own priority or stricter, so batch traffic never causes interactive
    shedding.  ``deadline_s = inf`` means no deadline (urgency 0).
    """

    name: str
    deadline_s: float
    priority: int
    degradable: bool = False        # may shrink max_answers before shedding
    deferrable: bool = False        # may park until the backlog drains
    sheddable: bool = False         # may be rejected outright
    degraded_max_answers: int = 8   # the budget a degraded query drops to


def default_slo_classes() -> List[SLOClass]:
    """The paper's three service shapes: interactive point lookups with a
    tight deadline (strict — never shed, the system degrades everyone
    else first), batch analytics with a loose one (degradable, then
    sheddable), and exhaustive scans with none (deferred to idle)."""
    return [
        SLOClass("interactive", deadline_s=0.5, priority=0),
        SLOClass("batch", deadline_s=5.0, priority=1,
                 degradable=True, sheddable=True),
        SLOClass("exhaustive", deadline_s=math.inf, priority=2,
                 deferrable=True, sheddable=True),
    ]


def parse_slo_spec(spec: str) -> List[SLOClass]:
    """Parse ``"interactive=0.5,batch=5,exhaustive=inf"`` into classes.

    Known names (the defaults') keep their strictness flags with the
    deadline overridden; unknown names become degradable+sheddable with
    priority by position after the known ones.  Order in the spec is
    priority order.
    """
    known = {c.name: c for c in default_slo_classes()}
    classes: List[SLOClass] = []
    for i, part in enumerate(p.strip() for p in spec.split(",") if p.strip()):
        if "=" not in part:
            raise ValueError(f"bad SLO spec entry {part!r} "
                             f"(want name=deadline_seconds)")
        name, _, val = part.partition("=")
        name = name.strip()
        deadline = math.inf if val.strip().lower() in ("inf", "none") \
            else float(val)
        if deadline <= 0:
            raise ValueError(f"deadline for {name!r} must be > 0 (or inf), "
                             f"got {val!r}")
        base = known.get(name)
        if base is not None:
            classes.append(dataclasses.replace(base, deadline_s=deadline,
                                               priority=i))
        else:
            classes.append(SLOClass(name, deadline_s=deadline, priority=i,
                                    degradable=True, sheddable=True))
    if not classes:
        raise ValueError(f"empty SLO spec {spec!r}")
    return classes


@dataclasses.dataclass
class Request:
    """One arriving query: what to run, when it arrives (seconds on the
    workload's virtual clock), and under which SLO class (None = no
    deadline; with no classes configured at all the front end falls back
    to plain ``submit_many``)."""

    query: Union[Query, DisjunctiveQuery]
    slo_class: Optional[str] = None
    arrival_s: float = 0.0
    max_answers: Optional[int] = None


@dataclasses.dataclass
class RequestOutcome:
    """What happened to one request: served (possibly degraded/deferred)
    or shed with an explicit reason — plus both sides of the prediction
    (predicted vs observed latency) for calibration observability."""

    name: str
    slo_class: Optional[str]
    arrival_s: float
    status: str                          # "ok" | "shed"
    shed_reason: Optional[str] = None    # required iff status == "shed"
    degraded: bool = False               # budget shrunk at admission
    deferred: bool = False               # parked until the backlog drained
    max_answers: Optional[int] = None    # effective budget K served under
    predicted_latency_s: float = 0.0
    latency_s: Optional[float] = None    # observed (None when shed)
    deadline_s: float = math.inf
    deadline_met: Optional[bool] = None  # None when shed / no deadline
    finished_round: Optional[int] = None  # pump index completion was seen at
    result: Optional[object] = None      # the QueryResult (None when shed)


@dataclasses.dataclass
class FrontendReport:
    """One ``serve()`` run: per-request outcomes (input order), per-class
    latency percentiles, and the admission/degrade/shed counters."""

    outcomes: List[RequestOutcome]
    per_class: Dict[str, Dict[str, float]]
    counters: Dict[str, int]
    shed_by_reason: Dict[str, int]
    rounds: int
    wall_s: float
    schedule: Optional[object] = None    # plain path: the ScheduleReport
    # per-class error-budget burn over the run's trailing window
    # (obs/profile.SloBurnMonitor.snapshot(); empty on the plain path)
    slo_burn: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)

    @property
    def served(self) -> List[RequestOutcome]:
        return [o for o in self.outcomes if o.status == "ok"]

    @property
    def shed(self) -> List[RequestOutcome]:
        return [o for o in self.outcomes if o.status == "shed"]


def _percentile(vals: Sequence[float], q: float) -> float:
    """numpy-free exact percentile (linear interpolation) — the report
    stays importable without dragging numpy into small consumers."""
    if not vals:
        return 0.0
    s = sorted(vals)
    pos = q * (len(s) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (pos - lo)


@dataclasses.dataclass
class _Pending:
    """One admitted (or deferred) request in flight."""

    idx: int                      # index into the outcomes list
    req: Request
    slo: Optional[SLOClass]
    estimate: Optional[CostEstimate]
    max_answers: Optional[int]
    qid: Optional[int] = None     # None while deferred (not yet admitted)
    admitted_round: int = 0
    arrive_wall: float = 0.0


class ServingFrontend:
    """Continuous-arrival serving over one session's ``QueryScheduler``.

    ``slo_classes`` — the deadline ladder (None = ``default_slo_classes``;
    pass ``[]`` for an explicit no-SLO front end).  ``cost_model`` defaults
    to a fresh ``CostModel`` over the session's graph.  ``shed_policy``:

      predictive — degrade (shrink K), then defer, then shed, strictly
                   from predicted backlog vs deadline (default)
      deadline   — shed anything predicted to miss; no degradation
      never      — admit everything (deadline scheduling still applies)

    ``headroom`` scales the deadline budget admission compares against
    (0.8 = keep 20% slack).  ``replay_speed`` scales workload arrival
    times to wall time (2.0 = replay twice as fast; <= 0 = instant, the
    deterministic default).  ``urgency_weight`` scales the slack-weighted
    deadline pressure fed to the shared ranking.  ``burn_window`` /
    ``error_budget`` parameterize the per-class SLO burn-rate monitor
    (obs/profile.SloBurnMonitor): every finite-deadline completion lands
    in a rolling window and burn = miss_fraction / error_budget.
    """

    def __init__(self, session, *,
                 slo_classes: Optional[Sequence[SLOClass]] = None,
                 cost_model: Optional[CostModel] = None,
                 shed_policy: str = "predictive",
                 heuristic: Optional[str] = None,
                 seed: Optional[int] = None,
                 fairness_gamma: float = 0.0,
                 urgency_weight: float = 1.0,
                 headroom: float = 1.0,
                 replay_speed: float = 0.0,
                 burn_window: int = 100,
                 error_budget: float = 0.01):
        if shed_policy not in SHED_POLICIES:
            raise ValueError(f"shed_policy must be one of {SHED_POLICIES}, "
                             f"got {shed_policy!r}")
        if headroom <= 0:
            raise ValueError(f"headroom must be > 0, got {headroom}")
        self.session = session
        self.classes: Dict[str, SLOClass] = {
            c.name: c for c in (default_slo_classes()
                                if slo_classes is None else slo_classes)}
        self.cost_model = cost_model if cost_model is not None \
            else CostModel(session.pg)
        self.shed_policy = shed_policy
        self.heuristic = heuristic
        self.seed = seed
        self.fairness_gamma = float(fairness_gamma)
        self.urgency_weight = float(urgency_weight)
        self.headroom = float(headroom)
        self.replay_speed = float(replay_speed)
        # SLO burn-rate accounting (obs/profile.py): the rolling window of
        # deadline outcomes per class and the error budget the window's
        # miss fraction is charged against
        self.burn_window = int(burn_window)
        self.error_budget = float(error_budget)

    # -- the serving loop ---------------------------------------------------

    def serve(self, requests: Sequence[Request]) -> FrontendReport:
        """Run one workload of requests to completion (admit → pump →
        retire), returning every request's outcome in input order."""
        if not self.classes or all(r.slo_class is None for r in requests):
            return self._serve_plain(requests)
        for r in requests:
            if r.slo_class is not None and r.slo_class not in self.classes:
                raise ValueError(
                    f"unknown slo_class {r.slo_class!r} for query "
                    f"{r.query.name!r} (configured: "
                    f"{sorted(self.classes)})")
        return self._serve_slo(requests)

    def _serve_plain(self, requests: Sequence[Request]) -> FrontendReport:
        """No SLO anywhere: delegate to ``submit_many`` — answers AND the
        partition-load schedule are byte-identical to calling it directly
        (same scheduler construction, same rng consumption, all-zero
        urgency contributes +0.0 to every ranking score)."""
        t0 = time.time()
        kwargs = {}
        if self.heuristic is not None:
            kwargs["heuristic"] = self.heuristic
        report = self.session.submit_many(
            [r.query for r in requests],
            max_answers=[r.max_answers for r in requests],
            seed=self.seed, fairness_gamma=self.fairness_gamma, **kwargs)
        by_name: Dict[str, List[object]] = {}
        for res in report.results:
            by_name.setdefault(res.name, []).append(res)
        outcomes = []
        for r in requests:
            res = by_name[r.query.name].pop(0)
            outcomes.append(RequestOutcome(
                name=r.query.name, slo_class=None, arrival_s=r.arrival_s,
                status="ok", max_answers=r.max_answers,
                latency_s=res.latency_s, result=res))
        return FrontendReport(
            outcomes=outcomes, per_class={},
            counters={"arrived": len(requests), "admitted": len(requests),
                      "served": len(outcomes)},
            shed_by_reason={}, rounds=0, wall_s=time.time() - t0,
            schedule=report)

    def _serve_slo(self, requests: Sequence[Request]) -> FrontendReport:
        session = self.session
        sched = session.scheduler(heuristic=self.heuristic, seed=self.seed,
                                  fairness_gamma=self.fairness_gamma)
        t0 = time.time()
        speed = self.replay_speed
        # arrival order: (arrival time, input position) — deterministic
        order = sorted(range(len(requests)),
                       key=lambda i: (requests[i].arrival_s, i))
        outcomes: List[Optional[RequestOutcome]] = [None] * len(requests)
        counters = {"arrived": len(requests), "admitted": 0, "served": 0,
                    "degraded": 0, "deferred": 0, "shed": 0}
        shed_by_reason: Dict[str, int] = {}
        in_flight: Dict[int, _Pending] = {}     # qid -> pending
        deferred: List[_Pending] = []
        next_arrival = 0
        rounds = 0
        burn = SloBurnMonitor(window=self.burn_window,
                              error_budget=self.error_budget)

        def vnow() -> float:
            """The virtual workload clock: wall time scaled by the replay
            speed (speed <= 0 = everything is due immediately)."""
            return math.inf if speed <= 0 else (time.time() - t0) * speed

        def backlog_s(priority: int) -> float:
            """Predicted seconds of in-flight work at ``priority`` or
            stricter — what a new arrival queues behind."""
            total = 0.0
            for p in in_flight.values():
                if p.slo is not None and p.slo.priority <= priority \
                        and p.estimate is not None:
                    total += p.estimate.latency_s
            return total

        tracer = getattr(session, "tracer", None)
        trace_on = tracer is not None and tracer.enabled

        def record_decision(outcome: str, r: Request,
                            slo: Optional[SLOClass],
                            est: Optional[CostEstimate],
                            reason: Optional[str] = None,
                            qid: Optional[int] = None) -> None:
            """One decision record per admission verdict: the predicted
            latency, the backlog it queued behind, and the deadline it was
            judged against — everything trace_report needs to replay WHY
            a request was admitted/degraded/deferred/shed."""
            if not trace_on:
                return
            tracer.decision(
                "frontend.admit", query=r.query.name,
                slo_class=slo.name if slo is not None else None,
                outcome=outcome, reason=reason, qid=qid,
                arrival_s=float(r.arrival_s),
                predicted_latency_s=(float(est.latency_s)
                                     if est is not None else None),
                backlog_s=(backlog_s(slo.priority)
                           if slo is not None else 0.0),
                deadline_s=(float(slo.deadline_s)
                            if slo is not None else None),
                headroom=float(self.headroom))

        def admit(pend: _Pending, outcome: str = "admit") -> None:
            r = pend.req
            pend.qid = sched.admit(r.query, max_answers=pend.max_answers)
            pend.admitted_round = rounds
            pend.arrive_wall = t0 + (r.arrival_s / speed if speed > 0 else 0.0)
            in_flight[pend.qid] = pend
            counters["admitted"] += 1
            record_decision(outcome, r, pend.slo, pend.estimate,
                            qid=pend.qid)

        def shed(idx: int, r: Request, slo: SLOClass, est: CostEstimate,
                 reason: str) -> None:
            counters["shed"] += 1
            shed_by_reason[reason] = shed_by_reason.get(reason, 0) + 1
            record_decision("shed", r, slo, est, reason=reason)
            outcomes[idx] = RequestOutcome(
                name=r.query.name, slo_class=slo.name, arrival_s=r.arrival_s,
                status="shed", shed_reason=reason,
                max_answers=r.max_answers,
                predicted_latency_s=est.latency_s, deadline_s=slo.deadline_s)

        def consider(idx: int) -> None:
            """Admission control for one due arrival: predict, then admit /
            degrade / defer / shed under the policy."""
            r = requests[idx]
            slo = self.classes[r.slo_class] if r.slo_class is not None \
                else None
            plans = [generate_plan(q, session.graph, session.catalog)
                     for q in (r.query.disjuncts
                               if isinstance(r.query, DisjunctiveQuery)
                               else [r.query])]
            est = self.cost_model.predict_plans(plans, r.max_answers)
            pend = _Pending(idx=idx, req=r, slo=slo, estimate=est,
                            max_answers=r.max_answers)
            if slo is None or self.shed_policy == "never":
                admit(pend)
                return
            # deferrable classes always yield to the rest of the workload:
            # park whenever anything else is in flight or still due (the
            # drain phase below admits them) — deterministic, since it
            # reads admission state, not timing
            if slo.deferrable and (in_flight or next_arrival < len(order)):
                pend.estimate = est
                deferred.append(pend)
                counters["deferred"] += 1
                record_decision("defer", r, slo, est, reason="deferrable")
                return
            budget = slo.deadline_s * self.headroom
            finish_est = backlog_s(slo.priority) + est.latency_s
            if math.isinf(slo.deadline_s) or finish_est <= budget:
                admit(pend)
                return
            if self.shed_policy == "deadline":
                if slo.sheddable:
                    shed(idx, r, slo, est, SHED_POLICY)
                else:
                    admit(pend)
                return
            # predictive policy: degrade first (shrink the answer budget
            # and re-price), then shed; strict classes admit regardless
            if slo.degradable:
                k2 = slo.degraded_max_answers if r.max_answers is None \
                    else min(r.max_answers, slo.degraded_max_answers)
                est2 = self.cost_model.predict_plans(plans, k2)
                if backlog_s(slo.priority) + est2.latency_s <= budget \
                        or not slo.sheddable:
                    pend.estimate = est2
                    pend.max_answers = k2
                    counters["degraded"] += 1
                    admit(pend, outcome="degrade")
                    outcomes_mark_degraded[pend.qid] = True
                    return
            if slo.sheddable:
                shed(idx, r, slo, est, SHED_DEADLINE)
            else:
                admit(pend)

        outcomes_mark_degraded: Dict[int, bool] = {}

        def refresh_urgency() -> None:
            """Slack-weighted deadline pressure for every in-flight query:
            1/slack, growing as the deadline nears (inf-deadline and
            no-SLO queries stay at exactly 0.0 → ranking unchanged)."""
            now = vnow()
            for qid, p in in_flight.items():
                if p.slo is None or math.isinf(p.slo.deadline_s):
                    continue
                if speed <= 0:
                    # instant replay has no clock; urgency falls out of the
                    # deadline alone, so tighter classes still rank first
                    slack = p.slo.deadline_s
                else:
                    slack = (p.req.arrival_s + p.slo.deadline_s) - now
                u = self.urgency_weight / max(slack, 0.05)
                sched.set_urgency(qid, u)

        def drain_completions(report) -> None:
            for res in report.results:
                p = in_flight.pop(res.qid)
                latency = max(0.0, time.time() - p.arrive_wall)
                if p.estimate is not None:
                    self.cost_model.observe(p.estimate, latency)
                session._absorb(res.reports, res.answers)
                slo = p.slo
                met = None
                if slo is not None and not math.isinf(slo.deadline_s):
                    met = bool(latency <= slo.deadline_s)
                    # only deadline outcomes burn budget: shed requests
                    # never enter the window, inf-deadline classes have
                    # no budget to burn
                    burn.observe(slo.name, met)
                counters["served"] += 1
                outcomes[p.idx] = RequestOutcome(
                    name=p.req.query.name,
                    slo_class=slo.name if slo else None,
                    arrival_s=p.req.arrival_s, status="ok",
                    degraded=bool(outcomes_mark_degraded.get(p.qid)),
                    deferred=p.qid is not None and any(
                        d is p for d in drained_deferred),
                    max_answers=p.max_answers,
                    predicted_latency_s=(p.estimate.latency_s
                                         if p.estimate else 0.0),
                    latency_s=latency,
                    deadline_s=slo.deadline_s if slo else math.inf,
                    deadline_met=met,
                    finished_round=rounds, result=res)

        drained_deferred: List[_Pending] = []
        try:
            while (next_arrival < len(order) or in_flight or deferred):
                # 1) admit every due arrival (instant replay: all of them);
                # next_arrival advances BEFORE consider() so the deferral
                # check reads only strictly-future arrivals
                while next_arrival < len(order):
                    idx = order[next_arrival]
                    if requests[idx].arrival_s <= vnow():
                        next_arrival += 1
                        consider(idx)
                    elif not in_flight and not deferred:
                        # idle: sleep the replay clock forward to the arrival
                        time.sleep(min(0.05, max(
                            0.0, (requests[idx].arrival_s - vnow()) / speed)))
                    else:
                        break
                # 2) drain phase: nothing due and nothing active -> admit the
                # parked exhaustive work (arrival order)
                if not in_flight and next_arrival >= len(order) and deferred:
                    for p in deferred:
                        drained_deferred.append(p)
                        admit(p)
                    deferred.clear()
                if not in_flight:
                    if speed > 0 and next_arrival < len(order):
                        time.sleep(0.001)  # deferred work parked; due soon
                    continue
                # 3) one bounded scheduler pump with fresh urgencies
                refresh_urgency()
                report = sched.run(max_rounds=1)
                rounds += 1
                drain_completions(report)
        finally:
            # the whole serve run was pinned to one generation view; let
            # a later compaction's GC reclaim it once superseded
            sched.close()

        latencies: Dict[str, List[float]] = {}
        deadline_met: Dict[str, List[bool]] = {}
        for o in outcomes:
            if o is not None and o.status == "ok" and o.slo_class:
                latencies.setdefault(o.slo_class, []).append(o.latency_s)
                if o.deadline_met is not None:
                    deadline_met.setdefault(o.slo_class, []).append(
                        o.deadline_met)
        per_class = {
            cls: {"served": float(len(vals)),
                  "p50_latency_s": _percentile(vals, 0.5),
                  "p95_latency_s": _percentile(vals, 0.95),
                  "p99_latency_s": _percentile(vals, 0.99)}
            for cls, vals in sorted(latencies.items())}
        slo_burn = burn.snapshot()
        session.record_serving(counters=counters,
                               shed_by_reason=shed_by_reason,
                               latencies=latencies,
                               deadline_met=deadline_met,
                               slo_burn=slo_burn)
        return FrontendReport(
            outcomes=[o for o in outcomes if o is not None],
            per_class=per_class, counters=counters,
            shed_by_reason=shed_by_reason, rounds=rounds,
            wall_s=time.time() - t0, slo_burn=slo_burn)


def requests_from_workload(
        lines: Sequence[Mapping], *,
        default_slo: Optional[str] = None,
        default_max_answers: Optional[int] = None) -> List[Request]:
    """Build ``Request``s from parsed workload-JSONL dicts (launch/serve.py
    format: each line is a query dict with optional ``max_answers`` /
    ``arrival_ms`` / ``slo_class`` keys riding alongside)."""
    reqs: List[Request] = []
    for ln in lines:
        budget = ln.get("max_answers", default_max_answers)
        reqs.append(Request(
            query=DisjunctiveQuery.from_json_dict(ln),
            slo_class=ln.get("slo_class", default_slo),
            arrival_s=float(ln.get("arrival_ms", 0.0)) / 1000.0,
            max_answers=None if budget is None else int(budget)))
    return reqs
