from .decode import (init_caches, abstract_caches, prefill, decode_step)

__all__ = ["init_caches", "abstract_caches", "prefill", "decode_step"]
