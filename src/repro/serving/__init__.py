from .cost import CostEstimate, CostModel, required_partition_mask, \
    work_units
from .decode import (init_caches, abstract_caches, prefill, decode_step)
from .frontend import (FrontendReport, Request, RequestOutcome, SLOClass,
                       ServingFrontend, default_slo_classes, parse_slo_spec,
                       requests_from_workload)

__all__ = [
    "init_caches", "abstract_caches", "prefill", "decode_step",
    "CostEstimate", "CostModel", "required_partition_mask", "work_units",
    "FrontendReport", "Request", "RequestOutcome", "SLOClass",
    "ServingFrontend", "default_slo_classes", "parse_slo_spec",
    "requests_from_workload",
]
