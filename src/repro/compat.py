"""Version-tolerance shims for jax API drift.

The repo targets the newest jax idioms (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``), but containers and
CI images often pin older 0.4.x releases where those live under
``jax.experimental.shard_map`` (kwarg ``check_rep``) and ``make_mesh`` has
no ``axis_types`` parameter.  Every mesh/shard_map construction in the
repo goes through this module so a jax upgrade is a one-file audit.
"""
from __future__ import annotations

from typing import Optional, Sequence

__all__ = ["shard_map", "make_mesh", "make_part_mesh", "axis_size"]


def axis_size(axis: str) -> int:
    """Static size of a named mesh axis from inside shard_map.

    ``jax.lax.axis_size`` is new; on older jax ``psum(1, axis)`` constant-
    folds to the same static int.
    """
    import jax
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` when available, else the experimental fallback.

    ``check_vma`` (new name) and ``check_rep`` (old name) toggle the same
    replication check; callers always use the new name.
    """
    import jax
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices: Optional[Sequence] = None):
    """``jax.make_mesh`` with explicit-Auto axis types where supported;
    plain ``jax.sharding.Mesh`` on jax < 0.4.35 (no ``jax.make_mesh``)."""
    import jax
    import numpy as np
    if not hasattr(jax, "make_mesh"):
        n = int(np.prod(tuple(shape)))
        devs = list(devices) if devices is not None else jax.devices()[:n]
        return jax.sharding.Mesh(
            np.asarray(devs).reshape(tuple(shape)), tuple(axes))
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def make_part_mesh(k: int):
    """The 1-D ``("part",)`` mesh MapReduceMP uses: one device per partition."""
    return make_mesh((k,), ("part",))
