"""Pallas TPU kernel: FUSED frontier expansion + predicate filtering +
answer-emission classification (the whole engine inner step).

``frontier_expand.py`` fuses the *match* (one-edge expansion against the
plan step's predicates); the surrounding engine loop still classified every
produced row on the host side of the kernel boundary — three extra [EB*W]
gathers (next frontier vertex, its g2l local index, its owner) and the
done/keep/outgoing mask algebra ran as separate XLA ops.  This kernel fuses
all of it: one grid step consumes a (1, W) candidate tile and emits the
*routing decision* for every candidate —

  done  — the produced row completes the plan: append to the FAA,
  keep  — its next frontier vertex is core-local: stays in the work buffer,
  out   — owned elsewhere: emit to ``dest``'s IMA (the paper's PCA/IMA
          continuation),

so the engines' ``lax.while_loop`` body contains a single kernel launch
plus cheap scatter appends.

The fusion trick mirrors the denormalized dst attributes of the ELL
tables: the two data-dependent gathers the classification needs
(``g2l[dst]`` and ``owner[dst]``) are precomputed ONCE per evaluator call
as two extra [Np, W] tables (``ell_dlidx``, ``ell_downer`` — hoisted out
of the while loop, amortized over every iteration), and the per-binding
scalar cases (the next frontier is an already-bound vertex) ride in as
prefetched SMEM scalars.  The kernel itself therefore still performs NO
data-dependent gathers: each grid step touches eight (1, W) VMEM tiles
selected by the scalar-prefetch ``lidx`` BlockSpec index map, exactly the
Mosaic row-gather idiom of ``frontier_expand.py``.

Layout notes (TPU target):
  * W padded to a lane multiple (128) by the ops.py wrapper,
  * per-binding scalars packed into ``pint`` [EB, 12] int32 + ``pflt``
    [EB] f32 in SMEM; all dynamic scalars (n_steps, n_core) are folded
    into per-row columns host-side so the kernel sees only static shapes,
  * outputs are int32 masks/ids — bool VMEM tiles are unsupported.

Validated against ref.fused_frontier_ref in interpret mode (CPU) over a
shape/dtype sweep including empty frontiers and all-filtered labels; see
tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.graph import DIR_BACKWARD, DIR_FORWARD, DIR_UNDIRECTED, WILDCARD
from ..core.query import (OP_EQ, OP_GE, OP_GT, OP_LE, OP_LT, OP_NE, OP_NONE,
                          QDIR_ANY, QDIR_IN, QDIR_OUT)

# packed int-param column layout (pint[:, _F_*])
(_F_EL, _F_DIR, _F_DLAB, _F_DOP, _F_DST, _F_CLOSES, _F_ACTIVE, _F_ISLAST,
 _F_USEDG, _F_FGLIDX, _F_FGOWNER, _F_NCORE) = range(12)
N_FPINT = 12


def _kernel(lidx_ref, pint_ref, pflt_ref, rows_ref,       # SMEM (prefetch)
            ed_ref, el_ref, edir_ref, dlab_ref, dval_ref, dgid_ref,
            dlidx_ref, downer_ref,                        # VMEM in (1, W)
            ok_ref, dg_ref, done_ref, keep_ref, out_ref, dest_ref,
            *, q_pad: int):
    i = pl.program_id(0)

    p_el = pint_ref[i, _F_EL]
    p_dir = pint_ref[i, _F_DIR]
    p_dlab = pint_ref[i, _F_DLAB]
    p_dop = pint_ref[i, _F_DOP]
    p_dst = pint_ref[i, _F_DST]
    p_closes = pint_ref[i, _F_CLOSES]
    # _F_ACTIVE folds m & (step < n_steps); _F_ISLAST folds
    # (step + 1 >= n_steps); _F_USEDG folds (next_src_slot == dst_slot)
    # & ~closes — all computed by the wrapper so the dynamic n_steps /
    # n_core scalars never have to enter the kernel as separate operands.
    active = pint_ref[i, _F_ACTIVE]
    islast = pint_ref[i, _F_ISLAST]
    use_dg = pint_ref[i, _F_USEDG]
    fg_lidx = pint_ref[i, _F_FGLIDX]    # g2l of the bound next-frontier
    fg_owner = pint_ref[i, _F_FGOWNER]  # owner of the bound next-frontier
    n_core = pint_ref[i, _F_NCORE]
    p_dval = pflt_ref[i]

    ed = ed_ref[0, :]
    el = el_ref[0, :]
    edir = edir_ref[0, :]
    dl = dlab_ref[0, :]
    dv = dval_ref[0, :]
    dg = dgid_ref[0, :]
    dlidx = dlidx_ref[0, :]      # g2l local index of each candidate dst
    downer = downer_ref[0, :]    # owner pid of each candidate dst

    # ---- the match (identical predicate algebra to frontier_expand) ----
    edge_exists = ed >= 0
    elabel_ok = (p_el == WILDCARD) | (el == p_el)
    dir_ok = ((p_dir == QDIR_ANY)
              | (edir == DIR_UNDIRECTED)
              | ((p_dir == QDIR_OUT) & (edir == DIR_FORWARD))
              | ((p_dir == QDIR_IN) & (edir == DIR_BACKWARD)))
    dlabel_ok = (p_dlab == WILDCARD) | (dl == p_dlab)

    finite = dv == dv
    cmp = (((p_dop == OP_EQ) & (dv == p_dval))
           | ((p_dop == OP_NE) & (dv != p_dval))
           | ((p_dop == OP_LT) & (dv < p_dval))
           | ((p_dop == OP_LE) & (dv <= p_dval))
           | ((p_dop == OP_GT) & (dv > p_dval))
           | ((p_dop == OP_GE) & (dv >= p_dval)))
    dval_ok = (p_dop == OP_NONE) | (finite & cmp)

    # injectivity: dg must differ from every bound slot (static Q unroll)
    already = jnp.zeros_like(dg, dtype=jnp.bool_)
    for q in range(q_pad):
        already = already | (dg == rows_ref[i, q])
    inj_ok = ~already

    bound_dst = rows_ref[i, p_dst]
    cyc_ok = (p_closes == 1) & (dg == bound_dst)
    new_ok = (p_closes == 0) & dlabel_ok & dval_ok & inj_ok
    ok = ((active == 1)
          & edge_exists & elabel_ok & dir_ok & (cyc_ok | new_ok))

    # ---- the classification (fused answer emission) ----
    # the produced row's next frontier vertex: the freshly-bound dst when
    # the next plan step expands from the slot this step binds, else an
    # already-bound vertex whose g2l/owner came in as SMEM scalars
    # dlidx/fg_lidx are -1 for unbound/absent vertices (the wrapper
    # denormalizes with that convention), so (lfg >= 0) subsumes the
    # fg >= 0 test of the jnp classification.
    lfg = jnp.where(use_dg == 1, dlidx, fg_lidx)
    local = (lfg >= 0) & (lfg < n_core)
    done = ok & (islast == 1)
    keep = ok & (islast == 0) & local
    outm = ok & (islast == 0) & ~local
    dest = jnp.where(use_dg == 1, downer, fg_owner)

    ok_ref[0, :] = ok.astype(jnp.int32)
    dg_ref[0, :] = dg
    done_ref[0, :] = done.astype(jnp.int32)
    keep_ref[0, :] = keep.astype(jnp.int32)
    out_ref[0, :] = outm.astype(jnp.int32)
    dest_ref[0, :] = dest


def fused_frontier_pallas(lidx, pint, pflt, rows,
                          ell_dst, ell_label, ell_dir,
                          ell_dlab, ell_dval, ell_dgid,
                          ell_dlidx, ell_downer,
                          *, interpret: bool = True):
    """Raw kernel invocation; ops.fused_frontier is the public wrapper.

    lidx [EB] int32 (clipped to [0, Np)), pint [EB, 12] int32, pflt [EB]
    f32, rows [EB, Q] int32, ell_* [Np, W] (W a lane multiple on TPU).
    Returns six [EB, W] int32 arrays: ok, dg, done, keep, out, dest.
    """
    EB = lidx.shape[0]
    Np, W = ell_dst.shape
    Q = rows.shape[1]

    ell_spec = pl.BlockSpec((1, W), lambda i, lidx_r, *_: (lidx_r[i], 0))
    out_spec = pl.BlockSpec((1, W), lambda i, *_: (i, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,           # lidx, pint, pflt, rows -> SMEM
        grid=(EB,),
        in_specs=[ell_spec] * 8,
        out_specs=[out_spec] * 6,
    )
    kernel = functools.partial(_kernel, q_pad=Q)
    shp = jax.ShapeDtypeStruct((EB, W), jnp.int32)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[shp] * 6,
        interpret=interpret,
    )(lidx, pint, pflt, rows,
      ell_dst, ell_label, ell_dir, ell_dlab, ell_dval, ell_dgid,
      ell_dlidx, ell_downer)
