"""Public jit'd wrappers around the Pallas kernels.

Each wrapper:
  * adapts engine-level arguments to the kernel's packed layout,
  * pads the lane dimension to 128 multiples (TPU tile alignment),
  * selects interpret mode automatically off-TPU (the kernels TARGET TPU;
    interpret=True executes the kernel body in Python on CPU so correctness
    is validated everywhere),
  * has a pure-jnp twin in ref.py used by the tests as the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .frontier_expand import (N_PINT, _P_ACTIVE, _P_CLOSES, _P_DIR, _P_DLAB,
                              _P_DOP, _P_DST, _P_EL, _P_STEP,
                              frontier_expand_pallas)
from .label_histogram import label_histogram_pallas

LANE = 128


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def frontier_expand(rows_b, step_b, lidx_b, m,
                    ell_dst, ell_label, ell_dir,
                    ell_dlab, ell_dval, ell_dgid,
                    plan, n_steps, *, interpret=None):
    """Engine-facing adapter with the same signature/semantics as the jnp
    match in engine._match_tile_jnp (minus row construction).

    Returns (ok [EB, W] bool, dg [EB, W] int32) for the ORIGINAL width W.
    """
    if interpret is None:
        interpret = not on_tpu()
    EB = rows_b.shape[0]
    Np, W = ell_dst.shape
    S = plan.src_slot.shape[0]

    s = jnp.clip(step_b, 0, S - 1)
    active = (m & (step_b < n_steps)).astype(jnp.int32)
    pint = jnp.zeros((EB, N_PINT), jnp.int32)
    pint = pint.at[:, _P_EL].set(plan.edge_label[s])
    pint = pint.at[:, _P_DIR].set(plan.direction[s])
    pint = pint.at[:, _P_DLAB].set(plan.dst_label[s])
    pint = pint.at[:, _P_DOP].set(plan.dst_value_op[s])
    pint = pint.at[:, _P_DST].set(plan.dst_slot[s])
    pint = pint.at[:, _P_CLOSES].set(plan.closes_cycle[s])
    pint = pint.at[:, _P_STEP].set(step_b)
    pint = pint.at[:, _P_ACTIVE].set(active)
    pflt = plan.dst_value[s].astype(jnp.float32)
    lidx = jnp.clip(lidx_b, 0, Np - 1).astype(jnp.int32)

    # pad the lane dim to 128 (padding edges: dst -1 -> never match)
    Wp = _round_up(W, LANE)
    if Wp != W:
        padw = [(0, 0), (0, Wp - W)]
        ell_dst = jnp.pad(ell_dst, padw, constant_values=-1)
        ell_label = jnp.pad(ell_label, padw, constant_values=-2)
        ell_dir = jnp.pad(ell_dir, padw)
        ell_dlab = jnp.pad(ell_dlab, padw, constant_values=-2)
        ell_dval = jnp.pad(ell_dval, padw, constant_values=jnp.nan)
        ell_dgid = jnp.pad(ell_dgid, padw, constant_values=-1)

    ok, dg = frontier_expand_pallas(
        lidx, pint, pflt, rows_b.astype(jnp.int32),
        ell_dst, ell_label, ell_dir, ell_dlab, ell_dval, ell_dgid,
        interpret=interpret)
    return ok[:, :W].astype(bool), dg[:, :W]


def frontier_expand_ref(rows_b, step_b, lidx_b, m,
                        ell_dst, ell_label, ell_dir,
                        ell_dlab, ell_dval, ell_dgid,
                        plan, n_steps):
    """jnp oracle with the identical adapter signature (tests diff the two)."""
    S = plan.src_slot.shape[0]
    s = jnp.clip(step_b, 0, S - 1)
    return ref.frontier_expand_ref(
        rows_b, step_b, lidx_b, m,
        ell_dst, ell_label, ell_dir, ell_dlab, ell_dval, ell_dgid,
        plan.edge_label[s], plan.direction[s], plan.dst_label[s],
        plan.dst_value_op[s], plan.dst_value[s], plan.dst_slot[s],
        plan.closes_cycle[s], n_steps)


def label_histogram(node_label, node_value, core_mask, label, value_op, value,
                    *, interpret=None):
    if interpret is None:
        interpret = not on_tpu()
    return label_histogram_pallas(node_label, node_value, core_mask,
                                  label, value_op, value, interpret=interpret)
