"""Public jit'd wrappers around the Pallas kernels.

Each wrapper:
  * adapts engine-level arguments to the kernel's packed layout,
  * pads the lane dimension to 128 multiples (TPU tile alignment),
  * selects interpret mode automatically off-TPU (the kernels TARGET TPU;
    interpret=True executes the kernel body in Python on CPU so correctness
    is validated everywhere),
  * has a pure-jnp twin in ref.py used by the tests as the oracle.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import ref
from .frontier_expand import (N_PINT, _P_ACTIVE, _P_CLOSES, _P_DIR, _P_DLAB,
                              _P_DOP, _P_DST, _P_EL, _P_STEP,
                              frontier_expand_pallas)
from .fused_frontier import (N_FPINT, _F_ACTIVE, _F_CLOSES, _F_DIR, _F_DLAB,
                             _F_DOP, _F_DST, _F_EL, _F_FGLIDX, _F_FGOWNER,
                             _F_ISLAST, _F_NCORE, _F_USEDG,
                             fused_frontier_pallas)
from .label_histogram import label_histogram_pallas

LANE = 128


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def frontier_expand(rows_b, step_b, lidx_b, m,
                    ell_dst, ell_label, ell_dir,
                    ell_dlab, ell_dval, ell_dgid,
                    plan, n_steps, *, interpret=None):
    """Engine-facing adapter with the same signature/semantics as the jnp
    match in engine._match_tile_jnp (minus row construction).

    Returns (ok [EB, W] bool, dg [EB, W] int32) for the ORIGINAL width W.
    """
    if interpret is None:
        interpret = not on_tpu()
    EB = rows_b.shape[0]
    Np, W = ell_dst.shape
    S = plan.src_slot.shape[0]

    s = jnp.clip(step_b, 0, S - 1)
    active = (m & (step_b < n_steps)).astype(jnp.int32)
    pint = jnp.zeros((EB, N_PINT), jnp.int32)
    pint = pint.at[:, _P_EL].set(plan.edge_label[s])
    pint = pint.at[:, _P_DIR].set(plan.direction[s])
    pint = pint.at[:, _P_DLAB].set(plan.dst_label[s])
    pint = pint.at[:, _P_DOP].set(plan.dst_value_op[s])
    pint = pint.at[:, _P_DST].set(plan.dst_slot[s])
    pint = pint.at[:, _P_CLOSES].set(plan.closes_cycle[s])
    pint = pint.at[:, _P_STEP].set(step_b)
    pint = pint.at[:, _P_ACTIVE].set(active)
    pflt = plan.dst_value[s].astype(jnp.float32)
    lidx = jnp.clip(lidx_b, 0, Np - 1).astype(jnp.int32)

    # pad the lane dim to 128 (padding edges: dst -1 -> never match)
    Wp = _round_up(W, LANE)
    if Wp != W:
        padw = [(0, 0), (0, Wp - W)]
        ell_dst = jnp.pad(ell_dst, padw, constant_values=-1)
        ell_label = jnp.pad(ell_label, padw, constant_values=-2)
        ell_dir = jnp.pad(ell_dir, padw)
        ell_dlab = jnp.pad(ell_dlab, padw, constant_values=-2)
        ell_dval = jnp.pad(ell_dval, padw, constant_values=jnp.nan)
        ell_dgid = jnp.pad(ell_dgid, padw, constant_values=-1)

    ok, dg = frontier_expand_pallas(
        lidx, pint, pflt, rows_b.astype(jnp.int32),
        ell_dst, ell_label, ell_dir, ell_dlab, ell_dval, ell_dgid,
        interpret=interpret)
    return ok[:, :W].astype(bool), dg[:, :W]


def frontier_expand_ref(rows_b, step_b, lidx_b, m,
                        ell_dst, ell_label, ell_dir,
                        ell_dlab, ell_dval, ell_dgid,
                        plan, n_steps):
    """jnp oracle with the identical adapter signature (tests diff the two)."""
    S = plan.src_slot.shape[0]
    s = jnp.clip(step_b, 0, S - 1)
    return ref.frontier_expand_ref(
        rows_b, step_b, lidx_b, m,
        ell_dst, ell_label, ell_dir, ell_dlab, ell_dval, ell_dgid,
        plan.edge_label[s], plan.direction[s], plan.dst_label[s],
        plan.dst_value_op[s], plan.dst_value[s], plan.dst_slot[s],
        plan.closes_cycle[s], n_steps)


def denorm_locality(ell_dgid, g2l_row, owner):
    """Precompute the per-candidate locality tables the fused kernel needs.

    Denormalizes ``g2l_row[dst]`` / ``owner[dst]`` into two extra [Np, W]
    ELL-shaped tables so the kernel never performs a data-dependent gather.
    Call ONCE per evaluator invocation (outside the while loop) — the cost
    is amortized over every expansion iteration.

    Returns (ell_dlidx [Np, W] int32 — local idx of each candidate dst in
    this partition, -1 if absent/padded; ell_downer [Np, W] int32 — owner
    pid of each candidate dst).
    """
    dsafe = jnp.clip(ell_dgid, 0, g2l_row.shape[0] - 1)
    ell_dlidx = jnp.where(ell_dgid >= 0, jnp.take(g2l_row, dsafe),
                          jnp.int32(-1))
    ell_downer = jnp.take(owner, dsafe)
    return ell_dlidx.astype(jnp.int32), ell_downer.astype(jnp.int32)


def _fused_params(rows_b, step_b, m, g2l_row, owner, n_core, plan, n_steps):
    """Pack the per-binding SMEM scalars for the fused kernel."""
    EB = rows_b.shape[0]
    S = plan.src_slot.shape[0]
    V = g2l_row.shape[0]

    s = jnp.clip(step_b, 0, S - 1)
    active = (m & (step_b < n_steps)).astype(jnp.int32)
    ns = step_b + 1
    islast = (ns >= n_steps).astype(jnp.int32)
    s2 = jnp.clip(ns, 0, S - 1)
    nsrc = plan.src_slot[s2]            # src slot of the NEXT plan step
    p_dst = plan.dst_slot[s]
    p_closes = plan.closes_cycle[s]
    # next frontier = freshly-bound dst iff the next step expands from the
    # slot this (non-cycle) step binds; otherwise an already-bound vertex
    use_dg = ((nsrc == p_dst) & (p_closes == 0)).astype(jnp.int32)
    fg_sc = jnp.take_along_axis(rows_b, nsrc[:, None], axis=1)[:, 0]
    fg_safe = jnp.clip(fg_sc, 0, V - 1)
    fg_lidx = jnp.where(fg_sc >= 0, jnp.take(g2l_row, fg_safe), jnp.int32(-1))
    fg_owner = jnp.take(owner, fg_safe)

    pint = jnp.zeros((EB, N_FPINT), jnp.int32)
    pint = pint.at[:, _F_EL].set(plan.edge_label[s])
    pint = pint.at[:, _F_DIR].set(plan.direction[s])
    pint = pint.at[:, _F_DLAB].set(plan.dst_label[s])
    pint = pint.at[:, _F_DOP].set(plan.dst_value_op[s])
    pint = pint.at[:, _F_DST].set(p_dst)
    pint = pint.at[:, _F_CLOSES].set(p_closes)
    pint = pint.at[:, _F_ACTIVE].set(active)
    pint = pint.at[:, _F_ISLAST].set(islast)
    pint = pint.at[:, _F_USEDG].set(use_dg)
    pint = pint.at[:, _F_FGLIDX].set(fg_lidx)
    pint = pint.at[:, _F_FGOWNER].set(fg_owner)
    pint = pint.at[:, _F_NCORE].set(jnp.int32(n_core))
    pflt = plan.dst_value[s].astype(jnp.float32)
    return pint, pflt, nsrc


def fused_frontier(rows_b, step_b, lidx_b, m,
                   ell_dst, ell_label, ell_dir,
                   ell_dlab, ell_dval, ell_dgid,
                   ell_dlidx, ell_downer,
                   g2l_row, owner, n_core,
                   plan, n_steps, *, interpret=None):
    """Engine-facing adapter for the fused expand+classify kernel.

    Same adapter contract as frontier_expand, plus the two denormalized
    locality tables from denorm_locality and the partition's g2l/owner/
    n_core context.  Returns six [EB, W] arrays for the ORIGINAL width W:
    (ok, done, keep, out) bool, (dg, dest) int32.
    """
    if interpret is None:
        interpret = not on_tpu()
    Np, W = ell_dst.shape

    pint, pflt, _ = _fused_params(rows_b, step_b, m, g2l_row, owner, n_core,
                                  plan, n_steps)
    lidx = jnp.clip(lidx_b, 0, Np - 1).astype(jnp.int32)

    # pad the lane dim to 128 (padding edges: dst -1 -> never match)
    Wp = _round_up(W, LANE)
    if Wp != W:
        padw = [(0, 0), (0, Wp - W)]
        ell_dst = jnp.pad(ell_dst, padw, constant_values=-1)
        ell_label = jnp.pad(ell_label, padw, constant_values=-2)
        ell_dir = jnp.pad(ell_dir, padw)
        ell_dlab = jnp.pad(ell_dlab, padw, constant_values=-2)
        ell_dval = jnp.pad(ell_dval, padw, constant_values=jnp.nan)
        ell_dgid = jnp.pad(ell_dgid, padw, constant_values=-1)
        ell_dlidx = jnp.pad(ell_dlidx, padw, constant_values=-1)
        ell_downer = jnp.pad(ell_downer, padw)

    ok, dg, done, keep, outm, dest = fused_frontier_pallas(
        lidx, pint, pflt, rows_b.astype(jnp.int32),
        ell_dst, ell_label, ell_dir, ell_dlab, ell_dval, ell_dgid,
        ell_dlidx, ell_downer,
        interpret=interpret)
    return (ok[:, :W].astype(bool), dg[:, :W], done[:, :W].astype(bool),
            keep[:, :W].astype(bool), outm[:, :W].astype(bool), dest[:, :W])


def fused_frontier_ref(rows_b, step_b, lidx_b, m,
                       ell_dst, ell_label, ell_dir,
                       ell_dlab, ell_dval, ell_dgid,
                       g2l_row, owner, n_core,
                       plan, n_steps):
    """jnp oracle with the identical adapter signature (tests diff the two)."""
    S = plan.src_slot.shape[0]
    s = jnp.clip(step_b, 0, S - 1)
    s2 = jnp.clip(step_b + 1, 0, S - 1)
    return ref.fused_frontier_ref(
        rows_b, step_b, lidx_b, m,
        ell_dst, ell_label, ell_dir, ell_dlab, ell_dval, ell_dgid,
        g2l_row, owner, n_core,
        plan.edge_label[s], plan.direction[s], plan.dst_label[s],
        plan.dst_value_op[s], plan.dst_value[s], plan.dst_slot[s],
        plan.closes_cycle[s], plan.src_slot[s2], n_steps)


def label_histogram(node_label, node_value, core_mask, label, value_op, value,
                    *, interpret=None):
    if interpret is None:
        interpret = not on_tpu()
    return label_histogram_pallas(node_label, node_value, core_mask,
                                  label, value_op, value, interpret=interpret)
