"""Pallas TPU kernels for PGQP-JAX hot spots.

  frontier_expand — one-edge expansion match (engine inner loop)
  label_histogram — SNI start-node counting (one-pass metric)

Each kernel ships with ops.py (jit'd wrapper; interpret mode off-TPU) and
ref.py (pure-jnp oracle).  See each module's docstring for the VMEM tiling.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
