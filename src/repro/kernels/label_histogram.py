"""Pallas TPU kernel: start-node label histogram (SNI metric, paper Sec. 5.1).

Counts core nodes matching (label, value predicate) — the one-pass metric
PGQP computes per partition to seed and update the SNI file.  Grid over node
blocks; each step reduces a (1, BN) VMEM tile to a partial count, and the
wrapper sums the [nb] partials (a two-level reduction keeps every block's
working set in VMEM and avoids cross-step accumulation hazards).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.graph import WILDCARD
from ..core.query import (OP_EQ, OP_GE, OP_GT, OP_LE, OP_LT, OP_NE, OP_NONE)

BLOCK_N = 1024


def _kernel(pint_ref, pflt_ref,         # scalar prefetch (SMEM)
            label_ref, value_ref, core_ref,   # VMEM (1, BN)
            out_ref):                   # VMEM (1, 1) partial count
    label = pint_ref[0]
    op = pint_ref[1]
    value = pflt_ref[0]

    lab = label_ref[0, :]
    val = value_ref[0, :]
    core = core_ref[0, :]

    ok = (core == 1) & ((label == WILDCARD) | (lab == label))
    finite = val == val
    cmp = (((op == OP_EQ) & (val == value))
           | ((op == OP_NE) & (val != value))
           | ((op == OP_LT) & (val < value))
           | ((op == OP_LE) & (val <= value))
           | ((op == OP_GT) & (val > value))
           | ((op == OP_GE) & (val >= value)))
    ok = ok & ((op == OP_NONE) | (finite & cmp))
    out_ref[0, 0] = ok.astype(jnp.int32).sum()


def label_histogram_pallas(node_label, node_value, core_mask,
                           label, value_op, value,
                           *, block_n: int = BLOCK_N, interpret: bool = True):
    """node_label [Np] i32, node_value [Np] f32, core_mask [Np] i32 (0/1).
    Returns scalar int32 count of matching core nodes."""
    Np = node_label.shape[0]
    nb = (Np + block_n - 1) // block_n
    pad = nb * block_n - Np
    lab = jnp.pad(node_label, (0, pad), constant_values=-2).reshape(nb, block_n)
    val = jnp.pad(node_value, (0, pad), constant_values=jnp.nan).reshape(nb, block_n)
    core = jnp.pad(core_mask.astype(jnp.int32), (0, pad)).reshape(nb, block_n)
    pint = jnp.stack([jnp.asarray(label, jnp.int32),
                      jnp.asarray(value_op, jnp.int32)])
    pflt = jnp.asarray(value, jnp.float32)[None]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block_n), lambda i, *_: (i, 0))] * 3,
        out_specs=pl.BlockSpec((1, 1), lambda i, *_: (i, 0)),
    )
    partials = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        interpret=interpret,
    )(pint, pflt, lab, val, core)
    return partials.sum(dtype=jnp.int32)
