"""Pallas TPU kernel: one-edge frontier expansion match (the engine hot spot).

Every engine iteration evaluates an [EB, W] tile of candidate edges — EB
active bindings x the ELLPACK adjacency width W — against the current plan
step's predicates.  This kernel fuses the whole match:

  * one row-gather of the 6 ELL tables per binding, expressed as a
    scalar-prefetch BlockSpec index_map (the Mosaic "gather rows" idiom used
    by MoE kernels): block (1, W) of each [Np, W] table, block index taken
    from the prefetched ``lidx`` scalar vector;
  * all predicate evaluation (edge label, direction, dst label, dst value
    comparison, injectivity, cycle closure) as branchless VPU ops on the
    (1, W) tile in VMEM.

Because dst-node attributes are denormalized into the ELL tables at
partition-build time (graph.py), the kernel performs NO data-dependent
gathers — each grid step's working set is six (1, W) VMEM tiles, with the
DMA for step i+1 overlapped with compute for step i by the Pallas pipeline.

Layout notes (TPU target):
  * W is padded to a multiple of 128 by the ops.py wrapper (lane dim),
  * per-binding scalars (plan-step params, binding rows for the injectivity
    check) ride in SMEM via scalar prefetch, not VMEM,
  * outputs are int32 masks — bool VMEM tiles are not supported by Mosaic.

Validated against ref.frontier_expand_ref in interpret mode (CPU) over a
shape/dtype sweep; see tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.graph import DIR_BACKWARD, DIR_FORWARD, DIR_UNDIRECTED, WILDCARD
from ..core.query import (OP_EQ, OP_GE, OP_GT, OP_LE, OP_LT, OP_NE, OP_NONE,
                          QDIR_ANY, QDIR_IN, QDIR_OUT)

# packed int-param column layout (pint[:, _P_*])
_P_EL, _P_DIR, _P_DLAB, _P_DOP, _P_DST, _P_CLOSES, _P_STEP, _P_ACTIVE = range(8)
N_PINT = 8


def _kernel(lidx_ref, pint_ref, pflt_ref, rows_ref,      # scalar prefetch (SMEM)
            ed_ref, el_ref, edir_ref, dlab_ref, dval_ref, dgid_ref,  # VMEM in
            ok_ref, dg_ref,                               # VMEM out
            *, q_pad: int):
    i = pl.program_id(0)

    p_el = pint_ref[i, _P_EL]
    p_dir = pint_ref[i, _P_DIR]
    p_dlab = pint_ref[i, _P_DLAB]
    p_dop = pint_ref[i, _P_DOP]
    p_dst = pint_ref[i, _P_DST]
    p_closes = pint_ref[i, _P_CLOSES]
    # _P_ACTIVE already folds m & (step < n_steps); computed by the wrapper
    # so the dynamic n_steps scalar never has to enter the kernel.
    active = pint_ref[i, _P_ACTIVE]
    p_dval = pflt_ref[i]

    ed = ed_ref[0, :]
    el = el_ref[0, :]
    edir = edir_ref[0, :]
    dl = dlab_ref[0, :]
    dv = dval_ref[0, :]
    dg = dgid_ref[0, :]

    edge_exists = ed >= 0
    elabel_ok = (p_el == WILDCARD) | (el == p_el)
    dir_ok = ((p_dir == QDIR_ANY)
              | (edir == DIR_UNDIRECTED)
              | ((p_dir == QDIR_OUT) & (edir == DIR_FORWARD))
              | ((p_dir == QDIR_IN) & (edir == DIR_BACKWARD)))
    dlabel_ok = (p_dlab == WILDCARD) | (dl == p_dlab)

    finite = dv == dv
    cmp = (((p_dop == OP_EQ) & (dv == p_dval))
           | ((p_dop == OP_NE) & (dv != p_dval))
           | ((p_dop == OP_LT) & (dv < p_dval))
           | ((p_dop == OP_LE) & (dv <= p_dval))
           | ((p_dop == OP_GT) & (dv > p_dval))
           | ((p_dop == OP_GE) & (dv >= p_dval)))
    dval_ok = (p_dop == OP_NONE) | (finite & cmp)

    # injectivity: dg must differ from every bound slot (static Q unroll)
    already = jnp.zeros_like(dg, dtype=jnp.bool_)
    for q in range(q_pad):
        already = already | (dg == rows_ref[i, q])
    inj_ok = ~already

    bound_dst = rows_ref[i, p_dst]
    cyc_ok = (p_closes == 1) & (dg == bound_dst)
    new_ok = (p_closes == 0) & dlabel_ok & dval_ok & inj_ok

    ok = ((active == 1)
          & edge_exists & elabel_ok & dir_ok & (cyc_ok | new_ok))
    ok_ref[0, :] = ok.astype(jnp.int32)
    dg_ref[0, :] = dg


def frontier_expand_pallas(lidx, pint, pflt, rows,
                           ell_dst, ell_label, ell_dir,
                           ell_dlab, ell_dval, ell_dgid,
                           *, interpret: bool = True):
    """Raw kernel invocation; ops.frontier_expand is the public wrapper.

    lidx [EB] int32 (clipped to [0, Np)), pint [EB, 8] int32, pflt [EB] f32,
    rows [EB, Q] int32, ell_* [Np, W] (W multiple of 128 on real TPU).
    Returns ok [EB, W] int32, dg [EB, W] int32.
    """
    EB = lidx.shape[0]
    Np, W = ell_dst.shape
    Q = rows.shape[1]

    ell_spec = pl.BlockSpec((1, W), lambda i, lidx_r, *_: (lidx_r[i], 0))
    out_spec = pl.BlockSpec((1, W), lambda i, *_: (i, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,           # lidx, pint, pflt, rows -> SMEM
        grid=(EB,),
        in_specs=[ell_spec] * 6,
        out_specs=[out_spec, out_spec],
    )
    kernel = functools.partial(_kernel, q_pad=Q)
    ok, dg = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((EB, W), jnp.int32),
                   jax.ShapeDtypeStruct((EB, W), jnp.int32)],
        interpret=interpret,
    )(lidx, pint, pflt, rows,
      ell_dst, ell_label, ell_dir, ell_dlab, ell_dval, ell_dgid)
    return ok, dg
