"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each function here defines the exact semantics its kernel twin must match;
tests sweep shapes/dtypes and assert allclose/array_equal against these.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.graph import DIR_BACKWARD, DIR_FORWARD, DIR_UNDIRECTED, WILDCARD
from ..core.query import (OP_EQ, OP_GE, OP_GT, OP_LE, OP_LT, OP_NE, OP_NONE,
                          QDIR_ANY, QDIR_IN, QDIR_OUT)


def value_pred(op, values, v):
    """Branchless value-predicate evaluation on arrays (NaN fails all ops)."""
    finite = values == values
    res = (
        ((op == OP_EQ) & (values == v))
        | ((op == OP_NE) & (values != v))
        | ((op == OP_LT) & (values < v))
        | ((op == OP_LE) & (values <= v))
        | ((op == OP_GT) & (values > v))
        | ((op == OP_GE) & (values >= v))
    )
    return (op == OP_NONE) | (finite & res)


def frontier_expand_ref(rows_b, step_b, lidx_b, m,
                        ell_dst, ell_label, ell_dir,
                        ell_dlab, ell_dval, ell_dgid,
                        p_el, p_dir, p_dlab, p_dop, p_dval, p_dst, p_closes,
                        n_steps):
    """One-edge expansion match over an [EB, W] candidate tile.

    Args (EB bindings, W = ELL width, Q = binding row width):
      rows_b   [EB, Q] int32  — current bindings (global vertex ids, -1 unbound)
      step_b   [EB]    int32  — next plan step per row
      lidx_b   [EB]    int32  — local index of the frontier vertex
      m        [EB]    bool   — row-active mask
      ell_*    [Np, W]        — ELLPACK adjacency + denormalized dst attrs
      p_*      [EB]           — per-row plan-step parameters (pre-gathered)
      n_steps  scalar int32

    Returns: ok [EB, W] bool match mask, dg [EB, W] int32 dst global ids.
    """
    lsafe = jnp.clip(lidx_b, 0, ell_dst.shape[0] - 1)
    ed = jnp.take(ell_dst, lsafe, axis=0)
    el = jnp.take(ell_label, lsafe, axis=0)
    edir = jnp.take(ell_dir, lsafe, axis=0)
    dl = jnp.take(ell_dlab, lsafe, axis=0)
    dv = jnp.take(ell_dval, lsafe, axis=0)
    dg = jnp.take(ell_dgid, lsafe, axis=0)

    edge_exists = ed >= 0
    elabel_ok = (p_el[:, None] == WILDCARD) | (el == p_el[:, None])
    dir_ok = ((p_dir[:, None] == QDIR_ANY)
              | (edir == DIR_UNDIRECTED)
              | ((p_dir[:, None] == QDIR_OUT) & (edir == DIR_FORWARD))
              | ((p_dir[:, None] == QDIR_IN) & (edir == DIR_BACKWARD)))
    dlabel_ok = (p_dlab[:, None] == WILDCARD) | (dl == p_dlab[:, None])
    dval_ok = value_pred(p_dop[:, None], dv, p_dval[:, None])
    inj_ok = ~jnp.any(rows_b[:, None, :] == dg[:, :, None], axis=-1)
    bound_dst = jnp.take_along_axis(rows_b, p_dst[:, None], axis=1)
    cyc_ok = (p_closes[:, None] == 1) & (bound_dst == dg)
    new_ok = (p_closes[:, None] == 0) & dlabel_ok & dval_ok & inj_ok
    ok = (m[:, None] & (step_b[:, None] < n_steps)
          & edge_exists & elabel_ok & dir_ok & (cyc_ok | new_ok))
    return ok, dg


def fused_frontier_ref(rows_b, step_b, lidx_b, m,
                       ell_dst, ell_label, ell_dir,
                       ell_dlab, ell_dval, ell_dgid,
                       g2l_row, owner, n_core,
                       p_el, p_dir, p_dlab, p_dop, p_dval, p_dst, p_closes,
                       nsrc, n_steps):
    """Fused expansion + answer-emission classification (oracle for
    fused_frontier.py).  Extends frontier_expand_ref with the routing
    decision the engine loop makes for every produced row.

    Extra args over frontier_expand_ref:
      g2l_row [V]  int32 — global->local index for THIS partition (-1 absent)
      owner   [V]  int32 — owning partition id per global vertex
      n_core  scalar     — #core nodes of this partition
      nsrc    [EB] int32 — src slot of the NEXT plan step (pre-gathered)

    Returns six [EB, W] arrays: ok/done/keep/out bool, dg/dest int32, as
      ok   — candidate matched this step's predicates
      done — matched and the plan is complete (append to FAA)
      keep — matched, continues, next frontier is core-local (work buffer)
      out  — matched, continues, next frontier owned elsewhere
      dest — owner pid of the next frontier vertex (meaningful where out)
    """
    ok, dg = frontier_expand_ref(
        rows_b, step_b, lidx_b, m,
        ell_dst, ell_label, ell_dir, ell_dlab, ell_dval, ell_dgid,
        p_el, p_dir, p_dlab, p_dop, p_dval, p_dst, p_closes, n_steps)

    Q = rows_b.shape[1]
    col = jnp.arange(Q, dtype=jnp.int32)
    setcol = ((col[None, None, :] == p_dst[:, None, None])
              & (p_closes[:, None, None] == 0))
    nr = jnp.where(setcol, dg[:, :, None], rows_b[:, None, :])  # [EB, W, Q]
    ns = jnp.broadcast_to(step_b[:, None] + 1, ok.shape)

    done = ok & (ns >= n_steps)
    fg = jnp.take_along_axis(nr, nsrc[:, None, None], axis=2)[:, :, 0]
    fg_safe = jnp.clip(fg, 0, g2l_row.shape[0] - 1)
    l2 = jnp.take(g2l_row, fg_safe)
    local = (l2 >= 0) & (l2 < n_core) & (fg >= 0)
    keep = ok & ~done & local
    outm = ok & ~done & ~local
    dest = jnp.take(owner, fg_safe)
    return ok, dg, done, keep, outm, dest


def label_histogram_ref(node_label, node_value, n_core_mask,
                        label, value_op, value):
    """#nodes matching (label, value predicate) among core nodes.

    node_label [Np] int32, node_value [Np] f32, n_core_mask [Np] bool.
    Returns scalar int32 count.
    """
    ok = n_core_mask & ((label == WILDCARD) | (node_label == label))
    ok = ok & value_pred(value_op, node_value, value)
    return ok.sum(dtype=jnp.int32)


def masked_count_ref(mask):
    """Total number of set bits, int32 (used for SNI metric updates)."""
    return mask.sum(dtype=jnp.int32)
