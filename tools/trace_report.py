#!/usr/bin/env python
"""Explain a serve.py --trace-out Chrome trace: latency decomposition,
heuristic load-order rationale, admission verdicts, CI well-formedness.

The trace is the one source of truth for two questions the counters
can't answer:

  "what dominated latency?"  — every query root span is decomposed into
      the *self time* of its descendant spans (a child's duration minus
      its own children's durations), grouped by span name, so nested
      spans (kernel.compile inside kernel.eval inside opat.round) are
      never double-counted.  Store loads split by tier
      (cold/warm/prefetch).

  "why was P3 loaded before P1?" — heuristic decision records carry the
      full per-partition score breakdown (SNI term, completion-rate
      term, fairness-aging term, deadline-urgency term) that produced
      each ranking; this tool replays them, verifies the recorded
      winner really is the argmax of the recorded scores, and with
      ``--why A B`` prints the term-by-term comparison at every round
      where both partitions were candidates.

  "is the kernel near its roofline?" — ``kernel.eval`` spans carry the
      cost attribution stamped by obs/profile.py (predicted FLOPs/bytes
      from the bucket's lowered HLO plus the roofline-bound time);
      ``--cost`` joins that prediction with the measured steady-state
      wall time per compiled bucket: achieved FLOP/s, bound-vs-measured
      ratio (% of roofline), and the live-device-byte watermark.  The
      first call of each bucket (jit trace + compile) is excluded from
      the steady-state mean.

Modes:
    python tools/trace_report.py trace.json            # full report
    python tools/trace_report.py trace.json --why 3 1  # rank rationale
    python tools/trace_report.py trace.json --cost     # kernel cost table
    python tools/trace_report.py trace.json --check    # CI gate

``--check`` exits non-zero unless the trace is non-empty, every span
nests inside its recorded parent, every query root span is closed
(non-zero duration once it has children), every recorded heuristic
choice is score-consistent, and cost attribution is all-or-none: if any
``kernel.eval`` span carries cost attrs, every one must (a partially
attributed trace means a kernel call site skipped the profiler).
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Any, Dict, List, Optional

# nesting tolerance: perf_counter stamps of parent/child are taken a few
# statements apart; allow this much slack (microseconds) either side
NEST_TOL_US = 200.0


def load_trace(path: str) -> Dict[str, List[Dict[str, Any]]]:
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents", doc if isinstance(doc, list) else [])
    spans = [e for e in events if e.get("ph") == "X"]
    decisions = [e for e in events if e.get("ph") == "i"
                 and e.get("cat") == "decision"]
    return {"spans": spans, "decisions": decisions}


def index_spans(spans: List[Dict[str, Any]]):
    by_id: Dict[int, Dict[str, Any]] = {}
    children: Dict[Optional[int], List[Dict[str, Any]]] = defaultdict(list)
    for sp in spans:
        sid = sp.get("args", {}).get("span_id")
        if sid is not None:
            by_id[sid] = sp
        children[sp.get("args", {}).get("parent_id")].append(sp)
    return by_id, children


def _bucket(sp: Dict[str, Any]) -> str:
    """Aggregation key for the decomposition: store loads split by the
    residency tier the span recorded."""
    name = sp["name"]
    tier = sp.get("args", {}).get("tier")
    if name == "store.load" and tier:
        return f"store.load[{tier}]"
    return name


def decompose(root: Dict[str, Any], children) -> Dict[str, float]:
    """Self-time (µs) of the root and every descendant, by bucket."""
    out: Dict[str, float] = defaultdict(float)

    def walk(sp: Dict[str, Any]) -> None:
        sid = sp.get("args", {}).get("span_id")
        kids = children.get(sid, []) if sid is not None else []
        self_us = sp.get("dur", 0.0) - sum(k.get("dur", 0.0) for k in kids)
        out[_bucket(sp)] += max(self_us, 0.0)
        for k in kids:
            walk(k)

    sid = root.get("args", {}).get("span_id")
    for k in (children.get(sid, []) if sid is not None else []):
        walk(k)
    tracked = sum(out.values())
    out["(untracked)"] = max(root.get("dur", 0.0) - tracked, 0.0)
    return dict(out)


def fmt_us(us: float) -> str:
    return f"{us / 1000.0:9.2f} ms"


def report_queries(spans, children, top: int, name_filter: str) -> None:
    roots = [sp for sp in spans if sp["name"] == "query"]
    if name_filter:
        roots = [sp for sp in roots
                 if name_filter in str(sp.get("args", {}).get("query", ""))]
    if not roots:
        print("no query spans recorded")
        return
    print(f"== {len(roots)} queries ==")
    for sp in sorted(roots, key=lambda s: -s.get("dur", 0.0))[:top]:
        a = sp.get("args", {})
        label = a.get("query", "?")
        gen = a.get("generation")
        print(f"\nquery {label}"
              + (f" (generation {gen})" if gen is not None else "")
              + f": total {fmt_us(sp.get('dur', 0.0)).strip()},"
              f" answers={a.get('n_answers', '?')}"
              f" loads={a.get('n_loads', '?')}")
        parts = decompose(sp, children)
        total = max(sp.get("dur", 0.0), 1e-9)
        for bucket, us in sorted(parts.items(), key=lambda kv: -kv[1]):
            if us <= 0.0:
                continue
            print(f"  {bucket:<24} {fmt_us(us)}  {us / total:6.1%}")


def report_aggregate(spans) -> None:
    agg: Dict[str, List[float]] = defaultdict(list)
    for sp in spans:
        agg[_bucket(sp)].append(sp.get("dur", 0.0))
    print("\n== span totals (wall, unnested) ==")
    for name, durs in sorted(agg.items(),
                             key=lambda kv: -sum(kv[1])):
        print(f"  {name:<24} n={len(durs):5d}  total {fmt_us(sum(durs))}"
              f"  mean {fmt_us(sum(durs) / len(durs))}")


def _rank_records(decisions):
    return [d for d in decisions
            if d["name"] in ("heuristic.rank", "heuristic.rank_shared")]


def verify_rankings(decisions) -> List[str]:
    """Every recorded choice must be the argmax of its own recorded
    scores (ties allowed: the tie-break is random by design)."""
    problems = []
    for i, d in enumerate(_rank_records(decisions)):
        a = d.get("args", {})
        breakdown = a.get("breakdown", {})
        chosen = a.get("chosen")
        if not breakdown or chosen is None:
            continue
        best = max(v.get("score", 0.0) for v in breakdown.values())
        got = breakdown.get(str(chosen), breakdown.get(chosen, {}))
        if abs(got.get("score", 0.0) - best) > 1e-9 * max(1.0, abs(best)):
            problems.append(
                f"ranking #{i}: chosen P{chosen} score "
                f"{got.get('score')} != max score {best}")
    return problems


def report_rankings(decisions, top: int) -> None:
    recs = _rank_records(decisions)
    if not recs:
        return
    print(f"\n== heuristic rankings ({len(recs)} decisions) ==")
    for i, d in enumerate(recs[:top]):
        a = d.get("args", {})
        ranked = a.get("ranked", [])
        print(f"\n[{i}] {d['name']} heuristic={a.get('heuristic')}"
              f" -> loads {ranked}")
        breakdown = a.get("breakdown", {})
        for pid in ranked:
            b = breakdown.get(str(pid), breakdown.get(pid, {}))
            terms = ", ".join(f"{k}={b[k]:g}" if isinstance(b[k], float)
                              else f"{k}={b[k]}"
                              for k in ("sni", "completion_rate", "base",
                                        "fairness", "urgency")
                              if k in b)
            print(f"    P{pid}: score={b.get('score', 0.0):g}  ({terms})")
    if len(recs) > top:
        print(f"  ... {len(recs) - top} more (raise --top)")


def report_why(decisions, a_pid: str, b_pid: str) -> None:
    """Term-by-term comparison of two partitions at every ranking
    where both were candidates — the recorded answer to 'why was
    P{a} loaded before P{b}?'."""
    recs = _rank_records(decisions)
    seen = 0
    for i, d in enumerate(recs):
        args = d.get("args", {})
        breakdown = args.get("breakdown", {})
        a = breakdown.get(a_pid, breakdown.get(int(a_pid), None)
                          if a_pid.isdigit() else None)
        b = breakdown.get(b_pid, breakdown.get(int(b_pid), None)
                          if b_pid.isdigit() else None)
        if not a or not b:
            continue
        seen += 1
        ranked = args.get("ranked", [])
        pos = {str(p): j for j, p in enumerate(ranked)}
        first = a_pid if pos.get(a_pid, 1 << 30) < pos.get(b_pid, 1 << 30) \
            else b_pid
        print(f"\n[{i}] {d['name']} ({args.get('heuristic')}): "
              f"P{first} ranked first  (order {ranked})")
        keys = sorted(set(a) | set(b))
        for k in keys:
            va, vb = a.get(k, 0.0), b.get(k, 0.0)
            marker = "  <-- deciding term" if k == "score" and va != vb \
                else ""
            print(f"    {k:<16} P{a_pid}={va:g}  P{b_pid}={vb:g}{marker}")
        if a.get("score") == b.get("score"):
            print("    scores tie: order fell to the random tie-break")
    if not seen:
        print(f"P{a_pid} and P{b_pid} were never ranked together "
              f"in this trace")


def report_admissions(decisions, top: int) -> None:
    recs = [d for d in decisions if d["name"] == "frontend.admit"]
    if not recs:
        return
    print(f"\n== admission decisions ({len(recs)}) ==")
    for d in recs[:top]:
        a = d.get("args", {})
        pred = a.get("predicted_latency_s")
        dl = a.get("deadline_s")
        backlog = a.get("backlog_s")
        detail = []
        if pred is not None:
            detail.append(f"predicted={pred * 1000:.0f}ms")
        if backlog is not None:
            detail.append(f"backlog={backlog * 1000:.0f}ms")
        if dl is not None:
            detail.append(f"deadline={dl * 1000:.0f}ms"
                          if dl != float("inf") else "deadline=inf")
        if a.get("reason"):
            detail.append(f"reason={a['reason']}")
        print(f"  {a.get('query', '?'):<24} [{a.get('slo_class')}] "
              f"{a.get('outcome', '?'):<8} {' '.join(detail)}")
    if len(recs) > top:
        print(f"  ... {len(recs) - top} more (raise --top)")


_COST_ATTRS = ("kernel_key", "cost_flops", "cost_bytes",
               "cost_t_bound_us", "cost_dominant")


def _kernel_spans(spans):
    return [sp for sp in spans if sp["name"] == "kernel.eval"]


def report_cost(spans) -> None:
    """Per-compiled-bucket cost attribution: measured steady-state wall
    time joined with the predicted FLOPs/bytes/roofline bound the
    profiler stamped on every ``kernel.eval`` span."""
    groups: Dict[str, List[Dict[str, Any]]] = defaultdict(list)
    for sp in _kernel_spans(spans):
        key = sp.get("args", {}).get("kernel_key")
        if key is not None:
            groups[key].append(sp)
    if not groups:
        print("no cost-attributed kernel.eval spans (profiling off, or a "
              "pre-PR-10 trace)")
        return
    print(f"== kernel cost attribution ({len(groups)} compiled buckets) ==")
    print(f"  {'bucket':<20} {'calls':>5} {'steady ms':>10} "
          f"{'pred GFLOP':>10} {'pred GB':>8} {'achieved':>12} "
          f"{'roofline%':>9}  bound   {'peak dev MB':>11}")
    for key in sorted(groups):
        sps = groups[key]
        steady = [sp for sp in sps
                  if not sp.get("args", {}).get("first_call")]
        timed = steady if steady else sps  # single-call bucket: use it
        mean_us = sum(sp.get("dur", 0.0) for sp in timed) / len(timed)
        a = sps[0].get("args", {})
        flops = float(a.get("cost_flops", 0.0))
        nbytes = float(a.get("cost_bytes", 0.0))
        bound_us = float(a.get("cost_t_bound_us", 0.0))
        dominant = a.get("cost_dominant", "?")
        # achieved throughput from the measured mean; roofline% is how
        # close measurement came to the model's bound (100% = at the
        # bound; <100% = overhead the roofline doesn't model)
        gflops = (flops / mean_us) / 1e3 if mean_us > 0 else 0.0
        roof = 100.0 * bound_us / mean_us if mean_us > 0 else 0.0
        live = max((float(sp.get("args", {}).get("device_live_bytes", 0.0))
                    for sp in sps), default=0.0)
        print(f"  {key:<20} {len(sps):>5} {mean_us / 1e3:>10.3f} "
              f"{flops / 1e9:>10.3f} {nbytes / 1e9:>8.3f} "
              f"{gflops:>8.2f} GF/s {roof:>8.2f}%  {dominant:<7}"
              f"{live / 1e6:>11.2f}")
    errs = sorted({(k, g[0].get("args", {}).get("cost_error"))
                   for k, g in groups.items()
                   if g[0].get("args", {}).get("cost_error")})
    for k, e in errs:
        print(f"  !! {k}: attribution failed ({e}) — costs read 0")


def check_cost_attribution(spans) -> List[str]:
    """All-or-none: once any ``kernel.eval`` span carries cost attrs,
    every one must — a partially stamped trace means one of the engines'
    kernel call sites bypassed the profiler."""
    kspans = _kernel_spans(spans)
    attributed = [sp for sp in kspans
                  if sp.get("args", {}).get("kernel_key") is not None]
    if not attributed:
        return []
    problems = []
    for sp in kspans:
        a = sp.get("args", {})
        missing = [k for k in _COST_ATTRS if k not in a]
        if missing:
            problems.append(
                f"kernel.eval span {a.get('span_id')} "
                f"(engine={a.get('engine')}) lacks cost attrs "
                f"{missing} while {len(attributed)} other kernel spans "
                f"are attributed")
    return problems


def check(trace) -> int:
    """CI gate: 0 iff the trace is non-empty, well-nested, every query
    span closed, and every recorded ranking score-consistent."""
    spans, decisions = trace["spans"], trace["decisions"]
    errors: List[str] = []
    if not spans:
        errors.append("trace has no spans")
    by_id, children = index_spans(spans)
    for sp in spans:
        a = sp.get("args", {})
        pid = a.get("parent_id")
        if pid is None:
            continue
        parent = by_id.get(pid)
        if parent is None:
            errors.append(f"span {a.get('span_id')} ({sp['name']}) "
                          f"references missing parent {pid}")
            continue
        # a child recorded on another thread (read_ahead worker) never
        # carries a parent_id, so strict containment applies to the rest
        p0 = parent["ts"] - NEST_TOL_US
        p1 = parent["ts"] + parent.get("dur", 0.0) + NEST_TOL_US
        c0, c1 = sp["ts"], sp["ts"] + sp.get("dur", 0.0)
        if c0 < p0 or c1 > p1:
            errors.append(
                f"span {a.get('span_id')} ({sp['name']}) "
                f"[{c0:.1f}, {c1:.1f}]us escapes parent "
                f"{pid} ({parent['name']}) [{p0:.1f}, {p1:.1f}]us")
    for sp in spans:
        if sp["name"] != "query":
            continue
        sid = sp.get("args", {}).get("span_id")
        if sp.get("dur", 0.0) <= 0.0 and children.get(sid):
            errors.append(f"query span {sid} "
                          f"({sp.get('args', {}).get('query')}) has "
                          f"children but zero duration (never closed?)")
    errors.extend(verify_rankings(decisions))
    errors.extend(check_cost_attribution(spans))
    if errors:
        for e in errors[:20]:
            print(f"CHECK FAIL: {e}", file=sys.stderr)
        if len(errors) > 20:
            print(f"... {len(errors) - 20} more", file=sys.stderr)
        return 1
    n_q = sum(1 for sp in spans if sp["name"] == "query")
    print(f"trace OK: {len(spans)} spans ({n_q} queries), "
          f"{len(decisions)} decisions, all nested and score-consistent")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(
        description="explain a serve.py --trace-out trace")
    ap.add_argument("trace", help="Chrome trace-event JSON from "
                                  "serve.py --trace-out")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: validate and exit (non-zero on a "
                         "malformed or inconsistent trace)")
    ap.add_argument("--why", nargs=2, metavar=("A", "B"),
                    help="explain why partition A was ranked before B "
                         "(term-by-term score comparison per round)")
    ap.add_argument("--cost", action="store_true",
                    help="per-kernel cost attribution table: measured "
                         "steady-state time vs the predicted FLOPs/bytes/"
                         "roofline bound stamped by the resource profiler")
    ap.add_argument("--query", default="",
                    help="only decompose queries whose name contains this")
    ap.add_argument("--top", type=int, default=10,
                    help="max queries / decisions to print (default 10)")
    args = ap.parse_args()

    trace = load_trace(args.trace)
    if args.check:
        sys.exit(check(trace))
    if args.why:
        report_why(trace["decisions"], args.why[0], args.why[1])
        return
    if args.cost:
        report_cost(trace["spans"])
        return
    spans = trace["spans"]
    _, children = index_spans(spans)
    report_queries(spans, children, args.top, args.query)
    report_aggregate(spans)
    if any(sp.get("args", {}).get("kernel_key") is not None
           for sp in _kernel_spans(spans)):
        print()
        report_cost(spans)
    report_rankings(trace["decisions"], args.top)
    report_admissions(trace["decisions"], args.top)
    problems = verify_rankings(trace["decisions"])
    if problems:
        print("\n!! score inconsistencies:")
        for p in problems:
            print(f"  {p}")
        sys.exit(1)


if __name__ == "__main__":
    main()
