#!/usr/bin/env python
"""Docs link check: fail on dead *relative* links in markdown files.

    python tools/check_links.py [FILE_OR_DIR ...]

Defaults to README.md + docs/.  External links (any scheme://, mailto:)
and pure in-page anchors (#...) are out of scope — this is the CI gate
that README/docs never point at files that do not exist in the checkout.
Directories are scanned recursively for *.md.  Leading-"/" targets are
treated as repo-root-absolute (GitHub style) and resolved against the
working directory, so run this from the repo root.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import Iterable, List

# [text](target) — target up to the first unescaped closing paren/space
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SCHEME_RE = re.compile(r"^[A-Za-z][A-Za-z0-9+.-]*:")


def find_dead_links(paths: Iterable[str],
                    root: Path | None = None) -> List[str]:
    """``root`` anchors leading-"/" (repo-root-absolute) link targets;
    defaults to the working directory for CLI use — pass it explicitly
    when the caller's cwd is not the repo root."""
    root = Path.cwd() if root is None else Path(root)
    files: List[Path] = []
    for p in (Path(p) for p in paths):
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        else:
            files.append(p)
    dead: List[str] = []
    for f in files:
        if not f.exists():
            dead.append(f"{f}: (file itself is missing)")
            continue
        for m in _LINK_RE.finditer(f.read_text()):
            target = m.group(1)
            if _SCHEME_RE.match(target) or target.startswith("#"):
                continue                       # external / in-page anchor
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if rel.startswith("/"):
                # GitHub-style repo-root-absolute link
                resolved = root / rel.lstrip("/")
            else:
                resolved = f.parent / rel
            if not resolved.exists():
                dead.append(f"{f}: {target}")
    return dead


def main(argv: List[str]) -> int:
    paths = argv or ["README.md", "docs"]
    dead = find_dead_links(paths)
    if dead:
        print(f"{len(dead)} dead relative link(s):")
        for d in dead:
            print(f"  {d}")
        return 1
    print(f"link check OK ({', '.join(paths)})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
