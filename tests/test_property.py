"""Hypothesis property tests: the system invariant is

    OPAT(partitioned graph, any scheme, any heuristic) == oracle(whole graph)

for random graphs and random (connected) queries — the paper's correctness
claim (Sec. 4.2) exercised adversarially.  Also: partitioner validity and
plan well-formedness under the same generators.
"""
import os
import shutil
import tempfile

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (EngineConfig, GraphSession, MAX_SN, MIN_SN,
                        RANDOM_SN, OPATEngine, build_catalog,
                        build_partitions, generate_plan, match_query,
                        partition_graph)
from repro.core.graph import GraphBuilder
from repro.core.query import Query, QueryEdge, QueryNode

SETTINGS = dict(max_examples=25, deadline=None,
                suppress_health_check=[HealthCheck.too_slow,
                                       HealthCheck.data_too_large])


@st.composite
def random_graph(draw):
    n = draw(st.integers(8, 60))
    n_vl = draw(st.integers(2, 6))
    n_el = draw(st.integers(1, 4))
    density = draw(st.floats(1.0, 3.0))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    b = GraphBuilder()
    for i in range(n):
        val = float(rng.integers(0, 10)) if rng.random() < 0.5 else None
        b.add_node(f"L{int(rng.integers(0, n_vl))}", value=val)
    m = int(n * density)
    for _ in range(m):
        s, d = rng.integers(0, n, size=2)
        if s == d:
            continue
        b.add_edge(int(s), int(d), f"E{int(rng.integers(0, n_el))}",
                   directed=bool(rng.random() < 0.3))
    return b.build(), seed


@st.composite
def random_query(draw, n_vl=6, n_el=4):
    nq = draw(st.integers(1, 4))
    nodes = []
    for _ in range(nq):
        wild = draw(st.booleans())
        label = "?" if wild else f"L{draw(st.integers(0, n_vl - 1))}"
        if draw(st.booleans()):
            nodes.append(QueryNode(label,
                                   value_op=draw(st.sampled_from(
                                       ["", "=", "!=", "<", ">="])),
                                   value=float(draw(st.integers(0, 10)))))
        else:
            nodes.append(QueryNode(label))
    edges = []
    for i in range(1, nq):   # spanning-tree edges keep the pattern connected
        j = draw(st.integers(0, i - 1))
        el = "?" if draw(st.booleans()) else f"E{draw(st.integers(0, n_el - 1))}"
        edges.append(QueryEdge(j, i, el,
                               direction=draw(st.integers(0, 2))))
    q = Query(nodes=nodes, edges=edges, name="hq")
    q.validate()
    return q


@given(gq=random_graph(), q=random_query(),
       k=st.integers(1, 4),
       scheme=st.sampled_from(["fast", "kway_shem", "ecosocial", "rb_shem"]),
       heuristic=st.sampled_from([MAX_SN, MIN_SN, RANDOM_SN]))
@settings(**SETTINGS)
def test_partitioned_equals_oracle(gq, q, k, scheme, heuristic):
    g, seed = gq
    assign = partition_graph(g, k, scheme, seed=seed % 97)
    pg = build_partitions(g, assign, k)
    cat = build_catalog(g)
    plan = generate_plan(q, g, cat)
    eng = OPATEngine(pg, EngineConfig(cap=16384, q_pad=8))
    res = eng.run(plan, heuristic, seed=seed % 89)
    ref = match_query(g, q, q_pad=8)
    got = np.unique(res.answers, axis=0)
    assert got.shape == ref.shape and np.array_equal(got, ref)


@given(gq=random_graph(), q=random_query(), k=st.integers(1, 3),
       n_ops=st.integers(1, 8))
@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
def test_streaming_interleaving_equals_fresh_save(gq, q, k, n_ops):
    """ISSUE 8 rebuild-equivalence property: after a RANDOM interleaving
    of inserts, deletes, and compactions, (a) the pending-delta overlay
    answers exactly like the oracle over a from-scratch build of the same
    final edge set, and (b) folding every partition (``compact_all``)
    changes no answer — deltas are invisible to query semantics."""
    from test_mutation import Mirror, graph_canon, random_ops
    from repro.storage import save_partitioned_graph
    from repro.storage.deltas import open_mutable
    g, seed = gq
    rng = np.random.default_rng(seed)
    assign = partition_graph(g, k, "fast", seed=seed % 97)
    pg = build_partitions(g, assign, k, scheme="fast")
    root = tempfile.mkdtemp(prefix="pgqp-prop-")
    try:
        gdir = os.path.join(root, "g")
        save_partitioned_graph(pg, gdir)
        mdir = open_mutable(gdir)
        mirror = Mirror(g)
        for op in random_ops(rng, mirror, k, n_ops):
            mdir.apply_op(op)
            if rng.random() < 0.25:
                mdir.compact(int(rng.integers(k)))
        view = mdir.snapshot()
        try:
            assert graph_canon(view.graph) == mirror.canon()
        finally:
            view.release()
        # (a) serve the final generation WITH its pending deltas
        fresh = mirror.to_graph()
        ref = match_query(fresh, q, q_pad=8)
        sess = GraphSession.open(gdir, engine="opat", seed=int(seed % 89),
                                 config=EngineConfig(cap=16384, q_pad=8))
        got = np.unique(sess.submit(q).answers, axis=0)
        assert got.shape == ref.shape and np.array_equal(got, ref)
        # (b) folding is answer-invariant
        sess.compact_all()
        got2 = np.unique(sess.submit(q).answers, axis=0)
        assert np.array_equal(got2, ref)
    finally:
        shutil.rmtree(root, ignore_errors=True)


@given(gq=random_graph(), k=st.integers(1, 5),
       scheme=st.sampled_from(["fast", "eco", "fastsocial", "kway_shem"]))
@settings(**SETTINGS)
def test_partition_is_total_function(gq, k, scheme):
    g, seed = gq
    assign = partition_graph(g, k, scheme, seed=seed % 97)
    assert assign.shape == (g.n_nodes,)
    assert assign.min() >= 0 and assign.max() < k
    pg = build_partitions(g, assign, k)
    cores = np.concatenate([p.node_gid[: p.n_core] for p in pg.parts])
    assert sorted(cores.tolist()) == list(range(g.n_nodes))
    total = sum(int(p.row_ptr[p.n_core]) for p in pg.parts)
    assert total == 2 * g.n_edges


@given(gq=random_graph(), q=random_query())
@settings(**SETTINGS)
def test_plan_well_formed(gq, q):
    g, _ = gq
    cat = build_catalog(g)
    plan = generate_plan(q, g, cat)
    assert plan.n_steps == len(q.edges)
    bound = {plan.start_slot}
    for s in plan.steps:
        assert s.src_slot in bound
        bound.add(s.dst_slot)
    assert bound == set(range(q.n_nodes))
    assert plan.est_cost >= 0.0
