"""Partitioned-graph construction invariants (paper Fig. 1 representation)."""
import numpy as np
import networkx as nx
import pytest

from repro.core import build_partitions, partition_graph
from repro.core.graph import GraphBuilder


def nx_of(graph):
    g = nx.Graph()
    g.add_nodes_from(range(graph.n_nodes))
    g.add_edges_from(zip(graph.edge_src.tolist(), graph.edge_dst.tolist()))
    return g


@pytest.mark.parametrize("k", [1, 2, 4, 7])
def test_partition_covers_all_vertices(small_graph, k):
    assign = partition_graph(small_graph, k, "fast")
    pg = build_partitions(small_graph, assign, k)
    cores = np.concatenate([p.node_gid[: p.n_core] for p in pg.parts])
    assert sorted(cores.tolist()) == list(range(small_graph.n_nodes))


def test_ghosts_are_exactly_cut_targets(small_graph):
    assign = partition_graph(small_graph, 4, "eco")
    pg = build_partitions(small_graph, assign, 4)
    for p in pg.parts:
        ghosts = set(p.node_gid[p.n_core: p.n_nodes].tolist())
        expect = set()
        for e in range(small_graph.n_edges):
            s, d = int(small_graph.edge_src[e]), int(small_graph.edge_dst[e])
            if assign[s] == p.pid and assign[d] != p.pid:
                expect.add(d)
            if assign[d] == p.pid and assign[s] != p.pid:
                expect.add(s)
        assert ghosts == expect


def test_ghost_attributes_replicated(small_graph):
    """The one-edge cut-set extension carries label/value/owner (Sec. 4.2)."""
    assign = partition_graph(small_graph, 4, "fastsocial")
    pg = build_partitions(small_graph, assign, 4)
    for p in pg.parts:
        for li in range(p.n_core, p.n_nodes):
            g = int(p.node_gid[li])
            assert p.node_label[li] == small_graph.node_label[g]
            assert p.node_owner[li] == assign[g]


def test_edge_conservation(small_graph):
    """Every symmetrized edge occurs exactly once in its endpoint's core
    adjacency (cut edges once per side via ghosts)."""
    assign = partition_graph(small_graph, 4, "kway_shem")
    pg = build_partitions(small_graph, assign, 4)
    total = sum(int(p.row_ptr[p.n_core]) for p in pg.parts)
    assert total == 2 * small_graph.n_edges


def test_g2l_roundtrip(small_pg):
    pg = small_pg
    for p in pg.parts:
        for li in range(p.n_nodes):
            g = int(p.node_gid[li])
            assert pg.g2l[p.pid, g] == li


def test_connected_components_matches_networkx(small_graph):
    assign = partition_graph(small_graph, 4, "kway_shem")
    pg = build_partitions(small_graph, assign, 4)
    ours = pg.connected_components_per_partition()
    for p in pg.parts:
        core = p.node_gid[: p.n_core].tolist()
        sub = nx_of(small_graph).subgraph(core)
        assert ours[p.pid] == nx.number_connected_components(sub)


def test_ell_matches_csr(small_pg):
    for p in small_pg.parts:
        for v in range(p.n_nodes):
            s, e = int(p.row_ptr[v]), int(p.row_ptr[v + 1])
            csr = sorted(zip(p.edge_dst[s:e].tolist(),
                             p.edge_label[s:e].tolist()))
            ell = sorted((d, l) for d, l in
                         zip(p.ell_dst[v].tolist(), p.ell_label[v].tolist())
                         if d >= 0)
            assert csr == ell


def test_ell_denormalized_dst_attrs(small_pg):
    for p in small_pg.parts:
        mask = p.ell_dst >= 0
        idx = np.clip(p.ell_dst, 0, p.node_gid.shape[0] - 1)
        assert np.array_equal(p.ell_dlab[mask], p.node_label[idx][mask])
        assert np.array_equal(p.ell_dgid[mask], p.node_gid[idx][mask])


def test_cut_edges_counted(small_graph):
    assign = partition_graph(small_graph, 4, "rb_shem")
    pg = build_partitions(small_graph, assign, 4)
    manual = int(np.sum(assign[small_graph.edge_src]
                        != assign[small_graph.edge_dst]))
    assert pg.cut_edges == manual


def test_builder_roundtrip():
    b = GraphBuilder()
    a = b.add_node("A", value=1.5)
    c = b.add_node("B")
    b.add_edge(a, c, "e", directed=True)
    g = b.build()
    assert g.n_nodes == 2 and g.n_edges == 1
    assert g.node_vocab.str_of(int(g.node_label[0])) == "A"
    assert np.isnan(g.node_value[1])
    assert bool(g.edge_directed[0])
