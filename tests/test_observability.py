"""End-to-end observability (obs/): span tracer, unified metrics
registry, exporters, decision records — and the contract that makes
them safe to ship: tracing on/off yields byte-identical answers and
RunStats on every engine, and the disabled path costs ~nothing.
"""
import json
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import (EngineConfig, GraphSession, MAX_SN, MAX_YIELD,
                        MAX_YIELD_SHARED, match_disjunctive,
                        rank_partitions, rank_partitions_shared)
from repro.data.generators import subgen_like_graph, subgen_queries
from repro.obs import (NULL_TRACER, MetricsRegistry, Tracer,
                       ingest_load_stats, ingest_schedule, ingest_session,
                       observability_snapshot, to_chrome_trace,
                       to_prometheus_text, validate_residency,
                       write_chrome_trace)


@pytest.fixture(scope="module")
def setup():
    g = subgen_like_graph(n_nodes=250, n_edges=700, n_embed=10, seed=3)
    dqueries = subgen_queries(g)
    refs = {dq.name: match_disjunctive(g, dq, q_pad=8) for dq in dqueries}
    return g, dqueries, refs


def make_session(g, engine="opat", k=4, **kw):
    return GraphSession(g, k=k, scheme="kway_shem", engine=engine, seed=1,
                        processors=2, config=EngineConfig(cap=32768), **kw)


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_null_tracer_is_noop_singleton():
    assert not NULL_TRACER.enabled
    s1 = NULL_TRACER.span("a", x=1)
    s2 = NULL_TRACER.span("b")
    assert s1 is s2            # one shared span object, zero allocation
    with s1 as sp:
        assert sp.set(tier="cold") is sp   # chainable no-op
    NULL_TRACER.decision("k", a=1)
    NULL_TRACER.add_span("x", 0.0, 1.0)


def test_tracer_span_nesting_and_ids():
    tr = Tracer()
    with tr.span("outer", a=1) as o:
        with tr.span("inner") as i:
            assert i.parent_id == o.span_id
            assert tr.current_span_id == i.span_id
        with tr.span("inner2") as i2:
            assert i2.parent_id == o.span_id
    assert o.parent_id is None
    spans = tr.spans
    assert [s.name for s in spans] == ["inner", "inner2", "outer"]
    assert all(s.t1 is not None and s.t1 >= s.t0 for s in spans)
    totals = tr.span_totals()
    assert totals["inner"]["count"] == 1
    tr.clear()
    assert tr.spans == [] and tr.decisions == []


def test_tracer_records_error_attr():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    assert tr.spans[0].attrs["error"] == "RuntimeError"


def test_add_span_and_decisions():
    tr = Tracer()
    sp = tr.add_span("query", 1.0, 2.5, qid=7)
    assert sp.t1 - sp.t0 == pytest.approx(1.5)
    tr.decision("heuristic.rank", chosen=3, breakdown={3: {"score": 1.0}})
    assert tr.decisions[0]["kind"] == "heuristic.rank"
    assert tr.decisions[0]["chosen"] == 3
    assert "ts" in tr.decisions[0]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_and_labels():
    reg = MetricsRegistry()
    c = reg.counter("repro_x_total", "help")
    c.inc()
    c.inc(2)
    assert c.value == 3
    c.set_total(10)           # ingestion: mirror an absolute source counter
    assert c.value == 10
    g = reg.gauge("repro_g", "help")
    g.set(4.5)
    h = reg.histogram("repro_lat_seconds", "help", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(99.0)           # overflow bucket
    assert h.count == 3 and h.overflow == 1
    assert h.cumulative() == [(0.1, 1), (1.0, 2)]
    # same name+labels returns the same instrument; new labels a new one
    assert reg.counter("repro_x_total", "help") is c
    c2 = reg.counter("repro_x_total", "help", tier="cold")
    assert c2 is not c
    snap = reg.snapshot()
    assert snap["repro_x_total"] == 10
    assert snap['repro_x_total{tier=cold}'] == 0
    assert snap["repro_lat_seconds"]["count"] == 3


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.counter("repro_a_total", "a counter", tier="warm").inc(2)
    h = reg.histogram("repro_d_seconds", "durations", buckets=(0.1, 1.0))
    h.observe(0.5)
    text = to_prometheus_text(reg)
    assert "# TYPE repro_a_total counter" in text
    assert 'repro_a_total{tier="warm"} 2' in text
    # cumulative le buckets: 0 below 0.1, 1 at le=1.0 and at +Inf
    assert 'repro_d_seconds_bucket{le="0.1"} 0' in text
    assert 'repro_d_seconds_bucket{le="1"} 1' in text
    assert 'repro_d_seconds_bucket{le="+Inf"} 1' in text
    assert "repro_d_seconds_count 1" in text


def test_validate_residency():
    # prefetch hits are a subset of warm: cold + (warm-ph) + ph == n
    out = validate_residency(2, 3, 1, 5)
    assert out == {"cold": 2, "demand_warm": 2, "prefetch_hits": 1,
                   "n_loads": 5}
    with pytest.raises(ValueError):
        validate_residency(2, 3, 1, 6)     # classes don't tile the loads
    with pytest.raises(ValueError):
        validate_residency(2, 1, 2, 3)     # ph > warm
    with pytest.raises(ValueError):
        validate_residency(None, 3, 1, 4)  # absent counter


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_chrome_trace_export(tmp_path):
    tr = Tracer()
    with tr.span("query", query="Q1"):
        with tr.span("store.load", pid=2) as sp:
            sp.set(tier="cold")
        with tr.span("kernel.eval", pid=2):
            pass
    tr.decision("heuristic.rank", chosen=2, ranked=[2],
                breakdown={2: {"sni": 4, "score": 4.0}})
    doc = to_chrome_trace(tr)
    evs = doc["traceEvents"]
    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    assert set(xs) == {"query", "store.load", "kernel.eval"}
    # lanes: one tid per subsystem, named via M metadata events
    lanes = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"queries", "store loads", "kernel eval"} <= lanes
    assert xs["store.load"]["tid"] != xs["query"]["tid"]
    # parenting survives the export (trace_report rebuilds the tree)
    assert xs["store.load"]["args"]["parent_id"] == \
        xs["query"]["args"]["span_id"]
    assert xs["store.load"]["args"]["tier"] == "cold"
    inst = [e for e in evs if e["ph"] == "i"]
    assert inst[0]["name"] == "heuristic.rank"
    assert inst[0]["args"]["breakdown"]["2"]["score"] == 4.0
    p = tmp_path / "t.json"
    write_chrome_trace(tr, str(p))
    assert json.loads(p.read_text())["traceEvents"]


def test_observability_snapshot_shape():
    tr = Tracer()
    with tr.span("query", query="Q"):
        pass
    tr.decision("frontend.admit", outcome="admit")
    reg = MetricsRegistry()
    reg.counter("repro_c_total", "h").inc()
    block = observability_snapshot(tr, reg)
    assert block["enabled"] is True
    assert block["metrics"]["repro_c_total"] == 1
    assert block["spans"]["query"]["count"] == 1
    assert block["decisions"]["frontend.admit"] == 1
    off = observability_snapshot(NULL_TRACER, reg)
    assert off["enabled"] is False and "spans" not in off


# ---------------------------------------------------------------------------
# decision records
# ---------------------------------------------------------------------------

def test_rank_partitions_decision_breakdown():
    rng = np.random.default_rng(0)
    tr = Tracer()
    ranked = rank_partitions(MAX_SN, [0, 1, 2], {0: 5, 1: 9, 2: 1}, rng,
                             tracer=tr)
    rec = tr.decisions[0]
    assert rec["kind"] == "heuristic.rank"
    assert rec["chosen"] == ranked[0] == 1
    # chosen is the argmax of the recorded scores (what --check verifies)
    scores = {p: b["score"] for p, b in rec["breakdown"].items()}
    assert max(scores, key=scores.get) == 1
    rng2 = np.random.default_rng(0)
    tr2 = Tracer()
    rank_partitions(MAX_YIELD, [0, 1], {0: 10, 1: 10}, rng2,
                    completion_rates={0: 0.1, 1: 0.9}, tracer=tr2)
    b = tr2.decisions[0]["breakdown"]
    assert b[1]["completion_rate"] == pytest.approx(0.9)
    assert b[1]["score"] > b[0]["score"]


def test_rank_partitions_shared_decision_terms():
    rng = np.random.default_rng(0)
    tr = Tracer()
    waiting = {0: [(10, 0.5, 3.0, 0.0)], 1: [(2, 0.5, 0.0, 8.0)]}
    rank_partitions_shared(MAX_YIELD_SHARED, waiting, rng,
                           fairness_gamma=0.5, tracer=tr)
    b = tr.decisions[0]["breakdown"]
    # every term of the score is recorded separately
    assert b[0]["base"] == pytest.approx(5.0)       # 10 x 0.5
    assert b[0]["fairness"] == pytest.approx(15.0)  # 0.5 x 10 x 3
    assert b[1]["urgency"] == pytest.approx(16.0)   # 2 x 8
    for pid in (0, 1):
        assert b[pid]["score"] == pytest.approx(
            b[pid]["base"] + b[pid]["fairness"] + b[pid]["urgency"])


# ---------------------------------------------------------------------------
# parity: tracing on/off is invisible to results
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,k", [("opat", 4), ("traditional", 4),
                                      ("mapreduce", 1)])
def test_traced_untraced_parity(setup, engine, k):
    g, dqueries, refs = setup
    plain = make_session(g, engine=engine, k=k)
    traced = make_session(g, engine=engine, k=k, tracer=Tracer())
    for dq in dqueries:
        r0 = plain.submit(dq, max_answers=5)
        r1 = traced.submit(dq, max_answers=5)
        assert np.array_equal(r0.answers, r1.answers), (engine, dq.name)
        for s0, s1 in zip(r0.stats, r1.stats):
            assert s0.loads == s1.loads
            assert s0.n_answers == s1.n_answers
            assert s0.iterations == s1.iterations
    assert traced.tracer.spans, "traced session recorded nothing"


def test_traced_untraced_parity_shared_scheduler(setup):
    g, dqueries, refs = setup
    plain = make_session(g)
    traced = make_session(g, tracer=Tracer())
    rep0 = plain.submit_many(dqueries, heuristic=MAX_YIELD_SHARED)
    rep1 = traced.submit_many(dqueries, heuristic=MAX_YIELD_SHARED)
    assert rep0.loads == rep1.loads
    assert rep0.batch_sizes == rep1.batch_sizes
    for q0, q1 in zip(rep0.results, rep1.results):
        assert q0.name == q1.name
        assert np.array_equal(q0.answers, q1.answers)
    names = {s.name for s in traced.tracer.spans}
    assert "scheduler.round" in names and "kernel.eval" in names
    # one externally-timed root span per retired query
    assert sum(1 for s in traced.tracer.spans if s.name == "query") == \
        len(rep1.results)
    kinds = {d["kind"] for d in traced.tracer.decisions}
    assert "heuristic.rank_shared" in kinds


def test_disabled_tracer_overhead_under_5pct(setup):
    """The null-path cost of every span a traced scheduler batch would
    emit must stay under 5% of the batch's wall time."""
    g, dqueries, refs = setup
    traced = make_session(g, tracer=Tracer())
    traced.submit_many(dqueries)                       # warm compile
    t0 = time.perf_counter()
    traced.submit_many(dqueries)
    wall = time.perf_counter() - t0
    n_events = len(traced.tracer.spans) + len(traced.tracer.decisions)
    reps = 20000
    t0 = time.perf_counter()
    for _ in range(reps):
        with NULL_TRACER.span("scheduler.round", pid=1, round=2) as sp:
            sp.set(tier="warm")
    per_span = (time.perf_counter() - t0) / reps
    assert n_events * per_span < 0.05 * wall, \
        (n_events, per_span, wall)


# ---------------------------------------------------------------------------
# ingestion + trace_report CLI
# ---------------------------------------------------------------------------

def test_ingest_session_and_schedule(setup):
    g, dqueries, refs = setup
    sess = make_session(g)
    rep = sess.submit_many(dqueries)
    reg = MetricsRegistry()
    ingest_session(reg, sess)
    ingest_schedule(reg, rep.loads, rep.batch_sizes)
    snap = reg.snapshot()
    ls = sess.load_stats
    assert snap["repro_store_cold_loads_total"] == ls.cold_loads
    assert snap["repro_store_warm_loads_total"] == ls.warm_loads
    assert snap["repro_scheduler_loads_total"] == len(rep.loads)
    assert snap["repro_session_queries_served_total"] >= len(dqueries)
    reg2 = MetricsRegistry()
    ingest_load_stats(reg2, ls)
    assert reg2.snapshot()["repro_store_cold_loads_total"] == ls.cold_loads


def test_trace_report_check_cli(setup, tmp_path):
    g, dqueries, refs = setup
    sess = make_session(g, tracer=Tracer())
    for dq in dqueries:
        sess.submit(dq, max_answers=5)
    path = tmp_path / "trace.json"
    write_chrome_trace(sess.tracer, str(path))
    import pathlib
    root = pathlib.Path(__file__).resolve().parent.parent
    out = subprocess.run(
        [sys.executable, "tools/trace_report.py", str(path), "--check"],
        cwd=root, capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert "trace OK" in out.stdout
    # the full report renders the latency decomposition
    out2 = subprocess.run(
        [sys.executable, "tools/trace_report.py", str(path)],
        cwd=root, capture_output=True, text=True)
    assert out2.returncode == 0, out2.stderr
    assert "store.load" in out2.stdout
    # a broken trace (span escaping its parent) fails the gate
    doc = json.loads(path.read_text())
    for e in doc["traceEvents"]:
        if e.get("ph") == "X" and e["args"].get("parent_id") is not None:
            e["ts"] += 10_000_000.0
            break
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(doc))
    out3 = subprocess.run(
        [sys.executable, "tools/trace_report.py", str(bad), "--check"],
        cwd=root, capture_output=True, text=True)
    assert out3.returncode != 0
    assert "escapes parent" in out3.stderr


def test_frontend_admission_decisions(setup):
    from repro.serving import Request, parse_slo_spec
    g, dqueries, refs = setup
    sess = make_session(g, tracer=Tracer())
    classes = parse_slo_spec("interactive=0.5,batch=5")
    fe = sess.frontend(slo_classes=classes, shed_policy="predictive")
    reqs = [Request(dq, slo_class="interactive") for dq in dqueries]
    fe.serve(reqs)
    recs = [d for d in sess.tracer.decisions
            if d["kind"] == "frontend.admit"]
    assert len(recs) == len(reqs)
    for r in recs:
        assert r["outcome"] in ("admit", "degrade", "defer", "shed")
        assert "predicted_latency_s" in r and "deadline_s" in r
        assert "backlog_s" in r
