"""MapReduceMP with one partition per device — needs >1 device, so this
test runs a SUBPROCESS with xla_force_host_platform_device_count=4
(conftest must NOT set it globally; smoke tests see the real device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax
    from repro.core import (EngineConfig, MAX_SN, MAX_YIELD, MIN_SN,
                            build_catalog, build_partitions, generate_plan,
                            match_query, partition_graph)
    from repro.core.mapreduce_mp import MapReduceMPEngine
    from repro.data.generators import subgen_like_graph, subgen_queries

    g = subgen_like_graph(n_nodes=250, n_edges=700, n_embed=10, seed=3)
    assign = partition_graph(g, 4, "kway_shem")
    pg = build_partitions(g, assign, 4)
    cat = build_catalog(g)
    from repro.compat import make_part_mesh
    mesh = make_part_mesh(4)

    # (2, MAX_YIELD) gates expansion through the on-device completion-rate
    # ranking (all_gathered completed/spawned counters, paper Sec. 9.2)
    for m_limit, heur in [(4, MAX_SN), (2, MAX_SN), (2, MIN_SN),
                          (2, MAX_YIELD)]:
        eng = MapReduceMPEngine(pg, mesh, EngineConfig(cap=16384),
                                m_limit=m_limit, heuristic=heur)
        for dq in subgen_queries(g):
            q = dq.disjuncts[0]
            plan = generate_plan(q, g, cat)
            res = eng.run(plan)
            ref = match_query(g, q, q_pad=8)
            got = np.unique(res.answers, axis=0)
            assert got.shape == ref.shape and np.array_equal(got, ref), (
                q.name, m_limit, heur, got.shape, ref.shape)
            assert res.n_iterations >= plan.max_path_len()
            assert res.completed_from.shape == (4,)
            assert int(res.completed_from.sum()) >= ref.shape[0]

    # answer budget across 4 devices: the global-psum stop condition must
    # return exactly min(K, total) rows from the full answer set
    eng = MapReduceMPEngine(pg, mesh, EngineConfig(cap=16384))
    for dq in subgen_queries(g):
        q = dq.disjuncts[0]
        plan = generate_plan(q, g, cat)
        ref = match_query(g, q, q_pad=8)
        refset = {tuple(r) for r in ref}
        for K in (1, 5):
            res = eng.run(plan, max_answers=K)
            assert res.answers.shape[0] == min(K, ref.shape[0]), (q.name, K)
            assert all(tuple(r) in refset for r in res.answers), (q.name, K)
    print("MAPREDUCE_MULTIDEV_OK")
""")


@pytest.mark.slow
def test_mapreduce_4_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MAPREDUCE_MULTIDEV_OK" in proc.stdout
