import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single CPU device.  Multi-device behaviour
# is tested via subprocesses (test_mapreduce_multidev.py).

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest

from repro.core import (EngineConfig, OPATEngine, build_catalog, build_partitions, generate_plan,
                        partition_graph)
from repro.data.generators import subgen_like_graph


@pytest.fixture(scope="session")
def small_graph():
    return subgen_like_graph(n_nodes=200, n_edges=600, n_embed=8, seed=2)


@pytest.fixture(scope="session")
def small_pg(small_graph):
    assign = partition_graph(small_graph, 4, "kway_shem")
    return build_partitions(small_graph, assign, 4)


def run_opat(graph, pg, query, heuristic="max-sn", cap=16384, seed=0,
             use_pallas=False):
    catalog = build_catalog(graph)
    plan = generate_plan(query, graph, catalog)
    eng = OPATEngine(pg, EngineConfig(cap=cap, use_pallas=use_pallas))
    return eng.run(plan, heuristic, seed=seed)
