"""Streaming graph updates: interleaving equivalence, generation-pinned
serving under concurrent compaction, and generation observability.

ISSUE 8 acceptance covered here:
  * >= 100 seeded random interleavings of insert/delete/compact recover
    to exactly the state an independent python mirror predicts, survive
    a reopen after ``compact_all``, and round-trip through a from-scratch
    save of the same final edge set (gid-identical canonical forms);
  * answers on the final generation are identical across OPAT,
    TraditionalMP, the scheduler batch (k=3) and MapReduceMP (k=1,
    single device) to a from-scratch save of the same final graph,
    oracle-verified;
  * queries pinned to generation G keep returning G-consistent answers
    while a compaction publishes G+1 mid-run; fresh opens see G+1; the
    superseded generation's files are GC'd only once no pin remains;
  * ``QueryResult``/``RunStats`` carry ``generation`` and
    ``workload_profile()`` reports per-partition delta counts.
"""
import math
import os
import shutil

import numpy as np
import pytest

from repro.core import (EngineConfig, GraphSession, build_partitions,
                        match_disjunctive, partition_graph)
from repro.core.graph import Graph, LabelVocab
from repro.data.generators import subgen_like_graph, subgen_queries
from repro.storage import DiskCatalog, save_partitioned_graph
from repro.storage.deltas import DELETED_LABEL, open_mutable

N_INTERLEAVINGS = 100
OPS_PER_SEED = 8


# ---------------------------------------------------------------------------
# an independent python mirror of the mutation semantics
# ---------------------------------------------------------------------------

class Mirror:
    """Plain-python model of the delta semantics, sharing NO code with
    storage/deltas.py: nodes are (label, value) slots (tombstoned in
    place), edges a list of (u, v, label, directed).  ``edge_del``
    removes every (u, v, label) copy; ``vertex_del`` tombstones the slot
    and drops every incident edge."""

    def __init__(self, g):
        node_label = np.asarray(g.node_label)
        node_value = np.asarray(g.node_value)
        self.nodes = [(g.node_vocab.str_of(int(node_label[i])),
                       float(node_value[i]))
                      for i in range(int(g.n_nodes))]
        self.edges = [(int(u), int(v), g.edge_vocab.str_of(int(lab)),
                       bool(d))
                      for u, v, lab, d in zip(
                          np.asarray(g.edge_src), np.asarray(g.edge_dst),
                          np.asarray(g.edge_label),
                          np.asarray(g.edge_directed))]
        self.value_dtype = node_value.dtype

    def alive(self):
        return [i for i, (lab, _) in enumerate(self.nodes)
                if lab != DELETED_LABEL]

    def apply(self, op):
        if op["op"] == "edge_add":
            self.edges.append((op["u"], op["v"], op["label"],
                               bool(op.get("directed", False))))
        elif op["op"] == "edge_del":
            self.edges = [e for e in self.edges
                          if not (e[0] == op["u"] and e[1] == op["v"]
                                  and e[2] == op["label"])]
        elif op["op"] == "vertex_add":
            # the storage path casts the record's float64 value to the
            # graph's node_value dtype at apply time — mirror that
            self.nodes.append((op["label"],
                               float(np.asarray(op["value"],
                                                self.value_dtype))))
        elif op["op"] == "vertex_del":
            gid = op["u"]
            self.nodes[gid] = (DELETED_LABEL, math.nan)
            self.edges = [e for e in self.edges
                          if e[0] != gid and e[1] != gid]
        else:
            raise AssertionError(op)

    def canon(self):
        nodes = tuple((i, lab, None if math.isnan(val) else val)
                      for i, (lab, val) in enumerate(self.nodes))
        return nodes, tuple(sorted(self.edges))

    def to_graph(self):
        """A from-scratch ``Graph`` of the final state (gid-identical,
        including tombstones)."""
        nv, ev = LabelVocab(), LabelVocab()
        node_label = np.asarray([nv.intern(lab) for lab, _ in self.nodes],
                                np.int32)
        node_value = np.asarray([val for _, val in self.nodes],
                                self.value_dtype)
        g = Graph(n_nodes=len(self.nodes),
                  node_label=node_label, node_value=node_value,
                  edge_src=np.asarray([e[0] for e in self.edges], np.int32),
                  edge_dst=np.asarray([e[1] for e in self.edges], np.int32),
                  edge_label=np.asarray([ev.intern(e[2])
                                         for e in self.edges], np.int32),
                  edge_directed=np.asarray([e[3] for e in self.edges], bool),
                  node_vocab=nv, edge_vocab=ev)
        g.validate()
        return g


def graph_canon(g):
    node_label = np.asarray(g.node_label)
    node_value = np.asarray(g.node_value)
    nodes = []
    for i in range(int(g.n_nodes)):
        val = float(node_value[i])
        nodes.append((i, g.node_vocab.str_of(int(node_label[i])),
                      None if math.isnan(val) else val))
    edges = sorted(
        (int(u), int(v), g.edge_vocab.str_of(int(lab)), bool(d))
        for u, v, lab, d in zip(np.asarray(g.edge_src),
                                np.asarray(g.edge_dst),
                                np.asarray(g.edge_label),
                                np.asarray(g.edge_directed)))
    return tuple(nodes), tuple(edges)


def random_ops(rng, mirror, k, n_ops):
    """One interleaving: ops valid against the mirror's running state
    (mutation entry points reject dead endpoints, so the generator only
    proposes what a real writer could)."""
    ops = []
    for _ in range(n_ops):
        roll = rng.random()
        alive = mirror.alive()
        if roll < 0.40 and len(alive) >= 2:
            u, v = rng.choice(alive, size=2, replace=False)
            op = {"op": "edge_add", "u": int(u), "v": int(v),
                  "label": str(rng.choice(["E_m0", "E_m1"])),
                  "directed": bool(rng.random() < 0.3)}
        elif roll < 0.65 and mirror.edges:
            u, v, lab, _ = mirror.edges[int(rng.integers(len(mirror.edges)))]
            op = {"op": "edge_del", "u": u, "v": v, "label": lab}
        elif roll < 0.85:
            op = {"op": "vertex_add", "label": str(rng.choice(["L_m0",
                                                               "L_m1"])),
                  "value": float(rng.integers(0, 8)) / 8.0,
                  "pid": int(rng.integers(k))}
        elif alive:
            op = {"op": "vertex_del", "u": int(rng.choice(alive))}
        else:
            continue
        mirror.apply(op)
        ops.append(op)
    return ops


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    g = subgen_like_graph(n_nodes=80, n_edges=220, n_embed=6, seed=7)
    assign = partition_graph(g, 3, "kway_shem")
    pg = build_partitions(g, assign, 3, scheme="kway_shem")
    base = str(tmp_path_factory.mktemp("mut-base"))
    save_partitioned_graph(pg, base)
    dqueries = subgen_queries(g)[:2]
    return g, base, dqueries


# ---------------------------------------------------------------------------
# (1) >= 100 seeded interleavings vs the mirror
# ---------------------------------------------------------------------------

def test_interleaving_rebuild_equivalence_100_seeds(setup, tmp_path):
    g, base, _ = setup
    for seed in range(N_INTERLEAVINGS):
        rng = np.random.default_rng(1000 + seed)
        work = str(tmp_path / f"il-{seed:03d}")
        shutil.copytree(base, work)
        mdir = open_mutable(work)
        mirror = Mirror(g)
        applied = 0
        for op in random_ops(rng, mirror, mdir.k, OPS_PER_SEED):
            mdir.apply_op(op)
            applied += 1
            # interleave compactions INTO the op stream
            if rng.random() < 0.15:
                mdir.compact(int(rng.integers(mdir.k)))
        view = mdir.snapshot()
        try:
            assert graph_canon(view.graph) == mirror.canon(), seed
        finally:
            view.release()
        # fold everything; a fresh open must land on the same state
        if rng.random() < 0.5:
            mdir.compact_all()
        else:
            mdir.compact(0)
        re_mdir = open_mutable(work)
        view = re_mdir.snapshot()
        try:
            assert graph_canon(view.graph) == mirror.canon(), seed
            assignment = np.asarray(view.assignment, np.int64)
        finally:
            view.release()
        # from-scratch save of the same final edge set round-trips to the
        # identical canonical graph (gids, tombstones and all)
        fresh = mirror.to_graph()
        assert graph_canon(fresh) == mirror.canon(), seed
        fresh_dir = str(tmp_path / f"il-{seed:03d}-fresh")
        save_partitioned_graph(
            build_partitions(fresh, assignment, 3, scheme="kway_shem"),
            fresh_dir)
        assert graph_canon(DiskCatalog(fresh_dir).load_graph()) == \
            mirror.canon(), seed
        shutil.rmtree(work)
        shutil.rmtree(fresh_dir)


# ---------------------------------------------------------------------------
# (2) final-generation engine equivalence
# ---------------------------------------------------------------------------

def _apply_ops(mdir, ops):
    """Replay one shared op stream onto a directory (vertex placement
    clamped to its k — placement changes the layout, never the graph)."""
    for i, op in enumerate(ops):
        if op["op"] == "vertex_add":
            op = {**op, "pid": op["pid"] % mdir.k}
        mdir.apply_op(op)
        if i == len(ops) // 2:
            mdir.compact(0)
    mdir.compact_all()


def test_final_generation_all_engines_match_fresh_save(setup, tmp_path):
    """OPAT + TraditionalMP + the scheduler batch (k=3) and MapReduceMP
    (k=1 — one partition per local device) all serve the mutated
    directory's final generation with answers identical to a from-scratch
    save of the same final graph, oracle-verified."""
    g, base, dqueries = setup
    cfg = EngineConfig(cap=32768)

    mirror = Mirror(g)
    ops = random_ops(np.random.default_rng(42), mirror, 3, 10)
    work = str(tmp_path / "eng3")
    shutil.copytree(base, work)
    mdir = open_mutable(work)
    _apply_ops(mdir, ops)
    fresh = mirror.to_graph()
    view = mdir.snapshot()
    assignment = np.asarray(view.assignment, np.int64)
    view.release()
    fresh_dir = str(tmp_path / "eng3-fresh")
    save_partitioned_graph(
        build_partitions(fresh, assignment, 3, scheme="kway_shem"),
        fresh_dir)

    refs = {}
    fresh_sess = GraphSession.open(fresh_dir, engine="opat", seed=1,
                                   config=cfg)
    for dq in dqueries:
        res = fresh_sess.submit(dq)
        ref = match_disjunctive(fresh_sess.graph, dq,
                                q_pad=res.answers.shape[1])
        assert np.array_equal(res.answers, ref), dq.name
        refs[dq.name] = ref

    for engine in ("opat", "traditional"):
        sess = GraphSession.open(work, engine=engine, seed=1,
                                 processors=2, config=cfg)
        for dq in dqueries:
            res = sess.submit(dq)
            assert np.array_equal(res.answers, refs[dq.name]), \
                (engine, dq.name)
        if engine == "opat":
            report = sess.submit_many(dqueries)
            for r in report.results:
                assert np.array_equal(r.answers, refs[r.name]), r.name

    # MapReduceMP: its own k=1 directory, same op stream
    work1 = str(tmp_path / "eng1")
    GraphSession(g, k=1, scheme="kway_shem", engine="opat",
                 seed=1).save(work1)
    mdir1 = open_mutable(work1)
    _apply_ops(mdir1, ops)                       # same logical final state
    mr = GraphSession.open(work1, engine="mapreduce", seed=1, config=cfg)
    for dq in dqueries:
        res = mr.submit(dq)
        assert np.array_equal(res.answers, refs[dq.name]), dq.name


# ---------------------------------------------------------------------------
# (3) generation pinning under a mid-run compaction
# ---------------------------------------------------------------------------

def test_pinned_queries_survive_mid_run_compaction(setup, tmp_path):
    g, base, dqueries = setup
    work = str(tmp_path / "pin")
    shutil.copytree(base, work)
    sess = GraphSession.open(work, engine="opat", seed=1,
                             config=EngineConfig(cap=32768))
    gen0 = sess.generation
    sched = sess.scheduler()
    for dq in dqueries:
        sched.admit(dq)
    pinned_graph = sched.view.graph
    pinned_files = sched.view.files()
    refs_pinned = {}
    partial = sched.run(max_rounds=1)            # serving has STARTED

    # mutation designed to change answers: delete a vertex bound by the
    # first query's answers, so generation G+1 provably answers
    # differently than the pinned generation G
    ref0 = match_disjunctive(pinned_graph, dqueries[0], q_pad=8)
    assert ref0.size, "fixture query must have answers"
    victim = int(ref0[ref0 >= 0].flat[0])
    sess.del_vertex(victim)
    new_gen = sess.compact_all()
    assert new_gen > gen0 and sess.generation == new_gen
    assert not np.array_equal(
        match_disjunctive(sess.graph, dqueries[0], q_pad=8), ref0)

    # the pinned generation's files survive the compaction's GC
    for fname in pinned_files:
        assert os.path.exists(os.path.join(work, fname)), fname

    # a query admitted AFTER the publish still joins generation G —
    # one scheduler, one generation
    sched.admit(dqueries[0])
    report = sched.run()                          # drain
    results = partial.results + report.results
    assert len(results) == len(dqueries) + 1
    for res in results:
        assert res.generation == gen0, res.name
        for rep in res.reports:
            assert rep.stats.generation == gen0
        ref = match_disjunctive(
            pinned_graph, next(q for q in dqueries if q.name == res.name),
            q_pad=res.answers.shape[1])
        assert np.array_equal(res.answers, ref), res.name

    # fresh opens (and fresh submits on the live session) see G+1
    re_sess = GraphSession.open(work, engine="opat", seed=1,
                                config=EngineConfig(cap=32768))
    assert re_sess.generation == new_gen
    res = sess.submit(dqueries[0])
    assert res.generation == new_gen
    assert np.array_equal(
        res.answers,
        match_disjunctive(sess.graph, dqueries[0],
                          q_pad=res.answers.shape[1]))

    # GC fires only once no pin remains
    live = sess._mdir.catalog
    live_files = ({p["shard"] for p in live.manifest["partitions"]}
                  | {live.graph_file})
    superseded = pinned_files - live_files
    assert superseded, "compaction must have superseded some files"
    sess._mdir.gc()                               # sched still pinned
    for fname in superseded:
        assert os.path.exists(os.path.join(work, fname)), fname
    sched.close()
    sess._mdir.gc()
    for fname in superseded:
        assert not os.path.exists(os.path.join(work, fname)), fname
    # and the closed scheduler refuses further use
    with pytest.raises(RuntimeError, match="close"):
        sched.admit(dqueries[0])


# ---------------------------------------------------------------------------
# (4) observability + guardrails
# ---------------------------------------------------------------------------

def test_generation_surfacing_and_delta_counts(setup, tmp_path):
    g, base, dqueries = setup
    work = str(tmp_path / "obs")
    shutil.copytree(base, work)
    sess = GraphSession.open(work, engine="opat", seed=1,
                             config=EngineConfig(cap=32768))
    assert sess.mutable and sess.generation == 0
    res = sess.submit(dqueries[0])
    assert res.generation == 0
    assert all(rep.stats.generation == 0 for rep in res.reports)

    alive = [i for i in range(g.n_nodes)][:4]
    sess.add_edge(alive[0], alive[1], "E_obs")
    sess.add_edge(alive[2], alive[3], "E_obs")
    prof = sess.workload_profile()
    pending = [p["delta_count"] for p in prof["partitions"]]
    assert sum(pending) == prof["pending_deltas"] > 0
    assert prof["generation"] == 0 and prof["compactions"] == 0

    hot = sess.compact_hot(min_pending=1)
    assert hot                                    # something was pending
    prof = sess.workload_profile()
    assert prof["pending_deltas"] == 0
    assert prof["compactions"] == len(hot)
    assert prof["generation"] == sess.generation == len(hot)
    res = sess.submit(dqueries[0])
    assert res.generation == sess.generation
    assert np.array_equal(
        res.answers,
        match_disjunctive(sess.graph, dqueries[0],
                          q_pad=res.answers.shape[1]))


def test_in_ram_sessions_have_no_generations(setup):
    g, _, dqueries = setup
    sess = GraphSession(g, k=3, scheme="kway_shem", engine="opat", seed=1,
                        config=EngineConfig(cap=32768))
    assert not sess.mutable and sess.generation is None
    res = sess.submit(dqueries[0])
    assert res.generation is None
    assert all(rep.stats.generation is None for rep in res.reports)
    prof = sess.workload_profile()
    assert "generation" not in prof and "pending_deltas" not in prof
    assert "delta_count" not in prof["partitions"][0]
    with pytest.raises(RuntimeError, match="disk-backed"):
        sess.add_edge(0, 1, "E_x")
    with pytest.raises(RuntimeError, match="disk-backed"):
        sess.compact_all()


def test_mutation_guardrails(setup, tmp_path):
    g, base, _ = setup
    work = str(tmp_path / "guard")
    shutil.copytree(base, work)
    mdir = open_mutable(work)
    mdir.del_vertex(3)
    with pytest.raises(ValueError, match="deleted"):
        mdir.add_edge(3, 5, "E_x")
    with pytest.raises(ValueError, match="out of range"):
        mdir.add_edge(0, 10_000, "E_x")
    with pytest.raises(ValueError, match="unknown delta op"):
        mdir.apply_op({"op": "nope"})
