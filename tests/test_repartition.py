"""Workload-aware repartitioning (core/repartition.py) + the session loop.

Covers the ISSUE-3 satellite/acceptance list:
  * reweighting semantics (answers' boundary edges pulled up, floors kept),
  * determinism of the profile -> assignment pipeline under a fixed seed,
  * on a skewed synthetic workload the "waw" layout strictly reduces mean
    loads-per-query and answer spans at an edge cut no worse than the
    baseline, with identical oracle-verified answers,
  * session parity (same answers before/after repartition()) for all three
    engines, and store/stacked-bundle invalidation across the rebind.
"""
import json

import numpy as np
import pytest

from repro.core import (EngineConfig, GraphSession, RepartitionConfig, WAW_SCHEME,
                        answer_span_matrix, load_profile, match_disjunctive, partition_graph,
                        partition_quality, repartition_assignment, reweight_edges)
from repro.data.generators import (subgen_like_graph, subgen_queries,
                                   waw_skewed_graph, waw_skewed_queries)


@pytest.fixture(scope="module")
def skew():
    g = waw_skewed_graph(seed=0)
    return g, waw_skewed_queries(hot_repeats=4)


@pytest.fixture(scope="module")
def skew_profile(skew):
    g, mix = skew
    sess = GraphSession(g, k=2, scheme="kway_shem", engine="opat", seed=0)
    for dq in mix:
        sess.submit(dq)
    return sess.pg.assignment.copy(), sess.workload_profile()


# ---------------------------------------------------------------------------
# Reweighting semantics
# ---------------------------------------------------------------------------

def test_reweight_pulls_up_spanning_boundary_edges(skew, skew_profile):
    g, _ = skew
    assign, prof = skew_profile
    w = reweight_edges(g, assign, prof)
    assert w.shape == (g.n_edges,) and w.min() >= 1
    cross = assign[g.edge_src] != assign[g.edge_dst]
    vsc = np.asarray(prof["answer_spans"]["vertex_span_counts"])
    hot = cross & (vsc[g.edge_src] > 0) & (vsc[g.edge_dst] > 0)
    if prof["answer_spans"]["mean_span"] > 1.0:
        assert hot.any(), "skewed workload must produce spanning answers"
        # the answers' own boundary edges carry the boost...
        assert w[hot].max() > 1
        # ...while boundary edges no spanning answer touched stay at the
        # floor (e.g. the background bridge edges)
        untouched = cross & ~hot
        assert untouched.any() and w[untouched].max() == 1
    # interior edges never exceed the cohesion bonus
    cfg = RepartitionConfig()
    assert w[~cross].max() <= 1 + cfg.cohesion_gain


def test_reweight_skips_split_pressure_without_counters(skew, skew_profile):
    g, _ = skew
    assign, prof = skew_profile
    blind = dict(prof, partition_counters_observed=False)
    w = reweight_edges(g, assign, blind)
    cross = assign[g.edge_src] != assign[g.edge_dst]
    # no cohesion bonus on interiors, but the co-traversal term (observed
    # host-side for every engine, MapReduceMP included) still applies
    assert w[~cross].max() == 1
    assert w[cross].max() > 1


def test_reweight_and_weighted_partitioner_validation(skew, skew_profile):
    g, _ = skew
    assign, prof = skew_profile
    with pytest.raises(ValueError):
        reweight_edges(g, assign, dict(prof, k=1))  # assignment pids >= k
    bad = dict(prof)
    bad["answer_spans"] = dict(prof["answer_spans"],
                               vertex_span_counts=[1, 2, 3])
    with pytest.raises(ValueError):
        reweight_edges(g, assign, bad)
    with pytest.raises(ValueError):
        partition_graph(g, 2, "kway_shem",
                        edge_weights=np.ones(3, dtype=np.int64))
    with pytest.raises(ValueError):
        partition_graph(g, 2, "kway_shem",
                        edge_weights=np.zeros(g.n_edges, dtype=np.int64))
    with pytest.raises(ValueError):
        load_profile({"not": "a profile"})
    with pytest.raises(ValueError):
        RepartitionConfig(boundary_gain=0)
    # a profile stripped of its embedded assignment needs an explicit one
    stripped = {kk: v for kk, v in prof.items() if kk != "assignment"}
    with pytest.raises(ValueError):
        repartition_assignment(g, stripped)
    a = repartition_assignment(g, stripped, assignment=assign)
    assert a.shape == (g.n_nodes,)


def test_repartition_assignment_is_deterministic(skew, skew_profile, tmp_path):
    g, _ = skew
    _, prof = skew_profile
    a1 = repartition_assignment(g, prof)
    a2 = repartition_assignment(g, prof)
    assert np.array_equal(a1, a2)
    # and identical through the JSON save/load path (the CI artifact)
    path = tmp_path / "profile.json"
    path.write_text(json.dumps(prof))
    a3 = repartition_assignment(g, str(path))
    assert np.array_equal(a1, a3)
    # explicit seed overrides the scheme seed deterministically
    assert np.array_equal(repartition_assignment(g, prof, seed=5),
                          repartition_assignment(g, prof, seed=5))


# ---------------------------------------------------------------------------
# The acceptance claim: waw beats the baseline on the skewed workload
# ---------------------------------------------------------------------------

def test_waw_improves_skewed_workload(skew):
    """Strictly fewer partitions loaded per query and strictly lower mean
    answer span, at an edge cut no worse than baseline, with identical
    oracle-verified answer sets."""
    g, mix = skew
    sess = GraphSession(g, k=2, scheme="kway_shem", engine="opat", seed=0)

    def serve_all():
        loads, span_sum, span_rows, answers = 0, 0, 0, {}
        for dq in mix:
            res = sess.submit(dq)
            loads += res.n_loads
            _, span = answer_span_matrix(sess.pg.owner, res.answers, sess.k)
            span_sum += int(span.sum())
            span_rows += int(span.shape[0])
            answers[dq.name] = res.answers
        cut = partition_quality(g, sess.pg.assignment, sess.k)["cut"]
        return loads / len(mix), span_sum / span_rows, cut, answers

    base_loads, base_span, base_cut, base_answers = serve_all()
    assert base_span > 1.0      # the workload really is split at baseline
    info = sess.repartition()   # close the loop on the session's own profile
    assert sess.scheme == WAW_SCHEME.name == "waw"
    assert sess.repartitions == 1 and info["round"] == 1
    waw_loads, waw_span, waw_cut, waw_answers = serve_all()

    assert waw_loads < base_loads
    assert waw_span < base_span
    assert waw_cut <= base_cut == info["cut_before"]
    assert waw_cut == info["cut_after"]
    for dq in mix:
        ref = match_disjunctive(g, dq, q_pad=base_answers[dq.name].shape[1])
        assert np.array_equal(base_answers[dq.name], ref), dq.name
        assert np.array_equal(waw_answers[dq.name], ref), dq.name


# ---------------------------------------------------------------------------
# GraphSession.repartition(): parity + invalidation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small():
    g = subgen_like_graph(n_nodes=250, n_edges=700, n_embed=10, seed=3)
    return g, subgen_queries(g)


@pytest.mark.parametrize("engine_name", ["opat", "traditional", "mapreduce"])
def test_session_parity_across_repartition(small, engine_name):
    """submit() answers are identical before and after repartition() for
    every engine (placement changes, semantics never)."""
    g, dqueries = small
    k = 1 if engine_name == "mapreduce" else 4   # 1 partition per device
    sess = GraphSession(g, k=k, scheme="kway_shem", engine=engine_name,
                        seed=1, processors=2, config=EngineConfig(cap=32768))
    before = {dq.name: sess.submit(dq).answers for dq in dqueries}
    sess.repartition()
    assert sess.scheme == "waw" and sess.k == k
    for dq in dqueries:
        got = sess.submit(dq).answers
        ref = match_disjunctive(g, dq, q_pad=8)
        assert np.array_equal(before[dq.name], ref), (engine_name, dq.name)
        assert np.array_equal(got, ref), (engine_name, dq.name)


def test_repartition_invalidates_store_and_stacked_bundles(small):
    g, dqueries = small
    sess = GraphSession(g, k=4, scheme="kway_shem", engine="traditional",
                        seed=1, processors=2, config=EngineConfig(cap=32768))
    for dq in dqueries:
        sess.submit(dq)
    old_store = sess.store
    assert any(isinstance(kk, tuple) for kk in old_store.resident_keys())
    sess.repartition()
    # a fresh store: nothing from the old layout (stacked bundles included)
    # can ever be served against the new assignment
    assert sess.store is not old_store
    assert sess.store.resident_keys() == []
    assert sess.engine.store is sess.store
    assert sess.store.pg is sess.pg and sess.pg.scheme == "waw"
    # profile counters restarted for the new layout
    prof = sess.workload_profile()
    assert prof["queries_served"] == 0 and prof["scheme"] == "waw"
    assert sum(p["loads"] for p in prof["partitions"]) == 0
    # and serving still works, re-populating the new store
    res = sess.submit(dqueries[0])
    assert np.array_equal(res.answers, match_disjunctive(g, dqueries[0],
                                                         q_pad=8))
    assert any(isinstance(kk, tuple) for kk in sess.store.resident_keys())


def test_profile_spans_and_cache_capacity_survive_repartition(small):
    g, dqueries = small
    sess = GraphSession(g, k=4, scheme="kway_shem", engine="opat", seed=1,
                        cache_parts=2)
    for dq in dqueries:
        sess.submit(dq)
    prof = sess.workload_profile()
    spans = prof["answer_spans"]
    assert spans["answers_observed"] == prof["answers_served"] > 0
    assert spans["mean_span"] >= 1.0
    assert len(spans["pair_counts"]) == 4
    assert len(spans["vertex_span_counts"]) == g.n_nodes
    assert len(prof["assignment"]) == g.n_nodes
    sess.repartition(prof)
    # remembered cache capacity applies to the rebuilt store too
    assert sess.store.capacity_parts == 2
    for dq in dqueries:
        sess.submit(dq)
    assert len(sess.store.resident_keys()) <= 2
