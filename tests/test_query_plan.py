"""Catalog, cost-based planning, query validation (paper Sec. 3)."""
import pytest

from repro.core import (build_catalog, generate_plan, make_path_query,
                        make_star_query)
from repro.core.query import Query, QueryEdge, QueryNode
from repro.data.generators import imdb_like_graph


@pytest.fixture(scope="module")
def g():
    return imdb_like_graph(n_movies=100, n_people=120, seed=1)


def test_catalog_cardinalities(g):
    cat = build_catalog(g)
    assert cat.n_nodes == g.n_nodes and cat.n_edges == g.n_edges
    yid = g.node_vocab.id_of("year")
    assert cat.type_card[yid] == int((g.node_label == yid).sum())
    assert cat.label_cardinality(-1) == g.n_nodes  # wildcard
    # min/max numeric values per label
    years = g.node_value[g.node_label == yid]
    assert cat.value_min[yid] == years.min()
    assert cat.value_max[yid] == years.max()


def test_plan_covers_every_edge_once(g):
    cat = build_catalog(g)
    q = make_star_query("movie_3", [("genre_is", "?"), ("in_year", "year"),
                                    ("produced_by", "?")])
    plan = generate_plan(q, g, cat)
    assert plan.n_steps == len(q.edges)
    # each non-cycle step binds a new slot; all slots end up bound
    bound = {plan.start_slot}
    for s in plan.steps:
        assert s.src_slot in bound
        bound.add(s.dst_slot)
    assert bound == set(range(q.n_nodes))


def test_plan_prefers_selective_start(g):
    """Unique-label node should be chosen as start over a wildcard."""
    cat = build_catalog(g)
    q = Query(nodes=[QueryNode("movie_7"), QueryNode("?")],
              edges=[QueryEdge(0, 1, "genre_is")])
    plan = generate_plan(q, g, cat)
    assert plan.start_slot == 0


def test_plan_cycle_closure(g):
    cat = build_catalog(g)
    # triangle pattern: movie-genre, movie-company, and a (nonexistent)
    # genre-company edge gives a cycle-closing step
    q = Query(nodes=[QueryNode("?"), QueryNode("genre_0"), QueryNode("?")],
              edges=[QueryEdge(0, 1, "genre_is"), QueryEdge(0, 2, "produced_by"),
                     QueryEdge(1, 2, "?")])
    plan = generate_plan(q, g, cat)
    closes = [s for s in plan.steps if s.closes_cycle]
    assert len(closes) == 1


def test_disconnected_query_rejected():
    q = Query(nodes=[QueryNode("a"), QueryNode("b")], edges=[])
    with pytest.raises(AssertionError):
        q.validate()


def test_max_path_len(g):
    cat = build_catalog(g)
    q = make_path_query(["person_3", "?", "?"], ["acted_in", "produced_by"])
    plan = generate_plan(q, g, cat)
    assert plan.max_path_len() <= 2
    assert plan.max_path_len() >= 1
