"""Exactness tests for the §Perf optimizations: every hillclimb change must
be semantics-preserving (values AND gradients)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.configs.registry import ShapeSpec, concrete_batch
from repro.launch.mesh import make_test_mesh
from repro.launch.sharding import _batch_dim_spec
from repro.models import xlstm as xl
from repro.models.layers import (flash_attention, flash_attention_cv,
                                 make_tp_moe_fn)
from repro.models.transformer import forward, init_params


# ---------------------------------------------------------------------------
# §Perf-A: chunkwise mLSTM / chunked-remat sLSTM
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mlstm_setup():
    rng = np.random.default_rng(0)
    B, S, d, H = 2, 64, 32, 4
    params = jax.tree.map(lambda a: a.astype(jnp.float32),
                          xl.mlstm_init(jax.random.PRNGKey(1), d, H))
    x = jnp.asarray(rng.normal(size=(B, S, d)).astype(np.float32))
    return params, x, H


@pytest.mark.parametrize("T", [1, 8, 32, 64])
def test_mlstm_chunkwise_exact(mlstm_setup, T):
    params, x, H = mlstm_setup
    y0, s0 = xl.mlstm_apply(params, x, n_heads=H, chunk=0)
    y1, s1 = xl.mlstm_apply(params, x, n_heads=H, chunk=T)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s0["C"]), np.asarray(s1["C"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s0["m"]), np.asarray(s1["m"]),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_mlstm_chunkwise_grads(mlstm_setup):
    params, x, H = mlstm_setup
    def loss(p, chunk):
        return jnp.sum(xl.mlstm_apply(p, x, n_heads=H, chunk=chunk)[0] ** 2)
    g0 = jax.grad(loss)(params, 0)
    g1 = jax.grad(loss)(params, 16)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_mlstm_chunk_nondivisible_falls_back(mlstm_setup):
    params, x, H = mlstm_setup    # S=64; chunk 48 does not divide
    y0, _ = xl.mlstm_apply(params, x, n_heads=H, chunk=0)
    y1, _ = xl.mlstm_apply(params, x, n_heads=H, chunk=48)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


@pytest.mark.slow
def test_slstm_remat_chunk_exact():
    rng = np.random.default_rng(1)
    B, S, d, H = 2, 64, 32, 4
    params = jax.tree.map(lambda a: a.astype(jnp.float32),
                          xl.slstm_init(jax.random.PRNGKey(2), d, H))
    x = jnp.asarray(rng.normal(size=(B, S, d)).astype(np.float32))
    y0, _ = xl.slstm_apply(params, x, n_heads=H)
    y1, _ = xl.slstm_apply(params, x, n_heads=H, remat_chunk=16)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    g0 = jax.grad(lambda p: jnp.sum(
        xl.slstm_apply(p, x, n_heads=H)[0] ** 2))(params)
    g1 = jax.grad(lambda p: jnp.sum(
        xl.slstm_apply(p, x, n_heads=H, remat_chunk=16)[0] ** 2))(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# §Perf-B: expert-parallel MoE dispatch
# ---------------------------------------------------------------------------

def test_moe_tp_matches_dense_single_rank():
    cfg = reduced(ARCHS["deepseek_moe_16b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, ShapeSpec("t", "train", 32, 2), seed=1)
    batch.pop("labels")
    mesh = make_test_mesh((1, 1))
    with mesh:
        moe_fn = make_tp_moe_fn(mesh, _batch_dim_spec(mesh, 2), cfg)
        l0, a0 = forward(params, cfg, batch, remat=False)
        l1, a1 = forward(params, cfg, batch, remat=False, moe_fn=moe_fn)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=1e-4, atol=1e-4)
    assert abs(float(a0) - float(a1)) < 1e-5


@pytest.mark.slow
def test_moe_tp_matches_dense_multi_rank():
    """4 fake devices, mesh (1,4): expert weights sharded over model."""
    import os
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import ARCHS, reduced
        from repro.configs.registry import ShapeSpec, concrete_batch
        from repro.launch.mesh import make_test_mesh
        from repro.launch.sharding import _batch_dim_spec
        from repro.models.layers import make_tp_moe_fn
        from repro.models.transformer import forward, init_params
        cfg = reduced(ARCHS["deepseek_moe_16b"])   # E=4 -> 1 expert/rank
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = concrete_batch(cfg, ShapeSpec("t", "train", 32, 2), seed=1)
        batch.pop("labels")
        mesh = make_test_mesh((1, 4))
        with mesh:
            moe_fn = make_tp_moe_fn(mesh, _batch_dim_spec(mesh, 2), cfg)
            l0, a0 = forward(params, cfg, batch, remat=False)
            l1, a1 = forward(params, cfg, batch, remat=False, moe_fn=moe_fn)
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   rtol=1e-4, atol=1e-4)
        assert abs(float(a0) - float(a1)) < 1e-5
        print("MOE_TP_MULTIRANK_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "MOE_TP_MULTIRANK_OK" in proc.stdout


# ---------------------------------------------------------------------------
# §Perf-C: custom-VJP flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,Hkv,hd,cq,ck", [
    (64, 8, 2, 16, 16, 16),
    (64, 4, 4, 8, 32, 16),     # MHA, rectangular chunks
    (32, 2, 1, 8, 32, 32),     # MQA, single chunk
])
def test_flash_cv_matches_reference(S, H, Hkv, hd, cq, ck):
    rng = np.random.default_rng(S + H)
    B = 2
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)).astype(np.float32))
    o_ref = flash_attention(q, k, v, causal=True, q_chunk=cq, kv_chunk=ck)
    o_cv = flash_attention_cv(q, k, v, cq, ck)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_cv),
                               rtol=1e-5, atol=1e-5)
    g_ref = jax.grad(lambda *a: jnp.sum(flash_attention(
        *a, causal=True, q_chunk=cq, kv_chunk=ck) ** 2),
        argnums=(0, 1, 2))(q, k, v)
    g_cv = jax.grad(lambda *a: jnp.sum(flash_attention_cv(*a, cq, ck) ** 2),
                    argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_cv):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_forward_flash_cv_equals_default():
    cfg = reduced(ARCHS["qwen3_4b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, ShapeSpec("t", "train", 32, 2), seed=1)
    batch.pop("labels")
    l0, _ = forward(params, cfg, batch, remat=False)
    l1, _ = forward(params, cfg, batch, remat=False, flash_cv=True)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=1e-4, atol=2e-3)


def test_attn_remat_equals_default():
    cfg = reduced(ARCHS["granite_3_2b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, ShapeSpec("t", "train", 32, 2), seed=1)
    batch.pop("labels")
    l0, _ = forward(params, cfg, batch, remat=False)
    l1, _ = forward(params, cfg, batch, remat=False, attn_remat=True)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=1e-5, atol=1e-5)
