"""Static HLO cost analyzer: exact on known programs (the roofline's
foundation — wrong here means wrong §Roofline)."""
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.launch.hlo_cost import analyze_hlo_text


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_matmul_flops_exact():
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((256, 512), jnp.float32),
                 jax.ShapeDtypeStruct((512, 1024), jnp.float32))
    r = analyze_hlo_text(c.as_text())
    assert r["flops"] == pytest.approx(2 * 256 * 512 * 1024, rel=0.01)
    # bytes: read a + b, write out
    assert r["bytes"] == pytest.approx(4 * (256 * 512 + 512 * 1024 + 256 * 1024),
                                       rel=0.05)


def test_scan_multiplies_trip_count():
    def scanned(a, ws):
        def body(x, w):
            return x @ w, None
        y, _ = jax.lax.scan(body, a, ws)
        return y
    c = _compile(scanned,
                 jax.ShapeDtypeStruct((128, 256), jnp.float32),
                 jax.ShapeDtypeStruct((12, 256, 256), jnp.float32))
    r = analyze_hlo_text(c.as_text())
    assert r["flops"] == pytest.approx(12 * 2 * 128 * 256 * 256, rel=0.02)


def test_nested_scan():
    def inner(x, ws):
        def body(x, w):
            return x @ w, None
        return jax.lax.scan(body, x, ws)[0]

    def outer(x, ws):
        def body(x, _):
            return inner(x, ws), None
        return jax.lax.scan(body, x, None, length=5)[0]
    c = _compile(outer,
                 jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((3, 64, 64), jnp.float32))
    r = analyze_hlo_text(c.as_text())
    assert r["flops"] == pytest.approx(5 * 3 * 2 * 64 * 64 * 64, rel=0.05)


def test_batched_dot_counts_batch_dims():
    c = _compile(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                 jax.ShapeDtypeStruct((8, 32, 64), jnp.float32),
                 jax.ShapeDtypeStruct((8, 64, 16), jnp.float32))
    r = analyze_hlo_text(c.as_text())
    assert r["flops"] == pytest.approx(8 * 2 * 32 * 64 * 16, rel=0.02)


def test_collectives_counted_with_ring_factors():
    mesh = make_mesh((1,), ("x",))
    def f(x):
        return jax.lax.psum(x, "x")
    sm = jax.jit(shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P()))
    c = sm.lower(jax.ShapeDtypeStruct((1024,), jnp.float32)).compile()
    r = analyze_hlo_text(c.as_text())
    # all-reduce: 2 x operand bytes
    assert r["collective_bytes_total"] == pytest.approx(2 * 1024 * 4, rel=0.01)
    assert r["collective_op_executions"] == 1


def test_collective_inside_scan_multiplied():
    mesh = make_mesh((1,), ("x",))
    def f(xs):
        def body(c, x):
            return c + jax.lax.psum(x, "x"), None
        out, _ = jax.lax.scan(body, jnp.zeros((64,), jnp.float32), xs)
        return out
    sm = jax.jit(shard_map(f, mesh=mesh, in_specs=P(None, "x"),
                           out_specs=P("x")))
    c = sm.lower(jax.ShapeDtypeStruct((7, 64), jnp.float32)).compile()
    r = analyze_hlo_text(c.as_text())
    assert r["collective_op_executions"] == pytest.approx(7, abs=0.1)


def test_elementwise_flops():
    c = _compile(lambda a: jnp.tanh(a) + a * 2.0,
                 jax.ShapeDtypeStruct((1000,), jnp.float32))
    r = analyze_hlo_text(c.as_text())
    # tanh + mul + add = 3 flops/elem (fusion internals are still counted)
    assert 2000 <= r["flops"] <= 4500
