"""Filesystem crash-injection harness for the storage layer.

``storage/format.py`` calls ``_fault_point(step, path)`` immediately
BEFORE every durable filesystem operation it performs — tmp-file writes
(``"write"``), atomic publishes (``"rename"``), and GC/trim removals
(``"unlink"``).  Because every publish in the format is an atomic
``os.replace`` and every write goes to a tmp name first, the set of
states a real crash can leave behind is exactly the set of prefixes of
that operation sequence — so raising at the i-th fault point simulates
"the process died right before durable op i" for every i, exhaustively.

Usage (see tests/test_fault_injection.py):

    inj = FaultInjector()                 # counting mode
    with inj.installed():
        scenario()                        # runs to completion
    n = inj.count                         # durable ops the scenario does

    inj = FaultInjector(crash_at=i)       # crash mode
    with inj.installed(), pytest.raises(InjectedCrash):
        scenario()                        # dies right before op i
    # ...assert the directory still serves the last published state
"""
from __future__ import annotations

import contextlib
from typing import List, Optional, Tuple

from repro.storage import format as storage_format


class InjectedCrash(BaseException):
    """Raised at the chosen fault point.  Deliberately NOT an Exception:
    production code that swallowed ``except Exception`` around a durable
    write would hide exactly the crash states this harness exists to
    reach."""

    def __init__(self, step: str, path: str, index: int):
        super().__init__(f"injected crash before durable op #{index} "
                         f"({step} {path})")
        self.step = step
        self.path = path
        self.index = index


class FaultInjector:
    """Counts durable filesystem ops, optionally crashing at one of them.

    ``crash_at=None`` is the dry-run counting mode: the scenario runs to
    completion and ``count`` reports how many fault points it passed —
    the sweep bound for the crash mode.  With ``crash_at=i`` the i-th
    fault point (0-based) raises ``InjectedCrash`` instead of returning,
    leaving the filesystem in the exact state a kill -9 would at that
    instant.  ``ops`` records every (step, path) seen either way, so a
    failing sweep iteration can report WHICH operation it died before.
    """

    def __init__(self, crash_at: Optional[int] = None):
        self.crash_at = crash_at
        self.count = 0
        self.ops: List[Tuple[str, str]] = []

    def __call__(self, step: str, path: str) -> None:
        index = self.count
        self.count += 1
        self.ops.append((step, path))
        if self.crash_at is not None and index == self.crash_at:
            raise InjectedCrash(step, path, index)

    @contextlib.contextmanager
    def installed(self):
        """Install as the storage layer's fault hook for the block.  Not
        reentrant; the previous hook (normally None) is restored even
        when the scenario dies mid-flight."""
        prev = storage_format.fault_hook
        storage_format.fault_hook = self
        try:
            yield self
        finally:
            storage_format.fault_hook = prev


def crash_points(scenario) -> int:
    """Dry-run ``scenario()`` once under a counting injector and return
    how many durable ops (= crash points) it performs."""
    inj = FaultInjector()
    with inj.installed():
        scenario()
    return inj.count
