"""Resource profiling (obs/profile.py) and the PR-10 observability
growth around it: profiling on/off yields byte-identical answers on
every engine, every kernel.eval span carries cost attribution, memory
accounting tracks live/peak bytes, the SLO burn-rate monitor follows
SRE semantics, byte counters cross-check against load counts, the
serve-JSON report speaks schema_version 3, and the EWMA trajectory
regression gate (benchmarks/regress.py) fails on real drift while
staying quiet inside its noise band.
"""
import json
import pathlib
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import EngineConfig, GraphSession, match_disjunctive
from repro.core.metrics import RunStats, validate_run_residency
from repro.data.generators import subgen_like_graph, subgen_queries
from repro.obs import (NULL_PROFILER, NULL_TRACER, MetricsRegistry,
                       ResourceProfiler, SloBurnMonitor, Tracer,
                       ingest_session, resource_profile_snapshot)

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def setup():
    g = subgen_like_graph(n_nodes=250, n_edges=700, n_embed=10, seed=3)
    dqueries = subgen_queries(g)
    refs = {dq.name: match_disjunctive(g, dq, q_pad=8) for dq in dqueries}
    return g, dqueries, refs


def make_session(g, engine="opat", k=4, **kw):
    return GraphSession(g, k=k, scheme="kway_shem", engine=engine, seed=1,
                        processors=2, config=EngineConfig(cap=32768), **kw)


# ---------------------------------------------------------------------------
# the disabled path
# ---------------------------------------------------------------------------

def test_null_profiler_is_noop_singleton():
    assert not NULL_PROFILER.enabled
    NULL_PROFILER.sample_device(NULL_TRACER.span("x"), object())
    NULL_PROFILER.attribute_kernel(("a", "b"), None)
    NULL_PROFILER.stamp_kernel(NULL_TRACER.span("x"), ("a", "b"))
    assert NULL_PROFILER.observe_rss() == 0
    assert NULL_PROFILER.snapshot() == {"enabled": False}


def test_session_profiler_defaults(setup):
    g, _, _ = setup
    # no tracer -> profiling off; real tracer -> profiling on; an
    # explicit profiler always wins
    assert make_session(g).profiler is NULL_PROFILER
    assert make_session(g, tracer=Tracer()).profiler.enabled
    prof = ResourceProfiler()
    assert make_session(g, profiler=prof).profiler is prof


def test_disabled_profiler_overhead_under_5pct(setup):
    """The null-path cost of every profiler call a profiled scheduler
    batch would make must stay under 5% of the batch's wall time."""
    g, dqueries, _ = setup
    traced = make_session(g, tracer=Tracer())
    traced.submit_many(dqueries)                       # warm compile
    t0 = time.perf_counter()
    traced.submit_many(dqueries)
    wall = time.perf_counter() - t0
    # the profiler fires at most twice per recorded span (sample + stamp)
    n_calls = 2 * len(traced.tracer.spans)
    store = traced.store
    reps = 20000
    t0 = time.perf_counter()
    for _ in range(reps):
        NULL_PROFILER.sample_device(NULL_TRACER.span("kernel.eval"), store)
        NULL_PROFILER.stamp_kernel(NULL_TRACER.span("kernel.eval"),
                                   ("opat", "eval"))
    per_call = (time.perf_counter() - t0) / (2 * reps)
    assert n_calls * per_call < 0.05 * wall, (n_calls, per_call, wall)


# ---------------------------------------------------------------------------
# parity: profiling on/off is invisible to results
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,k", [("opat", 4), ("traditional", 4),
                                      ("mapreduce", 1)])
def test_profiled_unprofiled_parity(setup, engine, k):
    g, dqueries, _ = setup
    plain = make_session(g, engine=engine, k=k)
    prof = make_session(g, engine=engine, k=k, tracer=Tracer())
    for dq in dqueries:
        r0 = plain.submit(dq, max_answers=5)
        r1 = prof.submit(dq, max_answers=5)
        assert np.array_equal(r0.answers, r1.answers), (engine, dq.name)
        for s0, s1 in zip(r0.stats, r1.stats):
            assert s0.loads == s1.loads
            assert s0.n_answers == s1.n_answers
    # and the profiled run actually profiled
    assert prof.profiler.kernel_costs


def test_profiled_unprofiled_parity_shared_scheduler(setup):
    g, dqueries, _ = setup
    plain = make_session(g)
    prof = make_session(g, tracer=Tracer())
    rep0 = plain.submit_many(dqueries)
    rep1 = prof.submit_many(dqueries)
    assert rep0.loads == rep1.loads
    for q0, q1 in zip(rep0.results, rep1.results):
        assert np.array_equal(q0.answers, q1.answers)
    keys = set(prof.profiler.kernel_costs)
    assert any(k.startswith("scheduler.") for k in keys), keys


# ---------------------------------------------------------------------------
# kernel cost attribution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,k,key", [
    ("opat", 4, "opat:eval"),
    ("traditional", 4, "traditional:veval"),
    ("mapreduce", 1, "mapreduce:eval"),
])
def test_every_kernel_span_carries_cost_attrs(setup, engine, k, key):
    g, dqueries, _ = setup
    sess = make_session(g, engine=engine, k=k, tracer=Tracer())
    for dq in dqueries:
        sess.submit(dq, max_answers=5)
    kspans = [s for s in sess.tracer.spans if s.name == "kernel.eval"]
    assert kspans
    for sp in kspans:
        assert sp.attrs["kernel_key"] == key
        for attr in ("cost_flops", "cost_bytes", "cost_t_bound_us",
                     "cost_dominant", "device_live_bytes"):
            assert attr in sp.attrs, (key, attr)
    cost = sess.profiler.kernel_costs[key]
    assert "cost_error" not in cost, cost
    assert cost["flops"] > 0 and cost["bytes"] > 0
    assert cost["t_bound_us"] > 0
    assert cost["dominant"] in ("compute", "memory", "collective")


def test_attribution_failure_degrades_not_raises():
    prof = ResourceProfiler()
    cost = prof.attribute_kernel(("broken", "fn"), object())  # no .lower
    assert cost["cost_error"]
    assert cost["flops"] == 0.0
    # memoized: the failure is computed once, stamped consistently
    assert prof.attribute_kernel(("broken", "fn"), object()) is cost
    tr = Tracer()
    with tr.span("kernel.eval") as sp:
        prof.stamp_kernel(sp, ("broken", "fn"))
    assert tr.spans[0].attrs["kernel_key"] == "broken:fn"
    assert tr.spans[0].attrs["cost_flops"] == 0.0


# ---------------------------------------------------------------------------
# memory accounting
# ---------------------------------------------------------------------------

def test_memory_accounting_peaks_and_live_bytes(setup):
    g, dqueries, _ = setup
    sess = make_session(g, tracer=Tracer())
    for dq in dqueries:
        sess.submit(dq, max_answers=5)
    prof = sess.profiler
    assert prof.peak_device_bytes > 0
    assert prof.observe_rss() > 0 and prof.peak_rss_bytes > 0
    live = [s.attrs["device_live_bytes"] for s in sess.tracer.spans
            if "device_live_bytes" in s.attrs]
    assert live and max(live) == prof.peak_device_bytes
    snap = prof.snapshot()
    assert snap["enabled"] and snap["peak_device_bytes"] > 0


def test_run_stats_byte_fields_and_crosschecks(setup):
    g, dqueries, _ = setup
    sess = make_session(g)
    res = sess.submit(dqueries[0], max_answers=5)
    s = res.stats[0]
    assert s.bytes_cold is not None
    assert (s.cold_loads > 0) == (s.bytes_cold > 0)
    out = validate_run_residency(s)
    assert out is not None and out["bytes_cold"] == s.bytes_cold
    # a byte-accounting path that was skipped fails the cross-check
    bad = RunStats(query="q", scheme="s", heuristic="h", loads=[0, 1],
                   l_ideal=2, n_answers=1, cold_loads=2, warm_loads=0,
                   prefetch_hits=0, bytes_cold=0)
    with pytest.raises(ValueError, match="bytes"):
        validate_run_residency(bad)
    # hand-built stats without byte fields still validate (None = absent)
    ok = RunStats(query="q", scheme="s", heuristic="h", loads=[0, 1],
                  l_ideal=2, n_answers=1, cold_loads=2, warm_loads=0,
                  prefetch_hits=0)
    assert validate_run_residency(ok)["cold"] == 2


def test_metrics_ingest_profile_gauges_and_byte_counters(setup):
    g, dqueries, _ = setup
    sess = make_session(g, tracer=Tracer())
    sess.submit_many(dqueries)
    reg = MetricsRegistry()
    ingest_session(reg, sess)
    snap = reg.snapshot()
    assert snap["repro_session_peak_device_bytes"] == \
        sess.profiler.peak_device_bytes
    assert snap["repro_session_peak_rss_bytes"] > 0
    assert snap["repro_store_host_bytes_total"] == \
        sess.load_stats.bytes_host
    # in-RAM session: no disk catalog, so no disk byte counter
    assert "repro_store_disk_bytes_total" not in snap
    # unprofiled session: no peak gauges
    reg2 = MetricsRegistry()
    ingest_session(reg2, make_session(g))
    assert "repro_session_peak_device_bytes" not in reg2.snapshot()


def test_disk_and_host_byte_counters_out_of_core(setup, tmp_path):
    g, dqueries, _ = setup
    make_session(g).save(str(tmp_path / "gd"))
    sess = GraphSession.open(str(tmp_path / "gd"), engine="opat", seed=1,
                             config=EngineConfig(cap=32768),
                             host_cache_parts=2, tracer=Tracer())
    res = sess.submit(dqueries[0], max_answers=5)
    s = res.stats[0]
    assert s.bytes_disk is not None and s.bytes_disk > 0
    assert s.bytes_host is not None and s.bytes_host > 0
    assert (s.disk_reads > 0) == (s.bytes_disk > 0)
    assert validate_run_residency(s)["bytes_disk"] == s.bytes_disk
    # the catalog-level byte counter reaches the registry and the
    # serve-JSON profile block
    reg = MetricsRegistry()
    ingest_session(reg, sess)
    snap = reg.snapshot()
    assert snap["repro_store_disk_bytes_total"] > 0
    block = resource_profile_snapshot(sess)
    assert block["bytes"]["disk_catalog"] >= block["bytes"]["disk"] > 0
    assert block["bytes"]["host"] == sess.load_stats.bytes_host


# ---------------------------------------------------------------------------
# SLO burn rate
# ---------------------------------------------------------------------------

def test_slo_burn_monitor_semantics():
    m = SloBurnMonitor(window=4, error_budget=0.25)
    assert m.burn_rate("interactive") == 0.0       # empty window
    for met in (True, True, False, True):
        m.observe("interactive", met)
    assert m.miss_fraction("interactive") == pytest.approx(0.25)
    assert m.burn_rate("interactive") == pytest.approx(1.0)
    # the window rolls: four more meets flush the miss out
    for _ in range(4):
        m.observe("interactive", True)
    assert m.burn_rate("interactive") == 0.0
    snap = SloBurnMonitor(window=2, error_budget=0.5)
    snap.observe("batch", False)
    s = snap.snapshot()["batch"]
    assert s["window"] == 1 and s["misses"] == 1
    assert s["burn_rate"] == pytest.approx(2.0)    # 1.0 miss / 0.5 budget
    with pytest.raises(ValueError):
        SloBurnMonitor(window=0)
    with pytest.raises(ValueError):
        SloBurnMonitor(error_budget=0.0)


def test_frontend_burn_rate_export(setup):
    from repro.serving import Request, parse_slo_spec
    g, dqueries, _ = setup
    sess = make_session(g, tracer=Tracer())
    fe = sess.frontend(slo_classes=parse_slo_spec("interactive=30"),
                       shed_policy="never")
    rep = fe.serve([Request(dq, slo_class="interactive")
                    for dq in dqueries])
    burn = rep.slo_burn["interactive"]
    assert burn["window"] == len(dqueries)
    assert burn["burn_rate"] == 0.0                # 30s deadline: all met
    # a sub-millisecond deadline misses everything: burn = 1/0.01 budget
    sess2 = make_session(g, tracer=Tracer())
    fe2 = sess2.frontend(slo_classes=parse_slo_spec("interactive=0.000001"),
                         shed_policy="never")
    rep2 = fe2.serve([Request(dq, slo_class="interactive")
                      for dq in dqueries])
    burn2 = rep2.slo_burn["interactive"]
    assert burn2["miss_fraction"] == 1.0
    assert burn2["burn_rate"] == pytest.approx(1.0 / 0.01)
    # the session kept it, and the registry exports it as a gauge
    assert sess2._slo_burn["interactive"]["burn_rate"] == \
        burn2["burn_rate"]
    reg = MetricsRegistry()
    ingest_session(reg, sess2)
    snap = reg.snapshot()
    assert snap["repro_frontend_slo_burn_rate{slo_class=interactive}"] == \
        pytest.approx(burn2["burn_rate"])
    block = resource_profile_snapshot(sess2)
    assert block["slo_burn"]["interactive"]["misses"] == len(dqueries)


# ---------------------------------------------------------------------------
# trajectory regression gate (benchmarks/regress.py + track.py growth)
# ---------------------------------------------------------------------------

def _traj_point(day, **over):
    pt = dict(utc_date=f"2026-07-{day:02d}", schema_version=1, n_trials=1,
              shared_b8_loads_per_query=0.5, shared_b8_qps=4.0,
              shared_b8_p95_ms=1000.0, oocore_disk_reads=20,
              kernel_speedup=None, kernel_backend="cpu")
    pt.update(over)
    return pt


def test_regress_clean_trajectory_passes():
    from benchmarks.regress import detect
    traj = [_traj_point(d, shared_b8_p95_ms=1000.0 + 20 * (d % 4),
                        shared_b8_qps=4.0 + 0.1 * (d % 3))
            for d in range(1, 9)]
    findings = detect(traj)
    assert all(f["status"] != "regression" for f in findings), findings
    # cpu kernel_speedup never gates: 0 usable points
    ks = next(f for f in findings if f["metric"] == "kernel_speedup")
    assert ks["status"] == "skipped"


def test_regress_fails_on_genuine_regression():
    from benchmarks.regress import detect
    traj = [_traj_point(d) for d in range(1, 8)]
    bad = detect(traj + [_traj_point(8, shared_b8_p95_ms=2000.0)])
    assert [f["metric"] for f in bad if f["status"] == "regression"] == \
        ["shared_b8_p95_ms"]
    # qps collapse trips its own metric
    bad2 = detect(traj + [_traj_point(8, shared_b8_qps=1.0)])
    assert any(f["metric"] == "shared_b8_qps"
               and f["status"] == "regression" for f in bad2)
    # deterministic counter drift gates too
    bad3 = detect(traj + [_traj_point(8, oocore_disk_reads=40)])
    assert any(f["metric"] == "oocore_disk_reads"
               and f["status"] == "regression" for f in bad3)


def test_regress_noise_stays_in_band():
    from benchmarks.regress import detect
    # within the 20% relative band AND the 75 ms absolute floor
    traj = [_traj_point(d) for d in range(1, 8)]
    ok = detect(traj + [_traj_point(8, shared_b8_p95_ms=1060.0,
                                    shared_b8_qps=3.7)])
    assert all(f["status"] != "regression" for f in ok), ok
    # a measured across-trial stddev widens the band past the floors
    noisy = [_traj_point(d, n_trials=3, shared_b8_p95_ms_std=150.0)
             for d in range(1, 8)]
    ok2 = detect(noisy + [_traj_point(8, shared_b8_p95_ms=1400.0,
                                      n_trials=3,
                                      shared_b8_p95_ms_std=150.0)])
    assert all(f["status"] != "regression" for f in ok2), ok2


def test_regress_too_few_points_passes_with_note():
    from benchmarks.regress import detect
    findings = detect([_traj_point(1)])
    assert all(f["status"] == "skipped" for f in findings)
    assert all("need 2" in f["note"] for f in findings)


def test_track_trajectory_dedupes_same_day(tmp_path):
    from benchmarks.track import append_trajectory, summary_point
    point = {
        "utc_date": "2026-08-09", "schema_version": 1, "n_trials": 2,
        "shared": [{"mode": "shared", "batch": 8, "loads_per_query": 0.5,
                    "qps": 4.0, "qps_std": 0.2, "p50_ms": 80.0,
                    "p95_ms": 120.0, "p95_ms_std": 5.0, "p99_ms": 140.0,
                    "cold_loads": 4, "warm_loads": 12}],
        "oocore": [{"mode": "out-of-core", "disk_reads": 20}],
        "kernel": {"speedup": 0.05, "backend": "cpu"},
    }
    sp = summary_point(point)
    assert sp["kernel_speedup"] is None          # cpu: suppressed
    assert sp["kernel_backend"] == "cpu"
    assert sp["shared_b8_p95_ms"] == 120.0
    assert sp["shared_b8_p95_ms_std"] == 5.0
    assert sp["n_trials"] == 2
    path = tmp_path / "traj.json"
    append_trajectory(str(path), point)
    append_trajectory(str(path), dict(point, n_trials=3))
    traj = json.loads(path.read_text())
    assert len(traj) == 1                        # same day: replaced
    assert traj[0]["n_trials"] == 3
    other = dict(point, utc_date="2026-08-10")
    append_trajectory(str(path), other)
    assert len(json.loads(path.read_text())) == 2


def test_track_merge_trials_stats():
    from benchmarks.track import _merge_trials
    runs = [[{"mode": "shared", "batch": 8, "cold_loads": 4,
              "p95_ms": 100.0, "qps": 4.0}],
            [{"mode": "shared", "batch": 8, "cold_loads": 4,
              "p95_ms": 110.0, "qps": 4.2}]]
    merged = _merge_trials(runs, ["mode", "batch"])
    assert merged[0]["p95_ms"] == pytest.approx(105.0)
    assert merged[0]["p95_ms_std"] > 0
    assert merged[0]["cold_loads"] == 4          # counters untouched
    # diverging counters are a nondeterminism bug, not noise
    runs[1][0]["cold_loads"] = 5
    with pytest.raises(SystemExit):
        _merge_trials(runs, ["mode", "batch"])


# ---------------------------------------------------------------------------
# serve-JSON schema v3 + trace_report --cost (end to end)
# ---------------------------------------------------------------------------

def test_resource_profile_snapshot_disabled(setup):
    g, _, _ = setup
    assert resource_profile_snapshot(make_session(g)) == {"enabled": False}


@pytest.mark.slow
def test_serve_json_schema_v3_and_cost_report(tmp_path):
    out = tmp_path / "report.json"
    trace = tmp_path / "trace.json"
    run = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--dataset",
         "synthetic", "--scale", "0.2", "--max-answers", "5",
         "--json", str(out), "--trace-out", str(trace), "--verify"],
        cwd=ROOT, capture_output=True, text=True,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert run.returncode == 0, run.stderr
    rep = json.loads(out.read_text())
    assert rep["schema_version"] == 3
    prof = rep["profile"]
    assert prof["enabled"] is True
    assert prof["peak_device_bytes"] > 0
    assert prof["kernel_costs"]["opat:eval"]["flops"] > 0
    assert prof["bytes"]["cold"] > 0
    # the cost table joins measured time with the prediction
    cost = subprocess.run(
        [sys.executable, "tools/trace_report.py", str(trace), "--cost"],
        cwd=ROOT, capture_output=True, text=True)
    assert cost.returncode == 0, cost.stderr
    assert "opat:eval" in cost.stdout and "roofline" in cost.stdout
    # --check enforces cost attrs on every kernel span (all-or-none)
    chk = subprocess.run(
        [sys.executable, "tools/trace_report.py", str(trace), "--check"],
        cwd=ROOT, capture_output=True, text=True)
    assert chk.returncode == 0, chk.stderr
    # strip the attrs from one kernel span: the gate must fail
    doc = json.loads(trace.read_text())
    for e in doc["traceEvents"]:
        if e.get("ph") == "X" and e.get("name") == "kernel.eval":
            for k in ("kernel_key", "cost_flops", "cost_bytes",
                      "cost_t_bound_us", "cost_dominant"):
                e["args"].pop(k, None)
            break
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(doc))
    chk2 = subprocess.run(
        [sys.executable, "tools/trace_report.py", str(bad), "--check"],
        cwd=ROOT, capture_output=True, text=True)
    assert chk2.returncode != 0
    assert "cost attrs" in chk2.stderr
