"""Docs exist and contain no dead relative links (ISSUE-3 acceptance:
README + both docs pages present, zero dead links — the same check CI
runs via tools/check_links.py)."""
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

from check_links import find_dead_links  # noqa: E402


def test_required_docs_exist():
    for rel in ("README.md", "docs/architecture.md", "docs/serving.md"):
        assert (REPO / rel).is_file(), f"{rel} is missing"


def test_no_dead_relative_links():
    dead = find_dead_links([str(REPO / "README.md"), str(REPO / "docs")],
                           root=REPO)
    assert dead == [], f"dead relative links: {dead}"


def test_checker_catches_dead_links(tmp_path):
    good = tmp_path / "real.md"
    good.write_text("ok")
    md = tmp_path / "page.md"
    md.write_text("[ok](real.md) [anchor](#x) [ext](https://x.y/z) "
                  "[dead](missing.md) [deep](sub/nope.md) "
                  "[rootdead](/no/such/file.md)")
    dead = find_dead_links([str(tmp_path)], root=tmp_path)
    assert len(dead) == 3
    assert any("missing.md" in d for d in dead)
    assert any("/no/such/file.md" in d for d in dead)
    # a root-absolute link is alive when it resolves under the given root
    (tmp_path / "page2.md").write_text("[rootok](/real.md)")
    assert find_dead_links([str(tmp_path / "page2.md")], root=tmp_path) == []
