"""Crash-injection sweep over the streaming-storage write paths.

ISSUE 8 acceptance: for EVERY durable filesystem operation performed by a
mutate -> compact -> mutate -> compact_all scenario, killing the process
immediately before that operation must leave the directory in a state
where

  * the last published manifest generation still opens and every shard
    reads back checksum-clean,
  * ``open_mutable`` recovers exactly a durable *prefix* of the mutation
    history (never a torn or reordered state),
  * the directory still makes progress (a follow-up ``compact_all``
    succeeds and preserves the recovered state), and
  * (sampled points) a full ``GraphSession.open`` serves oracle-correct
    answers over the recovered snapshot.

The harness lives in tests/fault_injection.py and drives the
``fault_hook`` installed in storage/format.py.
"""
import math
import os
import shutil

import numpy as np
import pytest

from fault_injection import FaultInjector, InjectedCrash
from repro.core import (EngineConfig, GraphSession, build_partitions,
                        match_disjunctive, partition_graph)
from repro.data.generators import subgen_like_graph, subgen_queries
from repro.storage import DiskCatalog, save_partitioned_graph
from repro.storage.deltas import open_mutable

ENGINE_EVERY = 10        # full engine+oracle check at every Nth crash point


def canon(g):
    """Order-independent canonical form of a graph (gids are stable
    across the delta path and a from-scratch rebuild, so gid-keyed tuples
    are directly comparable)."""
    node_label = np.asarray(g.node_label)
    node_value = np.asarray(g.node_value)
    nodes = []
    for i in range(int(g.n_nodes)):
        val = float(node_value[i])
        nodes.append((i, g.node_vocab.str_of(int(node_label[i])),
                      None if math.isnan(val) else val))
    edges = sorted(
        (int(u), int(v), g.edge_vocab.str_of(int(lab)), bool(d))
        for u, v, lab, d in zip(np.asarray(g.edge_src),
                                np.asarray(g.edge_dst),
                                np.asarray(g.edge_label),
                                np.asarray(g.edge_directed)))
    return tuple(nodes), tuple(edges)


def mdir_canon(mdir):
    view = mdir.snapshot()
    try:
        return canon(view.graph)
    finally:
        view.release()


def run_scenario(path, ops_a, ops_b):
    """The swept write workload: deltas, a single-partition compaction,
    another delta, then a full fold — every write path in deltas.py."""
    mdir = open_mutable(path)
    for op in ops_a:
        mdir.apply_op(op)
    mdir.compact(0)
    for op in ops_b:
        mdir.apply_op(op)
    mdir.compact_all()


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    g = subgen_like_graph(n_nodes=60, n_edges=150, n_embed=6, seed=11)
    assign = partition_graph(g, 3, "kway_shem")
    pg = build_partitions(g, assign, 3, scheme="kway_shem")
    base = str(tmp_path_factory.mktemp("fault-base"))
    save_partitioned_graph(pg, base)
    dqueries = subgen_queries(g)[:2]

    u0, v0 = int(g.edge_src[0]), int(g.edge_dst[0])
    lab0 = g.edge_vocab.str_of(int(g.edge_label[0]))
    ops_a = [
        {"op": "edge_add", "u": 1, "v": 5, "label": "E_soak"},
        {"op": "edge_del", "u": u0, "v": v0, "label": lab0},
        # pid pinned to 0 so compact(0) is guaranteed a stale shard
        {"op": "vertex_add", "label": "L_new", "value": 0.25, "pid": 0},
        {"op": "vertex_del", "u": 2},
    ]
    ops_b = [{"op": "edge_add", "u": 3, "v": 7, "label": "E_soak"}]

    # Mirror run: the only states a crash may recover to are the durable
    # prefixes of the record history (compaction never changes the
    # logical graph, only folds it).
    mirror = str(tmp_path_factory.mktemp("fault-mirror") / "m")
    shutil.copytree(base, mirror)
    md = open_mutable(mirror)
    states = [mdir_canon(md)]
    for op in ops_a + ops_b:
        md.apply_op(op)
        states.append(mdir_canon(md))

    # Counting dry run fixes the sweep bound and the op labels.
    count_dir = str(tmp_path_factory.mktemp("fault-count") / "c")
    shutil.copytree(base, count_dir)
    inj = FaultInjector()
    with inj.installed():
        run_scenario(count_dir, ops_a, ops_b)
    return {"base": base, "ops_a": ops_a, "ops_b": ops_b, "states": states,
            "dqueries": dqueries, "n_points": inj.count, "all_ops": inj.ops,
            "count_dir": count_dir}


def test_scenario_exercises_every_durable_step(setup):
    """The dry run touches log appends, shard writes, graph-file writes,
    manifest publishes, and post-publish unlinks — the sweep below covers
    the whole write surface, not a cherry-picked subset."""
    names = {(s, os.path.basename(p).split("-")[0].split(".")[0])
             for s, p in setup["all_ops"]}
    assert ("write", "deltas") in names and ("rename", "deltas") in names
    assert ("write", "part") in names and ("rename", "part") in names
    assert ("write", "graph") in names
    assert ("rename", "manifest") in names
    assert any(s == "unlink" for s, _ in setup["all_ops"])
    assert setup["n_points"] >= 20
    # and the uninjected run lands on the final mirror state
    assert mdir_canon(open_mutable(setup["count_dir"])) == \
        setup["states"][-1]


def test_injector_restores_hook_after_crash(setup, tmp_path):
    from repro.storage import format as storage_format
    work = str(tmp_path / "hook")
    shutil.copytree(setup["base"], work)
    inj = FaultInjector(crash_at=0)
    with pytest.raises(InjectedCrash):
        with inj.installed():
            run_scenario(work, setup["ops_a"], setup["ops_b"])
    assert storage_format.fault_hook is None


def test_crash_sweep_previous_generation_survives(setup, tmp_path):
    """THE acceptance sweep: every crash point, storage-level recovery
    checks at all of them, engine+oracle serving at every Nth."""
    states = setup["states"]
    n = setup["n_points"]
    for crash_at in range(n):
        work = str(tmp_path / f"crash-{crash_at:03d}")
        shutil.copytree(setup["base"], work)
        inj = FaultInjector(crash_at=crash_at)
        with pytest.raises(InjectedCrash):
            with inj.installed():
                run_scenario(work, setup["ops_a"], setup["ops_b"])
        step, path = inj.ops[crash_at]
        ctx = f"crash #{crash_at} before {step} {os.path.basename(path)}"

        # the last published generation opens and reads checksum-clean
        cat = DiskCatalog(work)
        for pid in range(cat.k):
            cat.read_part(pid)

        # recovery = last manifest + a durable prefix of the records
        mdir = open_mutable(work)
        got = mdir_canon(mdir)
        assert got in states, ctx

        # the directory still makes progress, preserving the state
        mdir.compact_all()
        re_mdir = open_mutable(work)
        assert mdir_canon(re_mdir) == got, ctx
        assert not re_mdir._records, ctx           # fully folded

        if crash_at % ENGINE_EVERY == 0 or crash_at == n - 1:
            sess = GraphSession.open(work, engine="opat", seed=1,
                                     config=EngineConfig(cap=32768))
            for dq in setup["dqueries"]:
                res = sess.submit(dq)
                ref = match_disjunctive(sess.graph, dq,
                                        q_pad=res.answers.shape[1])
                assert np.array_equal(res.answers, ref), (ctx, dq.name)
        shutil.rmtree(work)                        # bound tmp usage


NAMED_POINTS = {
    # name: (predicate on (step, basename), expected recovered prefix
    #        length or None, generation still 0 after recovery?)
    "log-append-write": (
        lambda s, b: s == "write" and b.startswith("deltas-"), 0, True),
    "log-append-rename": (
        lambda s, b: s == "rename" and b.startswith("deltas-"), 0, True),
    "shard-write": (
        lambda s, b: s == "write" and b.startswith("part-"), 4, True),
    "graph-file-write": (
        lambda s, b: s == "write" and b.startswith("graph-"), 4, True),
    "manifest-publish": (
        lambda s, b: s == "rename" and b.startswith("manifest"), 4, True),
    "post-publish-unlink": (
        lambda s, b: s == "unlink", 4, False),
}


@pytest.mark.parametrize("point", sorted(NAMED_POINTS))
def test_named_crash_points(setup, tmp_path, point):
    """Targeted semantics at the first occurrence of each step kind:
    a crash before a log publish loses exactly the in-flight record; a
    crash anywhere inside compact(0) keeps all four durable records AND
    generation 0; a crash in trim/GC happens after the publish."""
    pred, prefix_len, gen0 = NAMED_POINTS[point]
    crash_at = next(i for i, (s, p) in enumerate(setup["all_ops"])
                    if pred(s, os.path.basename(p)))
    work = str(tmp_path / "named")
    shutil.copytree(setup["base"], work)
    inj = FaultInjector(crash_at=crash_at)
    with pytest.raises(InjectedCrash):
        with inj.installed():
            run_scenario(work, setup["ops_a"], setup["ops_b"])
    cat = DiskCatalog(work)
    if gen0:
        assert cat.generation == 0
    else:
        assert cat.generation >= 1
    for pid in range(cat.k):
        cat.read_part(pid)
    assert mdir_canon(open_mutable(work)) == setup["states"][prefix_len]
