"""Pallas kernels vs pure-jnp oracles, swept over shapes/dtypes
(interpret mode on CPU; the kernels TARGET TPU)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (EngineConfig, MAX_SN, OPATEngine, build_catalog,
                        build_partitions, generate_plan, match_query,
                        partition_graph)
from repro.core.plan import PlanArrays
from repro.kernels import ops, ref
from repro.kernels.ops import frontier_expand, frontier_expand_ref, label_histogram


def _random_plan(rng, S, Q):
    return PlanArrays(
        n_slots=Q, n_steps=S,
        start_slot=np.int32(0), start_label=np.int32(0),
        start_value_op=np.int32(0), start_value=np.float32(0),
        src_slot=rng.integers(0, Q, S).astype(np.int32),
        dst_slot=rng.integers(0, Q, S).astype(np.int32),
        edge_label=rng.integers(-1, 3, S).astype(np.int32),
        direction=rng.integers(0, 3, S).astype(np.int32),
        dst_label=rng.integers(-1, 3, S).astype(np.int32),
        dst_value_op=rng.integers(0, 7, S).astype(np.int32),
        dst_value=rng.normal(size=S).astype(np.float32),
        closes_cycle=rng.integers(0, 2, S).astype(np.int32),
    )


def _random_ell(rng, Np, W, n_labels=3):
    dst = rng.integers(-1, Np, size=(Np, W)).astype(np.int32)
    lab = rng.integers(-2, n_labels, size=(Np, W)).astype(np.int32)
    dire = rng.integers(0, 3, size=(Np, W)).astype(np.int32)
    dlab = rng.integers(-2, n_labels, size=(Np, W)).astype(np.int32)
    dval = rng.normal(size=(Np, W)).astype(np.float32)
    dval[rng.random((Np, W)) < 0.2] = np.nan
    dgid = np.where(dst >= 0, rng.integers(0, 1000, size=(Np, W)), -1).astype(np.int32)
    return dst, lab, dire, dlab, dval, dgid


@pytest.mark.parametrize("EB,W,Q,Np", [
    (4, 4, 4, 8),
    (16, 7, 6, 32),       # W not a multiple of 128 -> wrapper pads
    (32, 128, 8, 64),     # W already lane-aligned
    (8, 130, 5, 16),      # W just past one lane tile
    (1, 1, 1, 1),         # degenerate minimum
])
def test_frontier_expand_matches_ref(EB, W, Q, Np):
    rng = np.random.default_rng(EB * 1000 + W)
    S = 6
    plan = _random_plan(rng, S, Q)
    tables = _random_ell(rng, Np, W)
    rows = rng.integers(-1, 1000, size=(EB, Q)).astype(np.int32)
    step = rng.integers(0, S + 2, size=EB).astype(np.int32)
    lidx = rng.integers(0, Np, size=EB).astype(np.int32)
    m = rng.random(EB) < 0.8
    n_steps = np.int32(S - 1)

    ok_k, dg_k = frontier_expand(rows, step, lidx, m, *tables, plan, n_steps,
                                 interpret=True)
    ok_r, dg_r = frontier_expand_ref(rows, step, lidx, m, *tables, plan, n_steps)
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_r))
    # dst gids only meaningful where an edge exists
    mask = np.asarray(tables[0])[np.clip(lidx, 0, Np - 1)] >= 0
    np.testing.assert_array_equal(np.asarray(dg_k)[mask], np.asarray(dg_r)[mask])


@pytest.mark.parametrize("Np", [1, 5, 1024, 1025, 4096])
@pytest.mark.parametrize("label,op", [(0, 0), (1, 1), (-1, 3), (2, 6)])
def test_label_histogram_matches_ref(Np, label, op):
    rng = np.random.default_rng(abs(Np + label * 31 + op))
    node_label = rng.integers(-2, 4, Np).astype(np.int32)
    node_value = rng.normal(size=Np).astype(np.float32)
    node_value[rng.random(Np) < 0.3] = np.nan
    core = (rng.random(Np) < 0.7).astype(np.int32)
    got = label_histogram(node_label, node_value, core,
                          np.int32(label), np.int32(op), np.float32(0.1),
                          interpret=True)
    want = ref.label_histogram_ref(node_label, node_value, core.astype(bool),
                                   np.int32(label), np.int32(op),
                                   np.float32(0.1))
    assert int(got) == int(want)


def test_value_pred_nan_semantics():
    vals = jnp.asarray([1.0, jnp.nan, 3.0])
    for op in range(7):
        out = np.asarray(ref.value_pred(jnp.int32(op), vals, jnp.float32(1.0)))
        if op == 0:
            assert out.all()
        else:
            assert not out[1]  # NaN fails every comparison


def test_engine_end_to_end_with_pallas(small_graph):
    """The OPAT engine produces oracle-identical answers with the Pallas
    match kernel swapped in (interpret mode)."""
    from repro.data.generators import subgen_queries
    assign = partition_graph(small_graph, 4, "fast")
    pg = build_partitions(small_graph, assign, 4)
    cat = build_catalog(small_graph)
    q = subgen_queries(small_graph)[0].disjuncts[0]
    plan = generate_plan(q, small_graph, cat)
    eng = OPATEngine(pg, EngineConfig(cap=16384, use_pallas=True))
    res = eng.run(plan, MAX_SN)
    ref_ans = match_query(small_graph, q, q_pad=8)
    assert np.array_equal(np.unique(res.answers, axis=0), ref_ans)


# ---------------------------------------------------------------------------
# fused expand + classify kernel (single-pass done/keep/out routing)
# ---------------------------------------------------------------------------

_V = 1000   # global-id space used by _random_ell's dgid column


def _random_locality(rng, Np):
    """Random partition context: g2l row (-1 = absent), owner map, core
    boundary."""
    g2l_row = np.full(_V, -1, np.int32)
    present = rng.choice(_V, size=min(Np, _V), replace=False)
    g2l_row[present] = rng.permutation(len(present)).astype(np.int32)
    owner = rng.integers(0, 4, _V).astype(np.int32)
    n_core = int(rng.integers(1, Np + 1))
    return g2l_row, owner, n_core


def _fused_both(rng, plan, tables, EB, W, Q, Np, n_steps, m=None):
    g2l_row, owner, n_core = _random_locality(rng, Np)
    dlidx, downer = ops.denorm_locality(jnp.asarray(tables[5]),
                                        jnp.asarray(g2l_row),
                                        jnp.asarray(owner))
    rows = rng.integers(-1, _V, size=(EB, Q)).astype(np.int32)
    step = rng.integers(0, plan.n_steps + 2, size=EB).astype(np.int32)
    lidx = rng.integers(0, Np, size=EB).astype(np.int32)
    if m is None:
        m = rng.random(EB) < 0.8
    got = ops.fused_frontier(rows, step, lidx, m, *tables, dlidx, downer,
                             g2l_row, owner, n_core, plan, n_steps,
                             interpret=True)
    want = ops.fused_frontier_ref(rows, step, lidx, m, *tables,
                                  g2l_row, owner, n_core, plan, n_steps)
    return got, want, lidx


def _assert_fused_equal(got, want, tables, lidx, Np):
    names = ("ok", "dg", "done", "keep", "out", "dest")
    ok_k, dg_k, done_k, keep_k, out_k, dest_k = map(np.asarray, got)
    ok_r, dg_r, done_r, keep_r, out_r, dest_r = map(np.asarray, want)
    for name, a, b in zip(names, (ok_k, done_k, keep_k, out_k),
                          (ok_r, done_r, keep_r, out_r)):
        np.testing.assert_array_equal(a, b, err_msg=name)
    # dst gids only meaningful where an edge exists; dest only where the
    # row is routed out
    edge = np.asarray(tables[0])[np.clip(lidx, 0, Np - 1)] >= 0
    np.testing.assert_array_equal(dg_k[edge], dg_r[edge], err_msg="dg")
    np.testing.assert_array_equal(dest_k[out_r], dest_r[out_r],
                                  err_msg="dest")
    # the three routes partition the matches: done|keep|out == ok, disjoint
    assert not (done_r & keep_r).any() and not (done_r & out_r).any() \
        and not (keep_r & out_r).any()
    np.testing.assert_array_equal(done_r | keep_r | out_r, ok_r)


@pytest.mark.parametrize("EB,W,Q,Np", [
    (4, 4, 4, 8),
    (16, 7, 6, 32),       # W not a multiple of 128 -> wrapper pads
    (32, 128, 8, 64),     # W already lane-aligned
    (8, 130, 5, 16),      # W just past one lane tile
    (1, 1, 1, 1),         # degenerate minimum
])
def test_fused_frontier_matches_ref(EB, W, Q, Np):
    rng = np.random.default_rng(EB * 1000 + W + 7)
    plan = _random_plan(rng, 6, Q)
    tables = _random_ell(rng, Np, W)
    got, want, lidx = _fused_both(rng, plan, tables, EB, W, Q, Np,
                                  np.int32(5))
    _assert_fused_equal(got, want, tables, lidx, Np)


def test_fused_frontier_empty_frontier():
    """An all-inactive binding batch matches the oracle and routes
    nothing."""
    rng = np.random.default_rng(11)
    EB, W, Q, Np = (8, 16, 4, 8)
    plan = _random_plan(rng, 6, Q)
    tables = _random_ell(rng, Np, W)
    got, want, lidx = _fused_both(rng, plan, tables, EB, W, Q, Np,
                                  np.int32(5), m=np.zeros(EB, bool))
    _assert_fused_equal(got, want, tables, lidx, Np)
    ok, _, done, keep, out, _ = map(np.asarray, got)
    assert not ok.any() and not done.any() and not keep.any() \
        and not out.any()


def test_fused_frontier_all_filtered_labels():
    """A plan whose edge label exists nowhere in the partition matches
    the oracle and produces zero matches."""
    import dataclasses
    rng = np.random.default_rng(13)
    EB, W, Q, Np = (8, 16, 4, 8)
    plan = _random_plan(rng, 6, Q)
    plan = dataclasses.replace(plan, edge_label=np.full(6, 7, np.int32))
    tables = _random_ell(rng, Np, W, n_labels=3)   # labels in [-2, 3)
    got, want, lidx = _fused_both(rng, plan, tables, EB, W, Q, Np,
                                  np.int32(5))
    _assert_fused_equal(got, want, tables, lidx, Np)
    assert not np.asarray(got[0]).any()


# ---------------------------------------------------------------------------
# fused path swapped into every engine: oracle identity end to end
# ---------------------------------------------------------------------------

def _pallas_setup(small_graph):
    from repro.data.generators import subgen_queries
    assign = partition_graph(small_graph, 4, "kway_shem")
    pg = build_partitions(small_graph, assign, 4)
    cat = build_catalog(small_graph)
    queries = [dq.disjuncts[0] for dq in subgen_queries(small_graph)]
    return pg, cat, queries


def test_traditional_mp_end_to_end_with_pallas(small_graph):
    """TraditionalMP vmaps the fused kernel over p partitions per
    iteration; answers stay oracle-identical."""
    from repro.core import TraditionalMPEngine
    pg, cat, queries = _pallas_setup(small_graph)
    eng = TraditionalMPEngine(pg, 2, EngineConfig(cap=16384, use_pallas=True))
    for q in queries:
        plan = generate_plan(q, small_graph, cat)
        res = eng.run(plan, MAX_SN, seed=1)
        ref_ans = match_query(small_graph, q, q_pad=8)
        assert np.array_equal(np.unique(res.answers, axis=0), ref_ans), q.name


@pytest.mark.parametrize("K", [None, 3])
def test_mapreduce_end_to_end_with_pallas(small_graph, K):
    """MapReduceMP runs the fused kernel under shard_map; with a budget the
    single compiled run returns exactly min(K, total) unique answers."""
    from repro.compat import make_part_mesh
    from repro.core.mapreduce_mp import MapReduceMPEngine
    _, cat, queries = _pallas_setup(small_graph)
    pg = build_partitions(small_graph,
                          np.zeros(small_graph.n_nodes, np.int32), 1)
    mesh = make_part_mesh(1)
    eng = MapReduceMPEngine(pg, mesh, EngineConfig(cap=32768, use_pallas=True))
    for q in queries:
        plan = generate_plan(q, small_graph, cat)
        res = eng.run(plan, max_answers=K)
        ref_ans = match_query(small_graph, q, q_pad=8)
        if K is None:
            assert np.array_equal(np.unique(res.answers, axis=0), ref_ans)
        else:
            got = np.unique(res.answers, axis=0)
            assert got.shape[0] == min(K, ref_ans.shape[0]), q.name
            refset = {tuple(r) for r in ref_ans}
            assert all(tuple(r) in refset for r in got), q.name


def test_scheduler_batch_with_pallas(small_graph):
    """The scheduler's batched evaluator (query-vmapped fused kernel)
    returns oracle-identical answer sets for a shared batch."""
    from repro.core import GraphSession, match_disjunctive
    from repro.data.generators import subgen_queries
    dqueries = subgen_queries(small_graph)
    sess = GraphSession(small_graph, k=4, scheme="kway_shem", engine="opat",
                        seed=1, config=EngineConfig(cap=32768,
                                                    use_pallas=True))
    report = sess.submit_many(dqueries)
    assert report.shared
    for res, dq in zip(report.results, dqueries):
        ref_ans = match_disjunctive(small_graph, dq, q_pad=8)
        assert np.array_equal(res.answers, ref_ans), dq.name


def test_opat_pallas_k_budget_truncation(small_graph):
    """K-budget truncation through the fused path: min(K, total) unique
    true answers."""
    pg, cat, queries = _pallas_setup(small_graph)
    eng = OPATEngine(pg, EngineConfig(cap=16384, use_pallas=True))
    for q in queries:
        plan = generate_plan(q, small_graph, cat)
        ref_ans = match_query(small_graph, q, q_pad=8)
        refset = {tuple(r) for r in ref_ans}
        for K in (1, 3):
            res = eng.run(plan, MAX_SN, seed=1, max_answers=K)
            got = np.unique(res.answers, axis=0)
            assert got.shape[0] == min(K, ref_ans.shape[0]), (q.name, K)
            assert all(tuple(r) in refset for r in got), (q.name, K)


def test_mapreduce_yield_counters_surface(small_graph):
    """The compiled MapReduce program carries per-partition completed/
    spawned counters out; a budgeted run is a single compiled call (no
    geometric host re-runs), so requested==returned exactly."""
    from repro.compat import make_part_mesh
    from repro.core.mapreduce_mp import MapReduceMPEngine
    _, cat, queries = _pallas_setup(small_graph)
    pg = build_partitions(small_graph,
                          np.zeros(small_graph.n_nodes, np.int32), 1)
    eng = MapReduceMPEngine(pg, make_part_mesh(1), EngineConfig(cap=32768))
    for q in queries:
        plan = generate_plan(q, small_graph, cat)
        res = eng.run(plan)
        assert res.completed_from is not None and \
            res.completed_from.shape == (1,)
        assert res.spawned_from is not None and \
            res.spawned_from.shape == (1,)
        # every unique answer was completed at least once (duplicates may
        # push the raw counter higher)
        ref_ans = match_query(small_graph, q, q_pad=8)
        assert int(res.completed_from.sum()) >= ref_ans.shape[0]
        assert int(res.spawned_from.sum()) >= 0
