"""Pallas kernels vs pure-jnp oracles, swept over shapes/dtypes
(interpret mode on CPU; the kernels TARGET TPU)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (EngineConfig, MAX_SN, OPATEngine, build_catalog,
                        build_partitions, generate_plan, match_query,
                        partition_graph)
from repro.core.plan import PlanArrays
from repro.kernels import ops, ref
from repro.kernels.ops import frontier_expand, frontier_expand_ref, label_histogram


def _random_plan(rng, S, Q):
    return PlanArrays(
        n_slots=Q, n_steps=S,
        start_slot=np.int32(0), start_label=np.int32(0),
        start_value_op=np.int32(0), start_value=np.float32(0),
        src_slot=rng.integers(0, Q, S).astype(np.int32),
        dst_slot=rng.integers(0, Q, S).astype(np.int32),
        edge_label=rng.integers(-1, 3, S).astype(np.int32),
        direction=rng.integers(0, 3, S).astype(np.int32),
        dst_label=rng.integers(-1, 3, S).astype(np.int32),
        dst_value_op=rng.integers(0, 7, S).astype(np.int32),
        dst_value=rng.normal(size=S).astype(np.float32),
        closes_cycle=rng.integers(0, 2, S).astype(np.int32),
    )


def _random_ell(rng, Np, W, n_labels=3):
    dst = rng.integers(-1, Np, size=(Np, W)).astype(np.int32)
    lab = rng.integers(-2, n_labels, size=(Np, W)).astype(np.int32)
    dire = rng.integers(0, 3, size=(Np, W)).astype(np.int32)
    dlab = rng.integers(-2, n_labels, size=(Np, W)).astype(np.int32)
    dval = rng.normal(size=(Np, W)).astype(np.float32)
    dval[rng.random((Np, W)) < 0.2] = np.nan
    dgid = np.where(dst >= 0, rng.integers(0, 1000, size=(Np, W)), -1).astype(np.int32)
    return dst, lab, dire, dlab, dval, dgid


@pytest.mark.parametrize("EB,W,Q,Np", [
    (4, 4, 4, 8),
    (16, 7, 6, 32),       # W not a multiple of 128 -> wrapper pads
    (32, 128, 8, 64),     # W already lane-aligned
    (8, 130, 5, 16),      # W just past one lane tile
    (1, 1, 1, 1),         # degenerate minimum
])
def test_frontier_expand_matches_ref(EB, W, Q, Np):
    rng = np.random.default_rng(EB * 1000 + W)
    S = 6
    plan = _random_plan(rng, S, Q)
    tables = _random_ell(rng, Np, W)
    rows = rng.integers(-1, 1000, size=(EB, Q)).astype(np.int32)
    step = rng.integers(0, S + 2, size=EB).astype(np.int32)
    lidx = rng.integers(0, Np, size=EB).astype(np.int32)
    m = rng.random(EB) < 0.8
    n_steps = np.int32(S - 1)

    ok_k, dg_k = frontier_expand(rows, step, lidx, m, *tables, plan, n_steps,
                                 interpret=True)
    ok_r, dg_r = frontier_expand_ref(rows, step, lidx, m, *tables, plan, n_steps)
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_r))
    # dst gids only meaningful where an edge exists
    mask = np.asarray(tables[0])[np.clip(lidx, 0, Np - 1)] >= 0
    np.testing.assert_array_equal(np.asarray(dg_k)[mask], np.asarray(dg_r)[mask])


@pytest.mark.parametrize("Np", [1, 5, 1024, 1025, 4096])
@pytest.mark.parametrize("label,op", [(0, 0), (1, 1), (-1, 3), (2, 6)])
def test_label_histogram_matches_ref(Np, label, op):
    rng = np.random.default_rng(abs(Np + label * 31 + op))
    node_label = rng.integers(-2, 4, Np).astype(np.int32)
    node_value = rng.normal(size=Np).astype(np.float32)
    node_value[rng.random(Np) < 0.3] = np.nan
    core = (rng.random(Np) < 0.7).astype(np.int32)
    got = label_histogram(node_label, node_value, core,
                          np.int32(label), np.int32(op), np.float32(0.1),
                          interpret=True)
    want = ref.label_histogram_ref(node_label, node_value, core.astype(bool),
                                   np.int32(label), np.int32(op),
                                   np.float32(0.1))
    assert int(got) == int(want)


def test_value_pred_nan_semantics():
    vals = jnp.asarray([1.0, jnp.nan, 3.0])
    for op in range(7):
        out = np.asarray(ref.value_pred(jnp.int32(op), vals, jnp.float32(1.0)))
        if op == 0:
            assert out.all()
        else:
            assert not out[1]  # NaN fails every comparison


def test_engine_end_to_end_with_pallas(small_graph):
    """The OPAT engine produces oracle-identical answers with the Pallas
    match kernel swapped in (interpret mode)."""
    from repro.data.generators import subgen_queries
    assign = partition_graph(small_graph, 4, "fast")
    pg = build_partitions(small_graph, assign, 4)
    cat = build_catalog(small_graph)
    q = subgen_queries(small_graph)[0].disjuncts[0]
    plan = generate_plan(q, small_graph, cat)
    eng = OPATEngine(pg, EngineConfig(cap=16384, use_pallas=True))
    res = eng.run(plan, MAX_SN)
    ref_ans = match_query(small_graph, q, q_pad=8)
    assert np.array_equal(np.unique(res.answers, axis=0), ref_ans)
