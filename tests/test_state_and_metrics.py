"""SNI/IMA/FAA bookkeeping primitives and load-ratio metrics."""
import numpy as np
import pytest

from repro.core.metrics import (RunStats, avg_load_ratio_across_schemes,
                                avg_load_ratio_for_batch,
                                validate_run_residency)
from repro.core.query import OP_EQ, OP_NE, OP_NONE
from repro.core.state import BindingBatch, QueryState, apply_value_op


def test_binding_batch_dedup():
    rows = np.array([[1, 2], [1, 2], [3, 4], [1, 2]], dtype=np.int32)
    step = np.array([0, 0, 1, 2], dtype=np.int32)
    b = BindingBatch(rows=rows, step=step).dedup()
    assert b.n == 3   # (1,2,s0), (3,4,s1), (1,2,s2)


def test_binding_batch_concat_empty():
    e = BindingBatch.empty(4)
    r = BindingBatch(rows=np.ones((2, 4), np.int32), step=np.zeros(2, np.int32))
    assert e.concat(r).n == 2
    assert r.concat(e).n == 2


def test_apply_value_op_numpy_and_nan():
    vals = np.array([1.0, np.nan, 3.0], dtype=np.float32)
    assert apply_value_op(OP_NONE, vals, 1.0).all()
    eq = apply_value_op(OP_EQ, vals, 1.0)
    assert eq[0] and not eq[1] and not eq[2]
    ne = apply_value_op(OP_NE, vals, 1.0)
    assert not ne[0] and not ne[1] and ne[2]   # NaN fails != too


def test_query_state_eligibility():
    st = QueryState.initial(3, 4, np.array([2, 0, 1]))
    assert st.eligible() == [0, 2]
    st.fresh_pending[0] = False
    st.ima[1] = BindingBatch(rows=np.ones((1, 4), np.int32),
                             step=np.zeros(1, np.int32))
    assert st.eligible() == [1, 2]
    assert st.sni_count(1) == 1
    assert st.sni_count(2) == 1


def test_load_ratio_measures():
    stats = [
        RunStats("Q1", "fast", "max-sn", loads=[0, 1], l_ideal=2, n_answers=1),
        RunStats("Q1", "eco", "max-sn", loads=[0, 1, 1, 2], l_ideal=2,
                 n_answers=1),
        RunStats("Q2", "fast", "max-sn", loads=[0], l_ideal=1, n_answers=1),
    ]
    # h(D)^{Q1}_{pschemes} = mean(2/2, 2/4) = 0.75
    assert avg_load_ratio_across_schemes(stats, "Q1", "max-sn") == pytest.approx(0.75)
    # h(D)^{fast}_{qbatch} = mean(1.0, 1.0) = 1.0
    assert avg_load_ratio_for_batch(stats, "fast", "max-sn") == pytest.approx(1.0)


def test_run_stats_residency_invariant():
    # residency classes must tile the load sequence: cold + demand-warm +
    # prefetch-hit == n_loads (warm INCLUDES prefetch hits in the store's
    # accounting, so demand_warm = warm - prefetch_hits)
    ok = RunStats("Q", "fast", "max-sn", loads=[0, 1, 1, 2], l_ideal=2,
                  n_answers=1, cold_loads=3, warm_loads=1, prefetch_hits=1)
    out = validate_run_residency(ok)
    assert out == {"cold": 3, "demand_warm": 0, "prefetch_hits": 1,
                   "n_loads": 4}

    # hand-built RunStats without counters: nothing to validate
    bare = RunStats("Q", "fast", "max-sn", loads=[0, 1], l_ideal=2,
                    n_answers=1)
    assert validate_run_residency(bare) is None

    # a load path that skipped the counters is an instrumentation bug
    bad = RunStats("Q", "fast", "max-sn", loads=[0, 1, 1], l_ideal=2,
                   n_answers=1, cold_loads=1, warm_loads=1, prefetch_hits=0)
    with pytest.raises(ValueError):
        validate_run_residency(bad)

    # TraditionalMP's load unit is the stacked bundle (p pids per store
    # get): skip the n_loads equality, keep the internal checks
    tmp = RunStats("Q", "fast", "max-sn", loads=[0, 1, 0, 1], l_ideal=2,
                   n_answers=1, cold_loads=1, warm_loads=1, prefetch_hits=0)
    assert validate_run_residency(tmp, per_partition_loads=False) is not None
    with pytest.raises(ValueError):   # prefetch_hits > warm is always wrong
        validate_run_residency(
            RunStats("Q", "fast", "max-sn", loads=[0], l_ideal=1,
                     n_answers=0, cold_loads=0, warm_loads=1,
                     prefetch_hits=2), per_partition_loads=False)
