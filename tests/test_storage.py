"""Out-of-core partition storage (src/repro/storage/ + store backing).

Covers the ISSUE-5 tentpole/acceptance list:
  * shard round trip (``save`` -> ``DiskCatalog.read_part``) bit-identical
    per partition, checksum-verified; corruption raises;
  * manifest catalog answers SNI ranking (``start_label_counts``) and the
    CC metric without touching a shard;
  * host LRU semantics: capacity, eviction, demand reads vs read-ahead
    (``disk_reads`` / ``read_ahead_issued`` / ``read_ahead_hits``);
  * the three-tier fall-through: device miss -> host -> disk, with the
    counters landing in ``LoadStats`` / ``RunStats`` / the profile;
  * ``GraphSession.save``/``open``: answers identical to the in-RAM
    session (oracle-verified) for every engine and the scheduler, on a
    graph whose shard bytes exceed the host budget;
  * ``repartition()`` on a disk-opened session: backing dropped, stale
    host entries invalidated, old directory untouched until ``save``.
"""
import json
import os

import numpy as np
import pytest

from repro.core import (EngineConfig, GraphSession, LoadStats,
                        PartitionStore, build_partitions, match_disjunctive,
                        partition_graph)
from repro.core.engine import part_to_device_dict
from repro.data.generators import subgen_like_graph, subgen_queries
from repro.storage import (DiskCatalog, HostShardCache,
                           OutOfCorePartitionedGraph, StorageFormatError,
                           array_checksum, save_partitioned_graph)


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    g = subgen_like_graph(n_nodes=250, n_edges=700, n_embed=10, seed=3)
    assign = partition_graph(g, 4, "kway_shem")
    pg = build_partitions(g, assign, 4, scheme="kway_shem")
    dqueries = subgen_queries(g)
    refs = {dq.name: match_disjunctive(g, dq, q_pad=8) for dq in dqueries}
    gdir = str(tmp_path_factory.mktemp("graph-dir"))
    manifest = save_partitioned_graph(pg, gdir)
    return g, pg, dqueries, refs, gdir, manifest


# ---------------------------------------------------------------------------
# format: shards, manifest, checksums
# ---------------------------------------------------------------------------

def test_shard_round_trip_bit_identical(setup):
    """Acceptance: every partition's arrays survive the disk round trip
    byte for byte (dtype, shape, and content)."""
    g, pg, _, _, gdir, _ = setup
    cat = DiskCatalog(gdir)
    for pid in range(pg.k):
        part, g2l = cat.read_part(pid)
        want = part_to_device_dict(pg.parts[pid])
        assert set(part.keys()) == set(want.keys())
        for k in want:
            a, b = np.asarray(part[k]), np.asarray(want[k])
            assert a.dtype == b.dtype and a.shape == b.shape, (pid, k)
            assert a.tobytes() == b.tobytes(), (pid, k)
        assert np.asarray(g2l).tobytes() == pg.g2l[pid].tobytes()


def test_manifest_catalog_metrics(setup):
    g, pg, _, _, gdir, manifest = setup
    assert manifest["format_version"] == 1
    assert manifest["k"] == 4 and manifest["scheme"] == "kway_shem"
    assert manifest["node_pad"] == pg.node_pad
    assert manifest["ell_width"] == pg.ell_width
    assert manifest["cut_edges"] == pg.cut_edges
    cat = DiskCatalog(gdir)
    # per-partition vertex/edge counts and CC match the live graph
    assert np.array_equal(cat.components_per_partition(),
                          pg.connected_components_per_partition())
    for pid in range(pg.k):
        meta = cat.part_meta(pid)
        assert meta["n_core"] == pg.parts[pid].n_core
        assert meta["n_nodes"] == pg.parts[pid].n_nodes
        assert meta["nbytes"] > 0
        hist = dict(map(tuple, meta["label_histogram"]))
        assert sum(hist.values()) == pg.parts[pid].n_core
    assert cat.total_part_bytes() == sum(cat.part_nbytes(p)
                                         for p in range(pg.k))


def test_start_label_counts_from_manifest_match_in_ram(setup):
    """SNI ranking inputs come from the catalog (label histograms + the
    O(V) node arrays for value predicates) and agree exactly with the
    in-RAM computation, including wildcards and value predicates."""
    from repro.core.graph import WILDCARD
    from repro.core.query import OP_GT
    g, pg, _, _, gdir, _ = setup
    cat = DiskCatalog(gdir)
    ooc = OutOfCorePartitionedGraph(cat)
    labels = [WILDCARD, -3] + sorted({int(l) for l in g.node_label})[:6]
    for lid in labels:
        assert np.array_equal(ooc.start_label_counts(lid),
                              pg.start_label_counts(lid)), lid
        assert np.array_equal(ooc.start_label_counts(lid, OP_GT, 0.5),
                              pg.start_label_counts(lid, OP_GT, 0.5)), lid


def test_out_of_core_pg_mirrors_in_ram(setup):
    g, pg, _, _, gdir, _ = setup
    ooc = OutOfCorePartitionedGraph(DiskCatalog(gdir))
    assert ooc.k == pg.k and ooc.scheme == pg.scheme
    assert ooc.node_pad == pg.node_pad and ooc.ell_width == pg.ell_width
    assert ooc.parts == [] and ooc.g2l is None
    assert np.array_equal(ooc.assignment, pg.assignment)
    assert np.array_equal(ooc.owner, pg.owner)
    gg = ooc.graph
    assert gg.n_nodes == g.n_nodes and gg.n_edges == g.n_edges
    assert np.array_equal(gg.node_label, g.node_label)
    for i in range(len(g.node_vocab)):
        assert gg.node_vocab.str_of(i) == g.node_vocab.str_of(i)


def test_checksum_catches_corruption(setup, tmp_path):
    g, pg, _, _, _, _ = setup
    gdir = str(tmp_path / "corrupt")
    save_partitioned_graph(pg, gdir)
    shard = DiskCatalog(gdir).shard_path(1)
    with np.load(shard) as z:
        arrs = {k: z[k] for k in z.files}
    arrs["node_label"] = arrs["node_label"].copy()
    arrs["node_label"][0] += 1
    np.savez(shard, **arrs)
    cat = DiskCatalog(gdir)
    with pytest.raises(StorageFormatError, match="checksum"):
        cat.read_part(1)
    cat.read_part(0)                                   # others still fine
    unchecked = DiskCatalog(gdir, verify_checksums=False)
    unchecked.read_part(1)                             # opt-out honoured


def test_open_rejects_non_graph_dirs(tmp_path):
    with pytest.raises(StorageFormatError, match="manifest"):
        DiskCatalog(str(tmp_path))
    bad = tmp_path / "bad"
    bad.mkdir()
    (bad / "manifest.json").write_text(json.dumps(
        {"kind": "pgqp-graph-dir", "format_version": 999}))
    with pytest.raises(StorageFormatError, match="format_version"):
        DiskCatalog(str(bad))


def test_array_checksum_sensitivity():
    a = np.arange(8, dtype=np.int32)
    assert array_checksum(a) == array_checksum(a.copy())
    assert array_checksum(a) != array_checksum(a.astype(np.int64))
    assert array_checksum(a) != array_checksum(a.reshape(2, 4))
    b = a.copy(); b[3] = 99
    assert array_checksum(a) != array_checksum(b)


def test_save_writes_manifest_last_and_resave_is_clean(setup, tmp_path):
    """The repartition/save round-trip satellite: saving over a live
    directory replaces shards and only then the manifest, and a directory
    without a manifest is not openable."""
    g, pg, _, _, _, _ = setup
    gdir = tmp_path / "resave"
    save_partitioned_graph(pg, str(gdir))
    before = DiskCatalog(str(gdir)).manifest
    save_partitioned_graph(pg, str(gdir))              # idempotent re-save
    after = DiskCatalog(str(gdir)).manifest
    assert before["partitions"] == after["partitions"]
    assert not (gdir / "manifest.json.tmp").exists()   # temp file cleaned


# ---------------------------------------------------------------------------
# the host LRU tier
# ---------------------------------------------------------------------------

def test_host_cache_lru_and_demand_reads(setup):
    g, pg, _, _, gdir, _ = setup
    stats = LoadStats()
    tier = HostShardCache(DiskCatalog(gdir), stats, capacity_parts=2)
    b0 = tier.get(0)
    assert stats.disk_reads == 1 and stats.bytes_disk == b0.nbytes
    assert tier.get(0) is b0                        # host hit: no new read
    assert stats.disk_reads == 1
    tier.get(1)
    tier.get(0)                                     # refresh 0
    tier.get(2)                                     # evicts 1 (LRU)
    assert stats.host_evictions == 1
    assert tier.resident(0) and tier.resident(2) and not tier.resident(1)
    tier.get(1)                                     # re-read costs disk again
    assert stats.disk_reads == 4
    with pytest.raises(ValueError):
        HostShardCache(DiskCatalog(gdir), LoadStats(), capacity_parts=0)


def test_host_cache_read_ahead_overlap(setup):
    g, pg, _, _, gdir, _ = setup
    stats = LoadStats()
    tier = HostShardCache(DiskCatalog(gdir), stats, capacity_parts=4)
    assert tier.read_ahead(3) is True
    assert tier.read_ahead(3) is False              # already in flight
    assert stats.disk_reads == 1 and stats.read_ahead_issued == 1
    got = tier.get(3)                               # joins the worker
    assert stats.read_ahead_hits == 1
    want = part_to_device_dict(pg.parts[3])
    for k in want:
        assert np.asarray(got.part[k]).tobytes() == \
            np.asarray(want[k]).tobytes(), k
    assert tier.read_ahead(3) is False              # resident now
    # disabled read-ahead never spawns work
    off = HostShardCache(DiskCatalog(gdir), LoadStats(), read_ahead=False)
    assert off.read_ahead(0) is False


def test_store_three_tier_fall_through(setup):
    """Device miss -> host -> disk: a bounded device cache over a bounded
    host cache pays disk reads on re-staging, and prefetch() of a
    non-host-resident partition becomes a background read-ahead instead
    of a blocking device staging."""
    g, pg, _, _, gdir, _ = setup
    cat = DiskCatalog(gdir)
    ooc = OutOfCorePartitionedGraph(cat)
    store = PartitionStore(ooc, capacity_parts=1, backing=cat,
                           host_cache_parts=1)
    store.get(0)
    assert store.stats.disk_reads == 1 and store.stats.misses == 1
    store.get(0)                                    # device warm: no traffic
    assert store.stats.hits == 1 and store.stats.disk_reads == 1
    store.get(1)                                    # evicts 0 in BOTH tiers
    store.get(0)                                    # full fall-through again
    assert store.stats.disk_reads == 3
    assert store.stats.evictions >= 1 and store.stats.host_evictions >= 1
    # prefetch of a non-host-resident pid issues a read-ahead, not a
    # device staging; the later get joins it (read_ahead_hit) and pays
    # only the device transfer on the critical path
    issued0 = store.stats.read_ahead_issued
    assert store.prefetch(2) is True
    assert store.stats.read_ahead_issued == issued0 + 1
    assert not store.contains(2)                    # no device entry yet
    store.get(2)
    assert store.stats.read_ahead_hits >= 1
    # byte-identical to the in-RAM staging
    ram = PartitionStore(pg)
    for k in ram.get(2).part:
        assert np.asarray(store.get(2).part[k]).tobytes() == \
            np.asarray(ram.get(2).part[k]).tobytes(), k


def test_store_stacked_entries_from_disk(setup):
    """TraditionalMP/MapReduceMP-shaped stacked bundles stage through the
    host tier too, identical to the in-RAM stack."""
    g, pg, _, _, gdir, _ = setup
    cat = DiskCatalog(gdir)
    store = PartitionStore(OutOfCorePartitionedGraph(cat), backing=cat,
                           host_cache_parts=2)
    ram = PartitionStore(pg)
    a, b = store.get_stacked((2, 0, 1)), ram.get_stacked((2, 0, 1))
    for k in b.part:
        assert np.asarray(a.part[k]).tobytes() == \
            np.asarray(b.part[k]).tobytes(), k
    assert np.asarray(a.g2l).tobytes() == np.asarray(b.g2l).tobytes()
    assert store.stats.disk_reads == 3


# ---------------------------------------------------------------------------
# GraphSession.save / open
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine_name", ["opat", "traditional", "mapreduce"])
def test_open_serves_identical_answers(setup, tmp_path, engine_name):
    """Acceptance: a disk-opened session with a host cache below the
    graph's shard bytes serves oracle-identical answers for every engine,
    with real disk traffic and (on the OPAT prefetch path) read-ahead
    overlap."""
    g, pg, dqueries, refs, _, _ = setup
    k = 1 if engine_name == "mapreduce" else 4      # 1 partition per device
    sess = GraphSession(g, k=k, scheme="kway_shem", engine=engine_name,
                        seed=1, processors=2, config=EngineConfig(cap=32768))
    gdir = str(tmp_path / f"g-{engine_name}")
    manifest = sess.save(gdir)
    hc = 2 if k > 2 else None
    ooc = GraphSession.open(gdir, engine=engine_name, seed=1, processors=2,
                            config=EngineConfig(cap=32768),
                            cache_parts=hc, host_cache_parts=hc)
    assert ooc.out_of_core and ooc.k == k and ooc.scheme == "kway_shem"
    if hc is not None:
        total = sum(p["nbytes"] for p in manifest["partitions"])
        assert total > hc * max(p["nbytes"] for p in manifest["partitions"])
    for dq in dqueries:
        res = ooc.submit(dq)
        assert np.array_equal(res.answers, refs[dq.name]), \
            (engine_name, dq.name)
    st = ooc.load_stats
    assert st.disk_reads > 0
    if engine_name == "opat":
        assert st.read_ahead_hits > 0
        rep = ooc.submit(dqueries[0]).reports[0]
        assert rep.stats.disk_reads is not None      # threaded into RunStats
    prof = ooc.workload_profile()
    assert prof["out_of_core"] is True
    assert prof["cache"]["disk_reads"] == st.disk_reads


def test_open_scheduler_batch_identical(setup, tmp_path):
    g, pg, dqueries, refs, gdir, _ = setup
    ooc = GraphSession.open(gdir, engine="opat", seed=1, cache_parts=2,
                            host_cache_parts=2,
                            config=EngineConfig(cap=32768))
    report = ooc.submit_many(dqueries, fairness_gamma=0.25)
    assert len(report.results) == len(dqueries)
    for r in report.results:
        assert np.array_equal(r.answers, refs[r.name]), r.name
    assert report.load_stats.disk_reads > 0


def test_repartition_drops_backing_and_resaves(setup, tmp_path):
    """Satellite: repartition() on a disk-opened session invalidates the
    stale host-cache entries (fresh store, no backing), keeps serving
    correctly from RAM, leaves the old directory untouched, and save()
    round-trips the new layout under a fresh manifest."""
    g, pg, dqueries, refs, _, _ = setup
    gdir = str(tmp_path / "orig")
    GraphSession(g, k=4, scheme="kway_shem", engine="opat", seed=1).save(gdir)
    sess = GraphSession.open(gdir, engine="opat", seed=1, host_cache_parts=2,
                             config=EngineConfig(cap=32768))
    for dq in dqueries:
        sess.submit(dq)
    old_manifest = DiskCatalog(gdir).manifest
    info = sess.repartition()
    assert info["scheme"] == "waw"
    assert not sess.out_of_core                      # backing dropped
    assert sess.store.backing is None
    assert sess.load_stats.disk_reads == 0           # fresh counters, no disk
    for dq in dqueries:                              # serves from RAM, same
        assert np.array_equal(sess.submit(dq).answers, refs[dq.name])
    # the old directory is untouched until save() writes the new layout
    assert DiskCatalog(gdir).manifest == old_manifest
    new_dir = str(tmp_path / "waw")
    sess.save(new_dir)
    re = GraphSession.open(new_dir, engine="opat", seed=1,
                           config=EngineConfig(cap=32768))
    assert re.scheme == "waw"
    for dq in dqueries:
        assert np.array_equal(re.submit(dq).answers, refs[dq.name])


def test_ooc_save_streams_shards_bit_identical(setup, tmp_path):
    """save() of a disk-opened session copies shards through the backing
    (one partition in flight at a time) bit-identically."""
    g, pg, _, _, gdir, _ = setup
    ooc = GraphSession.open(gdir, engine="opat", seed=1, host_cache_parts=1)
    copy_dir = str(tmp_path / "copy")
    ooc.save(copy_dir)
    a, b = DiskCatalog(gdir), DiskCatalog(copy_dir)
    for pid in range(4):
        assert a.part_meta(pid)["checksums"] == b.part_meta(pid)["checksums"]
        pa, ga = a.read_part(pid)
        pb, gb = b.read_part(pid)
        for k in pa:
            assert np.asarray(pa[k]).tobytes() == np.asarray(pb[k]).tobytes()
        assert np.asarray(ga).tobytes() == np.asarray(gb).tobytes()


# ---------------------------------------------------------------------------
# review-hardening regressions
# ---------------------------------------------------------------------------

def test_read_ahead_worker_failure_surfaces_real_error(setup, tmp_path):
    """A corrupt shard read on the background thread must re-raise the
    real StorageFormatError at the next get(), not a bare KeyError."""
    g, pg, _, _, _, _ = setup
    gdir = str(tmp_path / "ra-corrupt")
    save_partitioned_graph(pg, gdir)
    cat = DiskCatalog(gdir)
    shard = cat.shard_path(2)
    with np.load(shard) as z:
        arrs = {k: z[k] for k in z.files}
    arrs["node_value"] = arrs["node_value"].copy()
    arrs["node_value"][0] = 123.0
    np.savez(shard, **arrs)
    stats = LoadStats()
    tier = HostShardCache(cat, stats)
    assert tier.read_ahead(2) is True
    with pytest.raises(StorageFormatError, match="checksum"):
        tier.get(2)
    assert not tier.resident(2)
    # the error is consumed: a later get retries the (still corrupt) read
    with pytest.raises(StorageFormatError, match="checksum"):
        tier.get(2)


def test_unconsumed_read_ahead_stays_within_host_budget(setup):
    """Read-ahead bundles nobody ever get()s land in the LRU itself —
    bounded by the host budget, with no pending-thread leak."""
    import time as _time
    g, pg, _, _, gdir, _ = setup
    stats = LoadStats()
    tier = HostShardCache(DiskCatalog(gdir), stats, capacity_parts=2)
    for pid in (0, 1, 2, 3):
        assert tier.read_ahead(pid) is True
    deadline = _time.time() + 10.0
    while tier._pending and _time.time() < deadline:
        _time.sleep(0.01)
    assert not tier._pending                      # workers self-cleaned
    assert len(tier._cache) <= 2                  # budget enforced
    assert stats.host_evictions >= 2
    # a get of a still-resident read-ahead is a hit; of an evicted one,
    # a plain demand read — never a stale counter
    resident = list(tier._cache)
    tier.get(resident[-1])
    assert stats.read_ahead_hits == 1


def test_prefetch_of_in_flight_read_ahead_does_not_block(setup):
    """store.prefetch of a pid whose read-ahead is still in flight must
    return without joining the worker (resident() is cache-only)."""
    import threading as _threading
    g, pg, _, _, gdir, _ = setup

    class SlowCatalog:
        """Delegates to a real catalog, gating reads on an event."""

        def __init__(self, inner):
            self._inner = inner
            self.gate = _threading.Event()

        def __getattr__(self, name):
            return getattr(self._inner, name)

        def read_part(self, pid):
            self.gate.wait(timeout=10.0)
            return self._inner.read_part(pid)

    slow = SlowCatalog(DiskCatalog(gdir))
    ooc = OutOfCorePartitionedGraph(DiskCatalog(gdir))
    store = PartitionStore(ooc, backing=slow, host_cache_parts=2)
    assert store.prefetch(1) is True              # read-ahead issued
    # second prefetch while the worker is gated: no staging, no join
    assert store.prefetch(1) is False
    assert store.stats.read_ahead_issued == 1
    slow.gate.set()
    entry = store.get(1)                          # joins, stages to device
    assert store.stats.read_ahead_hits == 1
    ram = PartitionStore(pg)
    for k in ram.get(1).part:
        assert np.asarray(entry.part[k]).tobytes() == \
            np.asarray(ram.get(1).part[k]).tobytes(), k


def test_resave_changed_content_uses_new_shard_generation(setup, tmp_path):
    """Content-addressed shards: re-saving a DIFFERENT layout into a live
    directory writes new file names (the old manifest's generation stays
    untouched until the fresh manifest lands) and garbage-collects the
    superseded generation afterwards."""
    g, pg, dqueries, refs, _, _ = setup
    gdir = str(tmp_path / "gen")
    sess = GraphSession(g, k=4, scheme="kway_shem", engine="opat", seed=1,
                        config=EngineConfig(cap=32768))
    m1 = sess.save(gdir)
    names1 = {p["shard"] for p in m1["partitions"]}
    for dq in dqueries:
        sess.submit(dq)
    sess.repartition()                             # a different layout
    m2 = sess.save(gdir)
    names2 = {p["shard"] for p in m2["partitions"]}
    assert names1 != names2                        # new generation
    on_disk = {f for f in os.listdir(gdir)
               if f.startswith("part-") and f.endswith(".npz")}
    assert on_disk == names2                       # old generation GC'd
    re = GraphSession.open(gdir, engine="opat", seed=1,
                           config=EngineConfig(cap=32768))
    assert re.scheme == "waw"
    for dq in dqueries:
        assert np.array_equal(re.submit(dq).answers, refs[dq.name])
